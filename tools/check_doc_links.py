"""Docs link check: every relative markdown link must resolve on disk.

FuncPipe-style reproductions die at onboarding when the README points at a
moved file, so CI (and ``tests/test_docs.py``) verify that every
``[text](target)`` in the top-level docs resolves: relative targets (with
optional ``#fragment``) must exist relative to the containing file; absolute
URLs (``http://``, ``https://``, ``mailto:``) and pure in-page anchors are
skipped.

Usage::

    python tools/check_doc_links.py [FILE.md ...]   # default: the doc set
"""

from __future__ import annotations

import os
import re
import sys
from typing import List

DEFAULT_DOCS = (
    "README.md",
    "docs/ARCHITECTURE.md",
    "src/repro/kernels/README.md",
)

# [text](target) — non-greedy text, target up to the closing paren; images
# (![alt](target)) match too, which is what we want.
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(path: str) -> List[str]:
    """Returns human-readable problems for one markdown file."""
    problems: List[str] = []
    try:
        with open(path, encoding="utf-8") as f:
            text = f.read()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    base = os.path.dirname(os.path.abspath(path))
    for n, line in enumerate(text.splitlines(), start=1):
        for target in _LINK_RE.findall(line):
            if target.startswith(_SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not os.path.exists(os.path.join(base, rel)):
                problems.append(f"{path}:{n}: broken link -> {target}")
    return problems


def main(argv=None) -> int:
    files = (argv or sys.argv[1:]) or [
        p for p in DEFAULT_DOCS if os.path.exists(p)
    ]
    problems: List[str] = []
    for path in files:
        problems.extend(check_file(path))
    for p in problems:
        print(p, file=sys.stderr)
    if not problems:
        print(f"doc links ok ({len(files)} files)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
