"""Train a (reduced) assigned-architecture LM end-to-end on CPU, with
checkpointing, a simulated crash, and a bit-identical resume — a few hundred
steps by default (deliverable b: end-to-end train driver).

    PYTHONPATH=src python examples/train_lm.py --arch llama3.2-1b --steps 200
"""

import argparse
import shutil
import tempfile

import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.training.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    shape = ShapeConfig("ex", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        crash_at = max(2, args.steps // 2)
        t1 = Trainer(cfg, shape, TrainerConfig(
            total_steps=args.steps, ckpt_every=crash_at // 2,
            ckpt_dir=ckpt_dir, stop_after=crash_at))
        h1 = t1.fit()
        print(f"ran {len(h1['loss'])} steps, then 'crashed'; "
              f"loss {h1['loss'][0]:.4f} → {h1['loss'][-1]:.4f}")

        t2 = Trainer(cfg, shape, TrainerConfig(
            total_steps=args.steps, ckpt_every=50, ckpt_dir=ckpt_dir))
        h2 = t2.fit(resume=True)
        print(f"resumed at step {h2['step'][0]}, finished {args.steps}: "
              f"final loss {h2['loss'][-1]:.4f}")
        assert h2["loss"][-1] < h1["loss"][0], "training did not learn"
        print("loss decreased ✓  (deterministic restart verified in tests)")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
