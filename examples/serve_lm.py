"""Serve a (reduced) assigned arch with batched requests: prefill + decode
loop through the engine, for a dense, an MoE and an SSM model (deliverable b).

    PYTHONPATH=src python examples/serve_lm.py
"""

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingEngine
from repro.serving.router import route_tpu
from repro.configs import get_shape


def main():
    rng = np.random.default_rng(0)
    for arch in ("internlm2-1.8b", "deepseek-moe-16b", "mamba2-370m"):
        cfg_full = get_config(arch)
        route = route_tpu(cfg_full, get_shape("decode_32k"))
        cfg = cfg_full.reduced()
        engine = ServingEngine(cfg, seed=0)
        prompts = rng.integers(0, cfg.vocab_size, size=(4, 12)).astype(np.int32)
        out = engine.generate(prompts, max_new_tokens=6)
        print(f"[{arch}] router: {route.chips} chips ({route.reason})")
        print(f"  generated tokens:\n{out.tokens}")


if __name__ == "__main__":
    main()
