"""Quickstart: the paper's system in 40 lines.

Builds a GraphChallenge-style sparse DNN, partitions it with HGP-DNN across
8 serverless workers, runs fully-serverless distributed inference over both
IPC channels, validates against the dense oracle, and prints the bill.

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.simulator import run_fsi

NEURONS, LAYERS, BATCH, WORKERS = 512, 24, 64, 8


def main():
    net = make_sparse_dnn(NEURONS, n_layers=LAYERS, seed=0)
    x0 = make_inputs(NEURONS, BATCH, seed=1)
    oracle = dense_inference(net, x0)
    print(f"sparse DNN: N={NEURONS} L={LAYERS} nnz={net.total_nnz:,} "
          f"batch={BATCH}\n")

    for channel in ("serial", "queue", "object"):
        P = 1 if channel == "serial" else WORKERS
        r = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000)
        ok = np.allclose(r.output, oracle, rtol=1e-5, atol=1e-5)
        print(f"FSD-Inf-{channel.capitalize():<7} P={P}: "
              f"correct={ok}  latency={r.makespan:.2f}s  "
              f"per-sample={r.per_sample_ms(BATCH):.2f}ms  "
              f"cost=${r.cost.total:.6f} "
              f"(comms ${r.cost.communication:.6f})")
        if channel != "serial":
            print(f"    exchange: {r.raw_exchange_bytes/1e6:.2f}MB raw → "
                  f"{r.wire_exchange_bytes/1e6:.2f}MB on the wire (zlib), "
                  f"partition imbalance {r.metrics['imbalance']:.3f}")


if __name__ == "__main__":
    main()
