"""End-to-end driver for the paper's core scenario (deliverable b):
batch inference over a deep sparse DNN on a serverless fleet, with
channel + worker-count selection by the cost model, partitioning ablation,
straggler mitigation, and the TPU-adapted BSR kernel for the layer op.

    PYTHONPATH=src python examples/serverless_sparse_dnn.py
"""

import numpy as np

from repro.core import partitioner as pt
from repro.core.cost_model import recommend_configuration
from repro.core.sparse import bsr_from_dense
from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.simulator import LatencyModel, run_fsi
from repro.kernels.bsr_spmm.ops import sparse_layer_apply

NEURONS, LAYERS, BATCH = 512, 24, 64


def main():
    net = make_sparse_dnn(NEURONS, n_layers=LAYERS, seed=0)
    x0 = make_inputs(NEURONS, BATCH, seed=1)
    oracle = dense_inference(net, x0)

    # 1 — the router picks the config from the cost model (paper §IV-C)
    hgp = pt.partition_network(net.layers, P=8, method="hgp", seed=0)
    vol = pt.measure_comm_volume(net.layers, hgp, bytes_per_row=4 * BATCH)
    channel, P, table = recommend_configuration(
        model_bytes=net.model_bytes,
        per_layer_exchange_bytes=vol.total_bytes_sent / LAYERS,
        n_layers=LAYERS,
    )
    print(f"router: channel={channel} P={P} "
          f"(candidates: {[(k, round(v.total, 5)) for k, v in list(table.items())[:6]]})")

    # 2 — run it (falling back to parallel if serial was chosen, to demo IPC)
    run_channel = channel if channel != "serial" else "queue"
    run_P = P if P > 1 else 8
    r = run_fsi(net, x0, P=run_P, channel=run_channel, memory_mb=4000)
    assert np.allclose(r.output, oracle, rtol=1e-5, atol=1e-5)
    print(f"parallel run: {run_channel} P={run_P} latency={r.makespan:.2f}s "
          f"cost=${r.cost.total:.6f}")

    # 3 — partitioning ablation (Table III)
    for method in ("hgp", "random"):
        res = pt.partition_network(net.layers, P=run_P, method=method, seed=0)
        rep = pt.measure_comm_volume(net.layers, res, bytes_per_row=4 * BATCH)
        print(f"  {method:6s}: exchange volume {rep.total_bytes_sent/1e6:.1f}MB")

    # 4 — straggler mitigation (paper §V-A3 lineage)
    lat = LatencyModel(straggler_prob=0.4, straggler_slowdown=5e4)
    slow = run_fsi(net, x0, P=run_P, channel=run_channel, memory_mb=4000,
                   latency=lat)
    fixed = run_fsi(net, x0, P=run_P, channel=run_channel, memory_mb=4000,
                    latency=lat, reinvoke_stragglers=True)
    print(f"stragglers: makespan {slow.makespan:.2f}s → "
          f"{fixed.makespan:.2f}s with re-invocation")

    # 5 — the TPU adaptation of the layer op: fused BSR kernel ≡ CSR layer
    W = net.layers[0]
    bsr = bsr_from_dense(W.to_dense(), (32, 32))
    y_kernel = np.asarray(sparse_layer_apply(bsr, x0, bias=net.bias))
    from repro.data.graphchallenge import relu_bias_threshold
    y_ref = relu_bias_threshold(W.matmul_dense_fast(x0), net.bias)
    print(f"BSR Pallas kernel ≡ CSR layer: "
          f"{np.allclose(y_kernel, y_ref, rtol=1e-5, atol=1e-5)} "
          f"(block density {bsr.block_density:.2%})")


if __name__ == "__main__":
    main()
