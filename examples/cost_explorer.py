"""Interactive view of the paper's cost model (§IV): sweep model size ×
parallelism and print which channel the recommender picks — reproducing the
design recommendations (Serial → Queue → Object) as workloads grow.

    PYTHONPATH=src python examples/cost_explorer.py
"""

from repro.core.cost_model import recommend_configuration


def main():
    print(f"{'model':>10} {'exchange/layer':>15} {'choice':>12} {'P':>4}")
    for model_gb, exch_mb in [
        (0.03, 0.1), (0.5, 0.5), (2, 1), (8, 2), (8, 60), (30, 200),
    ]:
        ch, p, _ = recommend_configuration(
            model_bytes=int(model_gb * 1e9),
            per_layer_exchange_bytes=exch_mb * 1e6,
            n_layers=120,
            memory_mb_per_worker=4000,
        )
        print(f"{model_gb:>8}GB {exch_mb:>13}MB {ch:>12} {p:>4}")


if __name__ == "__main__":
    main()
