"""Crash-fault recovery under seeded chaos (ISSUE 10).

* baseline bit-identity: with ``faults=None`` every billable counter,
  makespan, and cost line is pinned to the pre-PR golden values — the chaos
  plumbing must be invisible when disarmed;
* zero-fault armed plan: ``FaultPlan()`` changes no main-fabric counter and
  no output bit (the only delta is the checkpoint store's own line);
* crash matrix: worker kills at every (channel × phase) recover to the
  bitwise fault-free output, with re-invocations, redeliveries, and
  checkpoint traffic on auditable ``CostBreakdown.recovery`` /
  ``communication`` lines and a makespan/cost that can only grow;
* checkpoint cadence: C>1 replays forward from the last checkpoint on the
  durable object channel and is honestly *unrecoverable* on the queue
  channel (inputs deleted at receipt commit) — a structured
  ``FleetFailure``, not silence;
* retry budget exactness: ``FleetFailure`` fires iff kills exceed
  ``max_reinvokes`` (the detector is self-tested on both sides);
* warm-pool spare re-invoke: straggler replacements draw from the warm pool
  and bill on ``CostBreakdown.warm_pool``;
* property suite over randomized seeded plans (fallback-compatible
  hypothesis strategies) for parity, cost monotonicity, and budget
  exactness;
* the LM pipeline twin: hop-drain crashes, KV-checkpoint restore, and the
  same zero-fault bit-identity contract.
"""

import hashlib

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.chaos import CRASH_PHASES, FaultPlan, FleetFailure
from repro.faas.simulator import LatencyModel, run_fsi

# ---------------------------------------------------------------------------
# golden baseline, captured at the parent commit (pre-chaos), faults=None:
# run_fsi(make_sparse_dnn(128, n_layers=6, seed=7), make_inputs(128, 8,
# seed=8), P=P, channel=ch, seed=0).  Exact equality — bit-identity is the
# acceptance criterion, not closeness.
# ---------------------------------------------------------------------------

OUTPUT_SHA = "fd7dacb091aceae5"
GOLDEN = {
    ("queue", 3): dict(
        publish_units=20, bytes_sns_to_sqs=4897, sqs_api_calls=44,
        messages=26.0, empty_polls=0.0,
        phased=0.980115153134654, overlap=0.8781153885790979,
        cost_total=6.0971774890083844e-05,
        compute=3.296131309177357e-05, communication=2.801046179831028e-05,
    ),
    ("queue", 4): dict(
        publish_units=3, bytes_sns_to_sqs=3072, sqs_api_calls=2,
        messages=3.0, empty_polls=0.0,
        phased=0.7556131767649985, overlap=0.7456131767649986,
        cost_total=2.037115048958127e-05,
        compute=1.7813658424151583e-05, communication=2.5574920654296875e-06,
    ),
    ("object", 3): dict(
        s3_puts=26, s3_gets=26, s3_lists=31, nul_files=0.0,
        phased=1.2850421164063104, overlap=1.0950220610729764,
        cost_total=0.0003490557230530466,
        compute=5.3655723053046595e-05, communication=0.0002954,
    ),
    ("object", 4): dict(
        s3_puts=3, s3_gets=3, s3_lists=1, nul_files=0.0,
        phased=0.7886074878761096, overlap=0.7736074878761098,
        cost_total=4.265931386359603e-05,
        compute=2.145931386359603e-05, communication=2.12e-05,
    ),
}

COUNTERS = ("publish_units", "bytes_sns_to_sqs", "sqs_api_calls",
            "s3_puts", "s3_gets", "s3_lists")


def _sha(a: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(a).tobytes()).hexdigest()[:16]


@pytest.fixture(scope="module")
def case():
    net = make_sparse_dnn(128, n_layers=6, seed=7)
    x0 = make_inputs(128, 8, seed=8)
    return net, x0, dense_inference(net, x0)


@pytest.fixture(scope="module")
def oracles(case):
    """Fault-free reference runs, keyed (channel, P)."""
    net, x0, _ = case
    runs = {}

    def get(channel, P=3):
        if (channel, P) not in runs:
            runs[(channel, P)] = run_fsi(net, x0, P=P, channel=channel,
                                         seed=0)
        return runs[(channel, P)]

    return get


def _counters(r):
    return {f: getattr(r.stats, f) for f in COUNTERS}


class TestBaselineBitIdentity:
    """faults=None: every billable count, both makespans, and every cost
    line stay bit-identical to the pre-PR baseline."""

    @pytest.mark.parametrize("channel,P", list(GOLDEN))
    def test_pinned_golden_values(self, case, channel, P):
        net, x0, _ = case
        r = run_fsi(net, x0, P=P, channel=channel, seed=0)
        g = GOLDEN[(channel, P)]
        assert _sha(r.output) == OUTPUT_SHA
        assert r.metrics["phased_makespan_s"] == g["phased"]
        assert r.metrics["overlap_makespan_s"] == g["overlap"]
        assert r.cost.total == g["cost_total"]
        assert r.cost.compute == g["compute"]
        assert r.cost.communication == g["communication"]
        assert r.cost.recovery == 0.0
        for f in g:
            if hasattr(r.stats, f):
                assert getattr(r.stats, f) == g[f], f
            elif f in r.metrics:
                assert r.metrics[f] == g[f], f


class TestZeroFaultArmedPlan:
    """An armed-but-empty FaultPlan must not move a single main-fabric
    counter or output bit; arming only costs the checkpoint store's own
    (auditable) recovery line."""

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_counters_and_output_identical(self, case, oracles, channel):
        net, x0, _ = case
        base = oracles(channel)
        z = run_fsi(net, x0, P=3, channel=channel, seed=0,
                    faults=FaultPlan())
        assert _counters(z) == _counters(base)
        assert z.raw_exchange_bytes == base.raw_exchange_bytes
        assert z.wire_exchange_bytes == base.wire_exchange_bytes
        np.testing.assert_array_equal(z.output, base.output)
        assert z.cost.communication == base.cost.communication
        assert z.metrics["n_reinvokes"] == 0.0
        assert z.metrics["checkpoint_puts"] > 0      # C=1: every layer
        assert z.cost.recovery > 0.0                 # the checkpoint tariffs
        assert z.metrics["recovery_usd"] == z.cost.recovery


class TestCrashRecovery:
    @pytest.mark.parametrize("phase", CRASH_PHASES)
    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_single_kill_recovers_bitwise(self, case, oracles, channel,
                                          phase):
        net, x0, dense = case
        base = oracles(channel)
        r = run_fsi(net, x0, P=3, channel=channel, seed=0,
                    faults=FaultPlan(kills=((1, 2, phase),)))
        np.testing.assert_array_equal(r.output, base.output)
        np.testing.assert_allclose(r.output, dense, rtol=1e-4, atol=1e-4)
        assert r.metrics["n_reinvokes"] == 1.0
        assert r.cost.recovery > 0.0
        assert r.cost.total > base.cost.total        # recovery is never free
        assert r.makespan > base.makespan
        if channel == "queue" and phase == "drain":
            # the drained-but-uncommitted messages came back via the
            # visibility timeout, re-billed as deliveries
            assert r.metrics["redeliveries"] >= 1.0

    def test_last_layer_drain_crash(self, case, oracles):
        """Crash after the final layer's drain: the redelivered duplicates
        must be swept before the output reduce, not decoded as reduce
        payloads."""
        net, x0, _ = case
        base = oracles("queue")
        r = run_fsi(net, x0, P=3, channel="queue", seed=0,
                    faults=FaultPlan(kills=((2, 5, "drain"),)))
        np.testing.assert_array_equal(r.output, base.output)
        assert r.metrics["redeliveries"] >= 1.0

    def test_runtime_limit_reinvokes(self, case, oracles):
        net, x0, _ = case
        base = oracles("object")
        r = run_fsi(net, x0, P=3, channel="object", seed=0,
                    faults=FaultPlan(runtime_limit_s=0.35, max_reinvokes=8))
        np.testing.assert_array_equal(r.output, base.output)
        assert r.metrics["n_reinvokes"] >= 1.0


class TestCheckpointCadence:
    def test_object_replays_from_last_checkpoint(self, case, oracles):
        """C=2: a crash one layer past the checkpoint replays that layer
        from the durable object inputs, bitwise."""
        net, x0, _ = case
        base = oracles("object")
        r = run_fsi(net, x0, P=3, channel="object", seed=0,
                    faults=FaultPlan(kills=((1, 3, "compute"),),
                                     checkpoint_every=2))
        np.testing.assert_array_equal(r.output, base.output)
        # C=2 writes half the checkpoints of C=1 (3 ckpt layers x 3 workers)
        assert r.metrics["checkpoint_puts"] == 9.0

    def test_queue_replay_is_honestly_unrecoverable(self, case):
        """C=2 on the queue channel: the replayed layer's inputs were
        deleted at receipt commit — a structured FleetFailure with a
        diagnosable reason, never a silent wrong answer."""
        net, x0, _ = case
        with pytest.raises(FleetFailure) as ei:
            run_fsi(net, x0, P=3, channel="queue", seed=0,
                    faults=FaultPlan(kills=((1, 3, "compute"),),
                                     checkpoint_every=2))
        diag = ei.value.diagnostics[1]
        assert "queue" in diag["reason"]
        assert "checkpoint_every" in diag["reason"]


class TestRetryBudgetExactness:
    KILLS = tuple((0, k, "compute") for k in range(4))

    def test_budget_exceeded_raises_with_diagnostics(self, case):
        net, x0, _ = case
        with pytest.raises(FleetFailure) as ei:
            run_fsi(net, x0, P=3, channel="object", seed=0,
                    faults=FaultPlan(kills=self.KILLS, max_reinvokes=3))
        diag = ei.value.diagnostics[0]
        assert diag["reinvokes"] == 4
        assert diag["phase"] == "compute"

    def test_budget_exactly_sufficient_recovers(self, case, oracles):
        net, x0, _ = case
        base = oracles("object")
        r = run_fsi(net, x0, P=3, channel="object", seed=0,
                    faults=FaultPlan(kills=self.KILLS, max_reinvokes=4))
        np.testing.assert_array_equal(r.output, base.output)
        assert r.metrics["n_reinvokes"] == 4.0


class TestInjectedSlowdowns:
    def test_throttle_and_publish_delay_preserve_output(self, case, oracles):
        net, x0, _ = case
        base = oracles("queue")
        r = run_fsi(net, x0, P=3, channel="queue", seed=0,
                    faults=FaultPlan(throttle_prob=0.2,
                                     publish_delay_prob=0.3))
        np.testing.assert_array_equal(r.output, base.output)
        assert r.metrics["throttle_retries"] > 0
        assert r.metrics["n_reinvokes"] == 0.0
        assert r.makespan > base.makespan            # retries cost time
        # payload-derived counters cannot move; delayed deliveries may add
        # honestly-billed extra polls, never remove any
        for f in ("publish_units", "bytes_sns_to_sqs"):
            assert getattr(r.stats, f) == getattr(base.stats, f), f
        assert r.stats.sqs_api_calls >= base.stats.sqs_api_calls

    def test_throttle_budget_exhaustion(self, case):
        net, x0, _ = case
        with pytest.raises(FleetFailure):
            run_fsi(net, x0, P=3, channel="queue", seed=0,
                    faults=FaultPlan(throttle_prob=0.95,
                                     throttle_max_retries=3))


class TestWarmPoolSpareReinvoke:
    """Satellite: with ``warm_pool=True`` a straggler's replacement is drawn
    from the warm pool — billed as pool provisioning on
    ``CostBreakdown.warm_pool``, not as a cold start on the request path."""

    def _run(self, case, warm):
        net, x0, _ = case
        # prob 0.5: a mix of slowed and healthy workers, so the median-based
        # detector actually flags someone (all-slowed fleets have no median
        # to stand out against)
        lat = LatencyModel(straggler_prob=0.5, straggler_slowdown=5e4)
        return run_fsi(net, x0, P=4, channel="queue", memory_mb=3000,
                       seed=0, latency=lat, reinvoke_stragglers=True,
                       straggler_timeout=2.0, warm_pool=warm)

    def test_spares_bill_on_warm_pool_line(self, case):
        net, x0, dense = case
        warm = self._run(case, warm=True)
        assert warm.metrics["warm_pool_spares"] > 0
        np.testing.assert_allclose(warm.output, dense, rtol=1e-4, atol=1e-4)
        # the spare's provisioning (cold start + weight reload) is on the
        # pool line: strictly more provisioned seconds than a no-straggler
        # warm run of the same shape
        net_, x0_, _ = case
        quiet = run_fsi(net_, x0_, P=4, channel="queue", memory_mb=3000,
                        seed=0, warm_pool=True)
        assert warm.metrics["warm_pool_provision_s"] > \
            quiet.metrics["warm_pool_provision_s"]
        assert warm.cost.warm_pool > quiet.cost.warm_pool

    def test_cold_reinvoke_unchanged_without_pool(self, case):
        cold = self._run(case, warm=False)
        assert "warm_pool_spares" not in cold.metrics
        assert cold.cost.warm_pool == 0.0


class TestFaultPlanValidation:
    def test_bad_phase_rejected(self):
        with pytest.raises(ValueError):
            FaultPlan(kills=((0, 0, "sleep"),))

    def test_bad_cadence_rejected(self):
        with pytest.raises(ValueError):
            FaultPlan(checkpoint_every=0)

    def test_event_keyed_draws_are_order_independent(self):
        a = FaultPlan(seed=3, crash_prob=0.5).activate()
        b = FaultPlan(seed=3, crash_prob=0.5).activate()
        sites = [(w, k, p) for w in range(3) for k in range(4)
                 for p in CRASH_PHASES]
        fwd = {s: a.peek_crash(*s) for s in sites}
        rev = {s: b.peek_crash(*s) for s in reversed(sites)}
        assert fwd == rev
        assert any(fwd.values()) and not all(fwd.values())


class TestChaosProperties:
    """Randomized seeded FaultPlans (strategies restricted to the
    hypothesis-fallback subset): output parity, billed-cost monotonicity,
    and budget exactness must hold for *any* plan, not just the pinned
    cases."""

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 10**6), worker=st.integers(0, 2),
           layer=st.integers(0, 5), phase=st.sampled_from(CRASH_PHASES),
           channel=st.sampled_from(["queue", "object"]))
    def test_single_kill_parity_and_cost_monotonicity(
            self, case, oracles, seed, worker, layer, phase, channel):
        net, x0, _ = case
        base = oracles(channel)
        r = run_fsi(net, x0, P=3, channel=channel, seed=0,
                    faults=FaultPlan(seed=seed,
                                     kills=((worker, layer, phase),)))
        np.testing.assert_array_equal(r.output, base.output)
        assert r.cost.total > base.cost.total
        assert r.cost.recovery > 0.0

    @settings(max_examples=6, deadline=None)
    @given(n_kills=st.integers(0, 5), budget=st.integers(0, 4),
           P=st.sampled_from([3, 4]))
    def test_fleet_failure_iff_budget_exceeded(self, case, oracles, n_kills,
                                               budget, P):
        net, x0, _ = case
        plan = FaultPlan(kills=tuple((0, k, "compute")
                                     for k in range(n_kills)),
                         max_reinvokes=budget)
        if n_kills > budget:
            with pytest.raises(FleetFailure) as ei:
                run_fsi(net, x0, P=P, channel="object", seed=0, faults=plan)
            assert ei.value.diagnostics[0]["reinvokes"] == budget + 1
        else:
            r = run_fsi(net, x0, P=P, channel="object", seed=0, faults=plan)
            np.testing.assert_array_equal(r.output, oracles("object", P).output)
            assert r.metrics["n_reinvokes"] == float(n_kills)

    @settings(max_examples=4, deadline=None)
    @given(seed=st.integers(0, 10**6),
           throttle=st.floats(0.05, 0.3), delay=st.floats(0.0, 0.4))
    def test_slowdowns_never_change_bits_or_counts(self, case, oracles, seed,
                                                   throttle, delay):
        net, x0, _ = case
        base = oracles("queue")
        r = run_fsi(net, x0, P=3, channel="queue", seed=0,
                    faults=FaultPlan(seed=seed, throttle_prob=throttle,
                                     publish_delay_prob=delay,
                                     throttle_max_retries=64))
        np.testing.assert_array_equal(r.output, base.output)
        # payload-derived counters cannot move; poll/delete call counts may
        # drift either way (delays batch more messages into fewer polls, or
        # force extra empty windows) — always billed, never hidden
        assert r.stats.publish_units == base.stats.publish_units
        assert r.stats.bytes_sns_to_sqs == base.stats.bytes_sns_to_sqs
        assert r.metrics["messages"] == base.metrics["messages"]
        assert r.makespan >= base.makespan


# ---------------------------------------------------------------------------
# the LM pipeline twin
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def lm_case():
    pytest.importorskip("jax")
    from repro.configs.base import get_config
    from repro.faas.lm_pipeline import build_stage_executors
    from repro.serving.engine import ServingEngine

    cfg = get_config("internlm2-1.8b").reduced()
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, cfg.vocab_size, (2, 10), dtype=np.int32)
    engine = ServingEngine(cfg, seed=0)
    executors = build_stage_executors(cfg, engine.params, 2)
    return cfg, prompts, engine, executors


def _lm_run(lm_case, channel, **kw):
    from repro.faas.lm_pipeline import run_lm_pipeline

    cfg, prompts, engine, executors = lm_case
    return run_lm_pipeline(cfg, prompts, engine.params, max_new_tokens=3,
                           P=2, channel=channel, executors=executors, **kw)


class TestLmPipelineChaos:
    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_zero_fault_plan_is_invisible(self, lm_case, channel):
        base = _lm_run(lm_case, channel)
        z = _lm_run(lm_case, channel, faults=FaultPlan())
        for f in COUNTERS:
            assert getattr(z.stats, f) == getattr(base.stats, f), f
        np.testing.assert_array_equal(z.tokens, base.tokens)
        np.testing.assert_array_equal(z.logits, base.logits)
        assert z.metrics["n_reinvokes"] == 0.0
        assert z.metrics["checkpoint_puts"] > 0

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_hop_drain_crash_recovers(self, lm_case, channel):
        """Stage 1 dies after draining the prefill hop, before its receipt
        deletes commit: the hop redelivers (queue) / re-GETs (object) and
        decode still emits the fault-free tokens."""
        base = _lm_run(lm_case, channel)
        r = _lm_run(lm_case, channel,
                    faults=FaultPlan(kills=((1, 0, "drain"),)))
        np.testing.assert_array_equal(r.tokens, base.tokens)
        np.testing.assert_array_equal(r.logits, base.logits)
        assert r.metrics["n_reinvokes"] == 1.0
        assert r.cost.recovery > 0.0
        assert r.cost.total > base.cost.total
        if channel == "queue":
            assert r.metrics["redeliveries"] >= 1.0

    def test_uncovered_queue_hop_is_unrecoverable(self, lm_case):
        with pytest.raises(FleetFailure) as ei:
            _lm_run(lm_case, "queue",
                    faults=FaultPlan(kills=((1, 6, "drain"),),
                                     checkpoint_every=2))
        assert "checkpoint_every" in ei.value.diagnostics[1]["reason"]

    def test_object_replays_uncovered_hop(self, lm_case):
        base = _lm_run(lm_case, "object")
        r = _lm_run(lm_case, "object",
                    faults=FaultPlan(kills=((1, 6, "drain"),),
                                     checkpoint_every=2))
        np.testing.assert_array_equal(r.tokens, base.tokens)
        assert r.metrics["n_reinvokes"] == 1.0
