"""Fault tolerance: checkpoint/restart determinism, elastic resharding,
async save integrity, gradient compression convergence, and FaaS channel
failure paths (duplicate delivery / out-of-order chunk arrival)."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.training import checkpoint as ckpt
from repro.training.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp, steps=6, ckpt_every=2, compress=False, seed=0,
                arch="llama3.2-1b", async_ckpt=False, stop_after=0):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=tmp, compress_grads=compress,
                         async_ckpt=async_ckpt, stop_after=stop_after)
    return Trainer(cfg, shape, tcfg, seed=seed)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.int32)}}
        ckpt.save(str(tmp_path), 3, tree)
        restored, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])

    def test_crc_detects_corruption(self, tmp_path):
        tree = {"w": jnp.ones((8, 8))}
        path = ckpt.save(str(tmp_path), 1, tree)
        # corrupt the single leaf file
        for name in os.listdir(path):
            if name.endswith(".npy"):
                arr = np.load(os.path.join(path, name))
                arr[0] += 1
                np.save(os.path.join(path, name), arr)
        with pytest.raises(IOError):
            ckpt.restore(str(tmp_path), tree)

    def test_keeps_latest(self, tmp_path):
        tree = {"w": jnp.ones(3)}
        for s in (1, 2, 3):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_steps(str(tmp_path)) == [1, 2, 3]
        _, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3


class TestRestartDeterminism:
    def test_resume_bitwise_identical(self, tmp_path):
        """Uninterrupted run ≡ crash-at-step-4 + restart (same final params)."""
        full = _mk_trainer(str(tmp_path / "full"), steps=6)
        hist_full = full.fit()

        crash_dir = str(tmp_path / "crash")
        # crash mid-run: same 6-step schedule, killed after step 4
        part = _mk_trainer(crash_dir, steps=6, ckpt_every=2, stop_after=4)
        part.fit()
        resumed = _mk_trainer(crash_dir, steps=6, ckpt_every=2)
        hist_res = resumed.fit(resume=True)

        flat_a = jax.tree.leaves(full.params)
        flat_b = jax.tree.leaves(resumed.params)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # loss history continues where it left off
        assert hist_res["step"][0] == 4
        np.testing.assert_allclose(hist_full["loss"][4:], hist_res["loss"],
                                   rtol=1e-6)

    def test_pipeline_step_keyed(self):
        from repro.data.pipeline import PipelineSpec

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", 16, 4, "train")
        p1 = PipelineSpec(cfg, shape, seed=0)
        p2 = PipelineSpec(cfg, shape, seed=0)
        np.testing.assert_array_equal(p1.batch(7)["tokens"], p2.batch(7)["tokens"])
        assert not np.array_equal(p1.batch(7)["tokens"], p1.batch(8)["tokens"])
        # host-sharded slice == slice of the global batch
        full = p1.batch(3)["tokens"]
        np.testing.assert_array_equal(p1.batch(3, lo=1, hi=3)["tokens"], full[1:3])


class TestElasticResharding:
    def test_restore_onto_multi_device_mesh(self, tmp_path):
        """Checkpoint written on 1 device restores sharded onto 8 devices
        (subprocess with a forced 8-device CPU topology)."""
        t = _mk_trainer(str(tmp_path), steps=2, ckpt_every=2)
        t.fit()
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from repro.configs import ShapeConfig, get_config
            from repro.models.registry import get_model
            from repro.training import checkpoint as ckpt
            from repro.training.optimizer import get_optimizer
            from repro.distributed.sharding import param_pspecs, to_named
            from repro.launch.mesh import make_mesh, MeshAxes

            cfg = get_config("llama3.2-1b").reduced()
            model = get_model(cfg)
            params = model.init(jax.random.key(0))
            opt = get_optimizer(cfg)
            opt_state = opt.init(params)
            mesh = make_mesh((2, 4), ("data", "model"))
            ax = MeshAxes(mesh)
            pspecs = param_pspecs(cfg, jax.eval_shape(lambda: params), ax)
            sh = {{"params": to_named(mesh, pspecs), "opt": None}}
            state, step = ckpt.restore(
                r"{tmp_path}", {{"params": params, "opt": opt_state}},
                shardings=None)
            # reshard params explicitly onto the 8-device mesh
            resharded = jax.tree.map(
                lambda a, s: jax.device_put(np.asarray(a), s),
                state["params"], to_named(mesh, pspecs),
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
            n_sharded = sum(
                len(a.sharding.device_set) > 1 for a in jax.tree.leaves(resharded))
            assert n_sharded > 0, "nothing was sharded"
            print("ELASTIC_OK", step, n_sharded)
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd="/root/repo",
                             timeout=300)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


class TestAsyncCheckpoint:
    def test_async_equals_sync(self, tmp_path):
        t_sync = _mk_trainer(str(tmp_path / "s"), steps=4, ckpt_every=2)
        t_sync.fit()
        t_async = _mk_trainer(str(tmp_path / "a"), steps=4, ckpt_every=2,
                              async_ckpt=True)
        t_async.fit()
        a, _ = ckpt.restore(str(tmp_path / "s"),
                            {"params": t_sync.params, "opt": t_sync.opt_state})
        b, _ = ckpt.restore(str(tmp_path / "a"),
                            {"params": t_async.params, "opt": t_async.opt_state})
        for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestGradientCompression:
    def test_wire_savings_and_convergence(self, tmp_path):
        from repro.distributed.compression import Int8Compressor

        base = _mk_trainer(str(tmp_path / "fp"), steps=8, ckpt_every=100)
        hist_fp = base.fit()
        comp = _mk_trainer(str(tmp_path / "q8"), steps=8, ckpt_every=100,
                           compress=True)
        hist_q8 = comp.fit()
        # int8 path converges: loss drops and stays within 10% of fp32 path
        assert hist_q8["loss"][-1] < hist_q8["loss"][0]
        assert abs(hist_q8["loss"][-1] - hist_fp["loss"][-1]) < 0.1 * hist_fp["loss"][-1] + 0.35
        fp32_b, int8_b = Int8Compressor.wire_bytes(base.params)
        assert int8_b < 0.27 * fp32_b

    def test_quantize_roundtrip_error_feedback(self):
        from repro.distributed.compression import (
            Int8Compressor, dequantize_int8, quantize_int8)

        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        q, s = quantize_int8(g)
        err = g - dequantize_int8(q, s)
        assert float(jnp.abs(err).max()) <= float(s) * 0.5 + 1e-6
        # error feedback: two-step quantized sum ≈ true sum
        comp = Int8Compressor()
        e = comp.init({"g": g})
        total = jnp.zeros_like(g)
        for _ in range(4):
            quant, e = comp.compress({"g": g}, e)
            total = total + comp.decompress(quant)["g"]
        np.testing.assert_allclose(total / 4, g, atol=float(s))


class TestCompressedPsum:
    def test_matches_fp32_psum_subprocess(self):
        """int8 shard_map psum ≈ fp32 psum on an 8-device mesh."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import compressed_psum
            from repro.distributed.sharding import shard_map_compat
            from repro.launch.mesh import make_mesh

            mesh = make_mesh((8,), ("data",))
            x = jax.random.normal(jax.random.key(0), (8, 64, 64), jnp.float32)

            def f(x_loc):
                return compressed_psum(x_loc[0], "data")

            got = jax.jit(shard_map_compat(
                f, mesh=mesh, in_specs=P("data"), out_specs=P()))(x)
            want = x.sum(axis=0)
            scale = float(jnp.max(jnp.abs(x))) / 127.0
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=8 * scale)
            print("PSUM_OK")
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd="/root/repo",
                             timeout=300)
        assert "PSUM_OK" in out.stdout, out.stderr[-2000:]


# ---------------------------------------------------------------------------
# FaaS channel failure paths: duplicate delivery + out-of-order arrival
# ---------------------------------------------------------------------------

from repro.core.cost_model import AWS_PRICING
from repro.core.fsi import (
    FleetRecvBuffers,
    finish_layer,
    fsi_object_recv,
    fsi_object_recv_fleet,
    fsi_object_send_and_local,
    fsi_queue_recv,
    fsi_queue_recv_fleet,
    fsi_queue_send_and_local,
    prepare_worker_artifacts,
)
from repro.core.partitioner import partition_network
from repro.core.send_recv import build_comm_plans
from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.object_service import ObjectFabric
from repro.faas.queue_service import QueueFabric
from repro.faas.simulator import LatencyModel, run_fsi
from repro.faas.worker import ComputeModel, EventLedger, WorkerState

# tiny cap forces multi-chunk sends so chunk ordering/duplication matters
SMALL_PRICING = dataclasses.replace(AWS_PRICING, max_publish_payload=1600)


class DuplicatingQueueFabric(QueueFabric):
    """At-least-once SQS: every published message is delivered twice, the
    duplicate arriving later (visibility-timeout style redelivery)."""

    def publish_batch(self, topic, entries, at_time, *, ledger_at=None):
        done = super().publish_batch(topic, entries, at_time,
                                     ledger_at=ledger_at)
        dup_led = None if ledger_at is None else ledger_at + 0.5
        return super().publish_batch(topic, entries, done + 0.5,
                                     ledger_at=dup_led)


class ReorderingQueueFabric(QueueFabric):
    """Deliveries within a poll window come back in reverse order."""

    def poll(self, worker, at_time, long_poll=True, max_messages=10):
        now, msgs = super().poll(worker, at_time, long_poll, max_messages)
        return now, list(reversed(msgs))


class DuplicatingReorderingQueueFabric(DuplicatingQueueFabric,
                                       ReorderingQueueFabric):
    pass


class DuplicatingObjectFabric(ObjectFabric):
    """Every object is PUT twice (idempotent overwrite of the same key) and
    LISTed twice (eventual-consistency style duplicate listing)."""

    def put_obj(self, layer, src, target, blob, at_time, *, ledger_at=None):
        done = super().put_obj(layer, src, target, blob, at_time,
                               ledger_at=ledger_at)
        dup_led = None if ledger_at is None else ledger_at + 0.5
        return super().put_obj(layer, src, target, blob, done,
                               ledger_at=dup_led)

    def list_files(self, layer, worker, at_time):
        now, handles = super().list_files(layer, worker, at_time)
        return now, handles + handles


class ReorderingObjectFabric(ObjectFabric):
    """LIST returns handles in reverse key order and multipart objects carry
    their chunks in reverse arrival order."""

    def put_multipart(self, layer, src, target, blobs, at_time, *,
                      ledger_at=None):
        return super().put_multipart(layer, src, target,
                                     list(reversed(blobs)), at_time,
                                     ledger_at=ledger_at)

    def list_files(self, layer, worker, at_time):
        now, handles = super().list_files(layer, worker, at_time)
        return now, list(reversed(handles))


QUEUE_FAULTS = {
    "duplicate": DuplicatingQueueFabric,
    "out-of-order": ReorderingQueueFabric,
    "duplicate+out-of-order": DuplicatingReorderingQueueFabric,
}
OBJECT_FAULTS = {
    "duplicate": DuplicatingObjectFabric,
    "out-of-order": ReorderingObjectFabric,
}


class TestChannelFailurePaths:
    """Payload reassembly must be idempotent: the FSI recv loops key every
    write by global row id and every completion by (src, seq), so redelivered
    or reordered chunks change nothing but billing noise.  Both drain paths
    — the per-worker loops and the fleet drain's one vectorized scatter —
    run the same fault fabrics (they share ``_queue_drain_one`` /
    ``_object_drain_one``, and this parametrization keeps it that way)."""

    P = 3

    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(64, n_layers=2, seed=5)
        x0 = make_inputs(64, 12, seed=6)
        partition = partition_network(net.layers, self.P, method="hgp", seed=0)
        plans = build_comm_plans(net.layers, partition)
        artifacts = prepare_worker_artifacts(net.layers, partition, plans)
        return net, x0, artifacts, dense_inference(net, x0)

    def _run(self, case, channel, fabric, drain="perworker", ledger=False,
             eager=False):
        net, x0, artifacts, _ = case
        compute = ComputeModel()
        workers = [WorkerState(rank=m, memory_mb=2000,
                               ledger=(EventLedger(eager_poll=eager)
                                       if ledger else None))
                   for m in range(self.P)]
        self._last_workers = workers
        panels = [x0[artifacts[m].x0_rows].astype(np.float32)
                  for m in range(self.P)]
        for k in range(net.n_layers):
            arts = [artifacts[m].layers[k] for m in range(self.P)]
            bufs = []
            for m in range(self.P):
                if channel == "queue":
                    bufs.append(fsi_queue_send_and_local(
                        arts[m], panels[m], workers[m], fabric, compute))
                else:
                    bufs.append(fsi_object_send_and_local(
                        arts[m], panels[m], workers[m], fabric, compute,
                        max_object_part=1600))
            if drain == "fleet":
                fb = FleetRecvBuffers.allocate(arts, panels[0].shape[1])
                for m in range(self.P):
                    fb.views[m][:] = bufs[m]
                if channel == "queue":
                    bufs = fsi_queue_recv_fleet(arts, fb, workers, fabric,
                                                compute)
                else:
                    bufs = fsi_object_recv_fleet(arts, fb, workers, fabric,
                                                 compute)
                for m in range(self.P):
                    panels[m] = finish_layer(arts[m], bufs[m], workers[m],
                                             compute, net.bias)
            else:
                for m in range(self.P):
                    if channel == "queue":
                        bufs[m] = fsi_queue_recv(arts[m], bufs[m], workers[m],
                                                 fabric, compute)
                    else:
                        bufs[m] = fsi_object_recv(arts[m], bufs[m], workers[m],
                                                  fabric, compute)
                    panels[m] = finish_layer(arts[m], bufs[m], workers[m],
                                             compute, net.bias)
        order = np.argsort(np.concatenate(
            [artifacts[m].layers[-1].out_rows for m in range(self.P)]))
        return np.concatenate(panels)[order]

    @pytest.mark.parametrize("drain", ["perworker", "fleet"])
    @pytest.mark.parametrize("fault", sorted(QUEUE_FAULTS))
    def test_queue_reassembly_idempotent(self, case, fault, drain):
        fabric = QUEUE_FAULTS[fault](self.P, pricing=SMALL_PRICING)
        out = self._run(case, "queue", fabric, drain=drain)
        np.testing.assert_allclose(out, case[3], rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("drain", ["perworker", "fleet"])
    @pytest.mark.parametrize("fault", sorted(OBJECT_FAULTS))
    def test_object_reassembly_idempotent(self, case, fault, drain):
        fabric = OBJECT_FAULTS[fault](self.P)
        out = self._run(case, "object", fabric, drain=drain)
        np.testing.assert_allclose(out, case[3], rtol=1e-4, atol=1e-4)

    def test_queue_faulty_fabric_drains_identical_across_paths(self, case):
        """Same duplicate+out-of-order fabric state, drained per-worker vs
        fleet: identical buffers AND identical billing counters — the
        (src, seq) dedupe lives in one shared loop."""
        results = {}
        for mode in ("perworker", "fleet"):
            fabric = DuplicatingReorderingQueueFabric(
                self.P, pricing=SMALL_PRICING)
            out = self._run(case, "queue", fabric, drain=mode)
            results[mode] = (out, dict(vars(fabric.metrics)))
        np.testing.assert_array_equal(results["perworker"][0],
                                      results["fleet"][0])
        assert results["perworker"][1] == results["fleet"][1]

    def test_queue_duplicate_of_first_chunk_does_not_retire_source(self, case):
        """Deterministic repro of the premature-retirement hazard: the first
        chunk of a two-chunk send is delivered twice BEFORE the second chunk
        arrives.  Naive per-source counting would hit ``total`` on the
        duplicate and drop the second chunk's rows; (src, seq) dedupe in
        ``fsi_queue_recv`` must keep the source pending."""
        from repro.faas.payload import pack_rows

        net, x0, artifacts, _ = case
        compute = ComputeModel()
        # find a (worker, layer, src) pair with a real transfer
        m, k, src = next(
            (m, k, src)
            for m in range(self.P)
            for k in range(net.n_layers)
            for src in artifacts[m].layers[k].recv_expect
        )
        art = artifacts[m].layers[k]
        src_art = artifacts[src].layers[k]
        rows = src_art.send_global[m]
        rng = np.random.default_rng(0)
        vals = rng.standard_normal((len(rows), 4)).astype(np.float32) + 1.0
        # force ≥ 2 chunks, then deliver [c0, c0, c1] in that order
        cap = max(128, (4 + 16) * (len(rows) // 2 + 1))
        chunks = pack_rows(k, src, rows, vals, cap)
        while len(chunks) < 2 and cap > 64:
            cap //= 2
            chunks = pack_rows(k, src, rows, vals, cap)
        assert len(chunks) >= 2, "case too small to split"
        fabric = QueueFabric(self.P, pricing=SMALL_PRICING)
        fabric.publish_batch(0, [(m, chunks[0])], at_time=0.0)
        fabric.publish_batch(0, [(m, chunks[0])], at_time=1.0)
        for i, c in enumerate(chunks[1:], start=2):
            fabric.publish_batch(0, [(m, c)], at_time=float(i))
        # a recv map reduced to this single source
        art_single = dataclasses.replace(
            art,
            recv_expect={src: art.recv_expect[src]},
            backend_states={},
        )
        worker = WorkerState(rank=m, memory_mb=2000)
        x_buf = np.zeros((len(art.needed_rows), 4), np.float32)
        x_buf = fsi_queue_recv(art_single, x_buf, worker, fabric, compute)
        pos = np.searchsorted(art.needed_rows, rows)
        np.testing.assert_array_equal(x_buf[pos], vals)

    # ---- overlapped-ledger coverage (drains interleaved with compute) ------

    @pytest.mark.parametrize("drain", ["perworker", "fleet"])
    @pytest.mark.parametrize("fault", sorted(QUEUE_FAULTS))
    def test_queue_faults_under_overlap_ledger(self, case, fault, drain):
        """Same fault fabrics with event-ledger workers: the (src, seq)
        dedupe must stay exact when the ledger re-times drains against
        in-flight sends, and the ledger timelines must come out sane (a
        redelivered stale chunk may only push the channel timeline forward,
        never unwind it)."""
        fabric = QUEUE_FAULTS[fault](self.P, pricing=SMALL_PRICING)
        out = self._run(case, "queue", fabric, drain=drain, ledger=True)
        np.testing.assert_allclose(out, case[3], rtol=1e-4, atol=1e-4)
        for w in self._last_workers:
            assert w.ledger.t_compute >= 0.0 and w.ledger.t_channel >= 0.0
            # overlapping can only remove serialization, never add work
            assert w.ledger.done <= w.abs_time + 1e-9

    @pytest.mark.parametrize("fault", sorted(OBJECT_FAULTS))
    def test_object_faults_under_overlap_ledger(self, case, fault):
        fabric = OBJECT_FAULTS[fault](self.P)
        out = self._run(case, "object", fabric, drain="fleet", ledger=True)
        np.testing.assert_allclose(out, case[3], rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("drain", ["perworker", "fleet"])
    @pytest.mark.parametrize("fault", sorted(QUEUE_FAULTS))
    def test_queue_faults_under_eager_polling(self, case, fault, drain):
        """PR 9: eager polling re-times ledger receives against the faulty
        fabrics' redelivered/reordered stamps.  Outputs must stay exact, and
        every fabric counter must be bit-identical to the lazy-ledger run —
        eager is a ledger-only re-timing even when deliveries misbehave."""
        results = {}
        for eager in (False, True):
            fabric = QUEUE_FAULTS[fault](self.P, pricing=SMALL_PRICING)
            out = self._run(case, "queue", fabric, drain=drain, ledger=True,
                            eager=eager)
            results[eager] = (out, dict(vars(fabric.metrics)),
                              [w.ledger.done for w in self._last_workers])
        np.testing.assert_allclose(results[True][0], case[3],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(results[True][0], results[False][0])
        assert results[True][1] == results[False][1]   # counters identical
        # the eager reader can only see a chunk sooner, never later
        for e, l in zip(results[True][2], results[False][2]):
            assert e <= l + 1e-9

    @pytest.mark.parametrize("fault", sorted(OBJECT_FAULTS))
    def test_object_faults_under_eager_polling(self, case, fault):
        results = {}
        for eager in (False, True):
            fabric = OBJECT_FAULTS[fault](self.P)
            out = self._run(case, "object", fabric, drain="fleet",
                            ledger=True, eager=eager)
            results[eager] = (out, dict(vars(fabric.metrics)))
        np.testing.assert_allclose(results[True][0], case[3],
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(results[True][0], results[False][0])
        assert results[True][1] == results[False][1]   # counters identical

    def test_queue_fault_billing_unchanged_by_ledger(self, case):
        """Attaching ledgers must not change a single fabric counter — the
        ledger is pure arithmetic riding along the phased schedule."""
        results = {}
        for with_ledger in (False, True):
            fabric = DuplicatingReorderingQueueFabric(
                self.P, pricing=SMALL_PRICING)
            out = self._run(case, "queue", fabric, drain="fleet",
                            ledger=with_ledger)
            results[with_ledger] = (out, dict(vars(fabric.metrics)))
        np.testing.assert_array_equal(results[False][0], results[True][0])
        assert results[False][1] == results[True][1]


class TestLmPipelineChannelFailures:
    """PR 7 satellite: the LM pipeline's activation hops and token loopback
    reuse the FSI drain loops, so (src, seq) dedupe + the monotone hop-tag
    stale-drop must keep tokens/logits exact under duplicate and reordered
    delivery on both fabrics — with the overlap ledger staying sane."""

    P = 3

    @pytest.fixture(scope="class")
    def lm_case(self):
        from repro.configs.base import get_config
        from repro.faas.lm_pipeline import build_stage_executors
        from repro.serving.engine import ServingEngine

        cfg = get_config("internlm2-1.8b").reduced()
        rng = np.random.default_rng(11)
        prompts = rng.integers(0, cfg.vocab_size, (2, 8), dtype=np.int32)
        engine = ServingEngine(cfg, seed=0)
        ref = engine.generate(prompts, max_new_tokens=2)
        executors = build_stage_executors(cfg, engine.params, self.P)
        return cfg, prompts, engine.params, ref, executors

    def _run(self, lm_case, channel, fabric):
        from repro.faas.lm_pipeline import run_lm_pipeline

        cfg, prompts, params, _, executors = lm_case
        return run_lm_pipeline(cfg, prompts, params, max_new_tokens=2,
                               P=self.P, channel=channel,
                               executors=executors, fabric=fabric)

    def _check(self, r, ref, ledger_bound=True):
        np.testing.assert_array_equal(r.tokens, ref.tokens)
        np.testing.assert_allclose(r.logits, ref.prefill_logits, atol=3e-2)
        if ledger_bound:
            # redelivery may only push clocks forward, never unwind them
            assert r.metrics["overlap_makespan_s"] <= \
                r.metrics["phased_makespan_s"] + 1e-9

    @pytest.mark.parametrize("fault", sorted(QUEUE_FAULTS))
    def test_queue_faults_keep_pipeline_exact(self, lm_case, fault):
        # tiny payload cap forces multi-chunk prefill hops, so chunk
        # reordering/duplication has something to corrupt
        fabric = QUEUE_FAULTS[fault](self.P, pricing=SMALL_PRICING)
        self._check(self._run(lm_case, "queue", fabric), lm_case[3])

    @pytest.mark.parametrize("fault", sorted(OBJECT_FAULTS))
    def test_object_faults_keep_pipeline_exact(self, lm_case, fault):
        fabric = OBJECT_FAULTS[fault](self.P)
        # the duplicating object fabric stamps its redelivery +0.5s on the
        # LEDGER timeline only (same asymmetry the FSI object-fault test
        # accepts), so the ledger ≤ phased bound is out of scope here
        self._check(self._run(lm_case, "object", fabric), lm_case[3],
                    ledger_bound=(fault != "duplicate"))

    def test_duplicates_change_billing_not_results(self, lm_case):
        """At-least-once delivery doubles what the FABRIC carries (raw bytes
        exactly 2x: every publish re-published), but the receive-side
        (src, seq) dedupe retires every duplicate — tokens and logits match
        the clean run bit-for-bit, and only billing grows."""
        clean = self._run(lm_case, "queue",
                          QueueFabric(self.P, pricing=SMALL_PRICING))
        noisy = self._run(lm_case, "queue",
                          DuplicatingQueueFabric(self.P,
                                                 pricing=SMALL_PRICING))
        np.testing.assert_array_equal(clean.tokens, noisy.tokens)
        np.testing.assert_array_equal(clean.logits, noisy.logits)
        assert noisy.raw_exchange_bytes == 2 * clean.raw_exchange_bytes
        assert noisy.stats.publish_units == 2 * clean.stats.publish_units
        assert noisy.stats.sqs_api_calls >= clean.stats.sqs_api_calls


class TestStragglersUnderOverlap:
    """Straggler slowdown + re-invoke must work when the reported clocks come
    from the overlapped ledger: charge counts stay bit-identical to the
    phased oracle and the output still matches the dense reference."""

    def _case(self):
        net = make_sparse_dnn(128, n_layers=4, seed=7)
        x0 = make_inputs(128, 16, seed=8)
        return net, x0, dense_inference(net, x0)

    def test_reinvoke_stragglers_overlap_vs_phased(self):
        net, x0, oracle = self._case()
        lat = LatencyModel(straggler_prob=0.5, straggler_slowdown=6.0)
        runs = {
            ov: run_fsi(net, x0, P=4, channel="queue", memory_mb=3000,
                        latency=lat, reinvoke_stragglers=True,
                        straggler_timeout=2.0, overlap=ov)
            for ov in (True, False)
        }
        a, b = runs[True], runs[False]
        np.testing.assert_allclose(a.output, oracle, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(a.output, b.output)
        assert vars(a.stats).keys() == vars(b.stats).keys()
        for f, va in vars(a.stats).items():
            if f == "mean_runtime_s":
                continue  # durations legitimately differ between clock models
            assert va == vars(b.stats)[f], f
        assert a.metrics == b.metrics
        assert a.makespan <= b.makespan + 1e-12

    def test_straggler_slowdown_dilates_overlap_makespan(self):
        # at this scale a layer's compute is ~µs against ~40ms channel hops,
        # so the slowdown must be extreme before it can dominate the ledger
        net, x0, _ = self._case()
        base = run_fsi(net, x0, P=4, channel="queue", memory_mb=3000)
        lat = LatencyModel(straggler_prob=0.9, straggler_slowdown=5e4)
        slow = run_fsi(net, x0, P=4, channel="queue", memory_mb=3000,
                       latency=lat)
        assert slow.makespan > base.makespan
