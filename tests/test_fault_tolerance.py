"""Fault tolerance: checkpoint/restart determinism, elastic resharding,
async save integrity, gradient compression convergence."""

import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.training import checkpoint as ckpt
from repro.training.trainer import Trainer, TrainerConfig


def _mk_trainer(tmp, steps=6, ckpt_every=2, compress=False, seed=0,
                arch="llama3.2-1b", async_ckpt=False, stop_after=0):
    cfg = get_config(arch).reduced()
    shape = ShapeConfig("t", seq_len=16, global_batch=4, kind="train")
    tcfg = TrainerConfig(total_steps=steps, ckpt_every=ckpt_every,
                         ckpt_dir=tmp, compress_grads=compress,
                         async_ckpt=async_ckpt, stop_after=stop_after)
    return Trainer(cfg, shape, tcfg, seed=seed)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12.0).reshape(3, 4),
                "b": {"c": jnp.ones((2,), jnp.int32)}}
        ckpt.save(str(tmp_path), 3, tree)
        restored, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3
        np.testing.assert_array_equal(restored["a"], tree["a"])
        np.testing.assert_array_equal(restored["b"]["c"], tree["b"]["c"])

    def test_crc_detects_corruption(self, tmp_path):
        tree = {"w": jnp.ones((8, 8))}
        path = ckpt.save(str(tmp_path), 1, tree)
        # corrupt the single leaf file
        for name in os.listdir(path):
            if name.endswith(".npy"):
                arr = np.load(os.path.join(path, name))
                arr[0] += 1
                np.save(os.path.join(path, name), arr)
        with pytest.raises(IOError):
            ckpt.restore(str(tmp_path), tree)

    def test_keeps_latest(self, tmp_path):
        tree = {"w": jnp.ones(3)}
        for s in (1, 2, 3):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_steps(str(tmp_path)) == [1, 2, 3]
        _, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3


class TestRestartDeterminism:
    def test_resume_bitwise_identical(self, tmp_path):
        """Uninterrupted run ≡ crash-at-step-4 + restart (same final params)."""
        full = _mk_trainer(str(tmp_path / "full"), steps=6)
        hist_full = full.fit()

        crash_dir = str(tmp_path / "crash")
        # crash mid-run: same 6-step schedule, killed after step 4
        part = _mk_trainer(crash_dir, steps=6, ckpt_every=2, stop_after=4)
        part.fit()
        resumed = _mk_trainer(crash_dir, steps=6, ckpt_every=2)
        hist_res = resumed.fit(resume=True)

        flat_a = jax.tree.leaves(full.params)
        flat_b = jax.tree.leaves(resumed.params)
        for a, b in zip(flat_a, flat_b):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # loss history continues where it left off
        assert hist_res["step"][0] == 4
        np.testing.assert_allclose(hist_full["loss"][4:], hist_res["loss"],
                                   rtol=1e-6)

    def test_pipeline_step_keyed(self):
        from repro.data.pipeline import PipelineSpec

        cfg = get_config("llama3.2-1b").reduced()
        shape = ShapeConfig("t", 16, 4, "train")
        p1 = PipelineSpec(cfg, shape, seed=0)
        p2 = PipelineSpec(cfg, shape, seed=0)
        np.testing.assert_array_equal(p1.batch(7)["tokens"], p2.batch(7)["tokens"])
        assert not np.array_equal(p1.batch(7)["tokens"], p1.batch(8)["tokens"])
        # host-sharded slice == slice of the global batch
        full = p1.batch(3)["tokens"]
        np.testing.assert_array_equal(p1.batch(3, lo=1, hi=3)["tokens"], full[1:3])


class TestElasticResharding:
    def test_restore_onto_multi_device_mesh(self, tmp_path):
        """Checkpoint written on 1 device restores sharded onto 8 devices
        (subprocess with a forced 8-device CPU topology)."""
        t = _mk_trainer(str(tmp_path), steps=2, ckpt_every=2)
        t.fit()
        script = textwrap.dedent(f"""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, numpy as np
            from repro.configs import ShapeConfig, get_config
            from repro.models.registry import get_model
            from repro.training import checkpoint as ckpt
            from repro.training.optimizer import get_optimizer
            from repro.distributed.sharding import param_pspecs, to_named
            from repro.launch.mesh import make_mesh, MeshAxes

            cfg = get_config("llama3.2-1b").reduced()
            model = get_model(cfg)
            params = model.init(jax.random.key(0))
            opt = get_optimizer(cfg)
            opt_state = opt.init(params)
            mesh = make_mesh((2, 4), ("data", "model"))
            ax = MeshAxes(mesh)
            pspecs = param_pspecs(cfg, jax.eval_shape(lambda: params), ax)
            sh = {{"params": to_named(mesh, pspecs), "opt": None}}
            state, step = ckpt.restore(
                r"{tmp_path}", {{"params": params, "opt": opt_state}},
                shardings=None)
            # reshard params explicitly onto the 8-device mesh
            resharded = jax.tree.map(
                lambda a, s: jax.device_put(np.asarray(a), s),
                state["params"], to_named(mesh, pspecs),
                is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
            n_sharded = sum(
                len(a.sharding.device_set) > 1 for a in jax.tree.leaves(resharded))
            assert n_sharded > 0, "nothing was sharded"
            print("ELASTIC_OK", step, n_sharded)
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd="/root/repo",
                             timeout=300)
        assert "ELASTIC_OK" in out.stdout, out.stderr[-2000:]


class TestAsyncCheckpoint:
    def test_async_equals_sync(self, tmp_path):
        t_sync = _mk_trainer(str(tmp_path / "s"), steps=4, ckpt_every=2)
        t_sync.fit()
        t_async = _mk_trainer(str(tmp_path / "a"), steps=4, ckpt_every=2,
                              async_ckpt=True)
        t_async.fit()
        a, _ = ckpt.restore(str(tmp_path / "s"),
                            {"params": t_sync.params, "opt": t_sync.opt_state})
        b, _ = ckpt.restore(str(tmp_path / "a"),
                            {"params": t_async.params, "opt": t_async.opt_state})
        for x, y in zip(jax.tree.leaves(a["params"]), jax.tree.leaves(b["params"])):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestGradientCompression:
    def test_wire_savings_and_convergence(self, tmp_path):
        from repro.distributed.compression import Int8Compressor

        base = _mk_trainer(str(tmp_path / "fp"), steps=8, ckpt_every=100)
        hist_fp = base.fit()
        comp = _mk_trainer(str(tmp_path / "q8"), steps=8, ckpt_every=100,
                           compress=True)
        hist_q8 = comp.fit()
        # int8 path converges: loss drops and stays within 10% of fp32 path
        assert hist_q8["loss"][-1] < hist_q8["loss"][0]
        assert abs(hist_q8["loss"][-1] - hist_fp["loss"][-1]) < 0.1 * hist_fp["loss"][-1] + 0.35
        fp32_b, int8_b = Int8Compressor.wire_bytes(base.params)
        assert int8_b < 0.27 * fp32_b

    def test_quantize_roundtrip_error_feedback(self):
        from repro.distributed.compression import (
            Int8Compressor, dequantize_int8, quantize_int8)

        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
        q, s = quantize_int8(g)
        err = g - dequantize_int8(q, s)
        assert float(jnp.abs(err).max()) <= float(s) * 0.5 + 1e-6
        # error feedback: two-step quantized sum ≈ true sum
        comp = Int8Compressor()
        e = comp.init({"g": g})
        total = jnp.zeros_like(g)
        for _ in range(4):
            quant, e = comp.compress({"g": g}, e)
            total = total + comp.decompress(quant)["g"]
        np.testing.assert_allclose(total / 4, g, atol=float(s))


class TestCompressedPsum:
    def test_matches_fp32_psum_subprocess(self):
        """int8 shard_map psum ≈ fp32 psum on an 8-device mesh."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import compressed_psum
            from repro.distributed.sharding import shard_map_compat
            from repro.launch.mesh import make_mesh

            mesh = make_mesh((8,), ("data",))
            x = jax.random.normal(jax.random.key(0), (8, 64, 64), jnp.float32)

            def f(x_loc):
                return compressed_psum(x_loc[0], "data")

            got = jax.jit(shard_map_compat(
                f, mesh=mesh, in_specs=P("data"), out_specs=P()))(x)
            want = x.sum(axis=0)
            scale = float(jnp.max(jnp.abs(x))) / 127.0
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       atol=8 * scale)
            print("PSUM_OK")
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd="/root/repo",
                             timeout=300)
        assert "PSUM_OK" in out.stdout, out.stderr[-2000:]
