"""Test-suite bootstrap: make the tests directory importable (for the
``_hypothesis_compat`` shim) regardless of pytest's import mode."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
