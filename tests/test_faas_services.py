"""Unit tests for the simulated SNS/SQS + S3 fabrics, payloads, launch tree,
and MPI-style collectives."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.faas.collectives import all_reduce, barrier, broadcast, reduce_to_root
from repro.faas.launch_tree import (
    TreeSpec,
    central_launch_schedule,
    children_of,
    launch_schedule,
    parent_of,
    two_level_launch_schedule,
    warm_pool_schedule,
)
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk, decode_chunk, encode_chunk, pack_rows
from repro.faas.queue_service import QueueFabric
from repro.faas.simulator import LatencyModel, SimulatorConfig, run_fsi
from repro.faas.worker import WorkerState


class TestPayload:
    def test_roundtrip(self):
        rows = np.array([3, 9, 100], dtype=np.int32)
        vals = np.arange(12, dtype=np.float32).reshape(3, 4)
        blob = encode_chunk(7, 2, rows, vals, 1, 5)
        layer, src, r2, v2, seq, total = decode_chunk(blob)
        assert (layer, src, seq, total) == (7, 2, 1, 5)
        np.testing.assert_array_equal(rows, r2)
        np.testing.assert_array_equal(vals, v2)

    def test_pack_respects_cap(self):
        rng = np.random.default_rng(0)
        rows = np.arange(5000, dtype=np.int32)
        vals = rng.random((5000, 64)).astype(np.float32)  # incompressible-ish
        cap = 256 * 1024
        chunks = pack_rows(0, 0, rows, vals, cap)
        assert all(len(c) <= cap for c in chunks)
        # reassembly covers every row exactly once
        got = sorted(int(r) for c in chunks for r in decode_chunk(bytes(c))[2])
        assert got == list(range(5000))

    def test_pack_empty(self):
        assert pack_rows(0, 0, np.zeros(0, np.int32), np.zeros((0, 4), np.float32), 1024) == []

    def test_pack_incompressible_tiny_cap_no_recursion(self):
        """Adversarial re-splitting: high-entropy values at a tiny cap drive
        the emit path through many halvings.  The explicit work-stack must
        survive with a crushed Python recursion limit (the recursive version
        could not), keep every chunk under the cap unless it is a single
        row, and conserve the row set exactly."""
        import sys

        rng = np.random.default_rng(0)
        n = 4096
        rows = np.arange(n, dtype=np.int32)
        vals = rng.standard_normal((n, 16)).astype(np.float32)  # incompressible
        cap = 700  # a handful of rows per message at best
        limit = sys.getrecursionlimit()
        try:
            sys.setrecursionlimit(60)
            chunks = pack_rows(0, 1, rows, vals, cap)
        finally:
            sys.setrecursionlimit(limit)
        assert len(chunks) > n // 64  # really did split hard
        got_rows, got_vals = [], []
        for seq, c in enumerate(chunks):
            _, src, r, v, s, total = decode_chunk(bytes(c))
            assert (src, s, total) == (1, seq, len(chunks))
            assert len(c) <= cap or len(r) == 1
            got_rows.append(r)
            got_vals.append(v)
        np.testing.assert_array_equal(np.concatenate(got_rows), rows)
        np.testing.assert_array_equal(np.vstack(got_vals), vals)

    def test_decode_chunk_zero_copy_views(self):
        """``decode_chunk`` must hand back read-only views into the decoded
        body — no per-message copies; the recv scatter is the single copy
        site."""
        rows = np.array([3, 9, 100], dtype=np.int32)
        vals = np.arange(12, dtype=np.float32).reshape(3, 4)
        for compressed in (True, False):
            blob = encode_chunk(7, 2, rows, vals, 0, 1, compress=compressed)
            _, _, r2, v2, _, _ = decode_chunk(blob, compressed=compressed)
            for arr in (r2, v2):
                assert not arr.flags.owndata, "decode_chunk copied"
                assert not arr.flags.writeable
            with pytest.raises((ValueError, RuntimeError)):
                v2[0, 0] = 1.0
            np.testing.assert_array_equal(r2, rows)
            np.testing.assert_array_equal(v2, vals)
            # scatter-style reads still work (the one materialization)
            buf = np.zeros((4, 4), np.float32)
            buf[[0, 1, 2]] = v2
            np.testing.assert_array_equal(buf[:3], vals)

    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(min_value=1, max_value=500),
        batch=st.integers(min_value=1, max_value=32),
        cap=st.sampled_from([4096, 65536, 262144]),
        seed=st.integers(min_value=0, max_value=99999),
    )
    def test_property_pack_conservation(self, n, batch, cap, seed):
        rng = np.random.default_rng(seed)
        rows = np.sort(rng.choice(10**6, size=n, replace=False)).astype(np.int32)
        vals = rng.standard_normal((n, batch)).astype(np.float32)
        chunks = pack_rows(0, 3, rows, vals, cap)
        assert all(len(c) <= cap for c in chunks)
        seen = {}
        for c in chunks:
            _, src, r, v, seq, total = decode_chunk(bytes(c))
            assert src == 3 and total == len(chunks)
            for ri, vi in zip(r, v):
                seen[int(ri)] = vi
        assert sorted(seen) == [int(r) for r in rows]
        reassembled = np.stack([seen[int(r)] for r in rows])
        np.testing.assert_array_equal(reassembled, vals)


class TestQueueFabric:
    def test_fanout_and_billing(self):
        f = QueueFabric(4)
        blob = Chunk(b"x" * 1000, raw_bytes=2000)
        f.publish_batch(0, [(1, blob), (2, blob)], at_time=0.0)
        assert f.metrics.publish_api_calls == 1
        assert f.metrics.publish_billed_units == 1  # 2KB < 64KB
        assert f.metrics.bytes_sns_to_sqs == 2000
        t, msgs = f.poll(1, at_time=1.0)
        assert len(msgs) == 1 and bytes(msgs[0].blob) == bytes(blob)
        t, msgs = f.poll(2, at_time=1.0)
        assert len(msgs) == 1

    def test_publish_caps_enforced(self):
        f = QueueFabric(4)
        big = Chunk(b"x" * (300 * 1024), raw_bytes=0)
        with pytest.raises(ValueError):
            f.publish_batch(0, [(1, big)], 0.0)
        small = Chunk(b"x", raw_bytes=1)
        with pytest.raises(ValueError):
            f.publish_batch(0, [(1, small)] * 11, 0.0)

    def test_billing_in_64kb_units(self):
        f = QueueFabric(4)
        blob = Chunk(b"x" * (200 * 1024), raw_bytes=0)
        f.publish_batch(0, [(1, blob)], 0.0)
        assert f.metrics.publish_billed_units == 4  # ceil(200/64)

    def test_long_poll_waits_for_delivery(self):
        f = QueueFabric(2, publish_latency=0.01, fanout_latency=0.05)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=10.0)
        t, msgs = f.poll(1, at_time=0.0, long_poll=True)
        # first long poll windows may expire before delivery at ~10.06
        while not msgs:
            t, msgs = f.poll(1, at_time=t, long_poll=True)
        assert t >= 10.06 - 1e-9
        assert len(msgs) == 1

    def test_long_poll_exact_deadline_message_not_returned(self):
        """Boundary pin (regression): a message whose ``deliver_at`` lands
        EXACTLY on the long-poll deadline is not returned — the window is
        half-open ``[now, now + W)``, the empty response is already on the
        wire at that instant.  The pre-fix ``<=`` boundary returned the
        message and skipped the empty-poll charge, so billing under eager
        polling could drift from the phased oracle by one empty poll."""
        f = QueueFabric(2, publish_latency=0.0, fanout_latency=0.0,
                        poll_rtt=0.0, long_poll_window=2.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=2.0)
        t, msgs = f.poll(1, at_time=0.0, long_poll=True)
        assert msgs == []                      # deadline == deliver_at: miss
        assert t == 2.0
        assert f.metrics.empty_polls == 1      # the empty window IS billed
        t, msgs = f.poll(1, at_time=t, long_poll=True)
        assert len(msgs) == 1                  # next call collects it
        assert t == 2.0                        # already available: no wait
        assert f.metrics.messages_delivered == 1

    def test_long_poll_sub_deadline_delivers_without_empty_charge(self):
        """Just inside the window the poll wakes at delivery: one delivered
        call, zero empty polls."""
        f = QueueFabric(2, publish_latency=0.0, fanout_latency=0.0,
                        poll_rtt=0.0, long_poll_window=2.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))],
                        at_time=2.0 - 1e-9)
        t, msgs = f.poll(1, at_time=0.0, long_poll=True)
        assert len(msgs) == 1 and t == 2.0 - 1e-9
        assert f.metrics.empty_polls == 0

    def test_long_poll_each_call_exactly_one_outcome(self):
        """Structural invariant: every long-poll call counts exactly one of
        {delivered, empty}, never both, never neither — the accounting the
        eager-polling ledger re-times and the phased oracle bills."""
        f = QueueFabric(2, publish_latency=0.0, fanout_latency=0.0,
                        poll_rtt=0.0, long_poll_window=2.0)
        f.publish_batch(0, [(1, Chunk(b"a", raw_bytes=1))], at_time=1.0)
        f.publish_batch(0, [(1, Chunk(b"b", raw_bytes=1))], at_time=7.5)
        t, calls = 0.0, 0
        while f.pending(1) and calls < 20:
            d0, e0 = f.metrics.messages_delivered, f.metrics.empty_polls
            t, msgs = f.poll(1, at_time=t, long_poll=True)
            calls += 1
            delivered = f.metrics.messages_delivered - d0
            empty = f.metrics.empty_polls - e0
            assert (delivered > 0) != (empty == 1)
            assert delivered == len(msgs)
        assert f.metrics.messages_delivered == 2
        assert f.metrics.empty_polls > 0       # the 1.0→7.5 gap forced waits

    def test_short_poll_can_miss(self):
        f = QueueFabric(2, short_poll_miss_prob=1.0, seed=0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        _, msgs = f.poll(1, at_time=5.0, long_poll=False)
        assert msgs == []  # all servers missed
        _, msgs = f.poll(1, at_time=5.0, long_poll=True)
        assert len(msgs) == 1  # long poll visits all servers


class TestVisibilityTimeout:
    """At-least-once queue semantics (ISSUE 10): polled messages move to an
    in-flight set and only ``delete_batch`` retires them; undeleted messages
    redeliver — with a fresh receipt, re-billed — once the visibility
    deadline passes."""

    def _fab(self, **kw):
        return QueueFabric(2, publish_latency=0.0, fanout_latency=0.0,
                           poll_rtt=0.0, long_poll_window=2.0, **kw)

    def test_polled_message_is_invisible_not_gone(self):
        f = self._fab(visibility_timeout=5.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        t, msgs = f.poll(1, 0.0, long_poll=True)
        assert len(msgs) == 1
        # invisible while in flight: the next window-long poll comes up empty
        t2, msgs2 = f.poll(1, t, long_poll=True)
        assert msgs2 == [] and t2 == t + 2.0
        assert f.metrics.redeliveries == 0

    def test_delete_actually_removes(self):
        f = self._fab(visibility_timeout=1.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        t, msgs = f.poll(1, 0.0, long_poll=True)
        f.delete_batch(1, [msgs[0].receipt], t)
        # well past the visibility deadline: nothing ever reappears
        t2, msgs2 = f.poll(1, t + 10.0, long_poll=True)
        assert msgs2 == []
        assert f.metrics.redeliveries == 0
        assert f.metrics.messages_delivered == 1

    def test_undeleted_message_redelivers_with_fresh_receipt(self):
        f = self._fab(visibility_timeout=5.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        t, msgs = f.poll(1, 0.0, long_poll=True)
        old_receipt = msgs[0].receipt
        t2, msgs2 = f.poll(1, t + 5.0, long_poll=True)  # deadline passed
        assert len(msgs2) == 1
        assert bytes(msgs2[0].blob) == b"m"
        assert msgs2[0].receipt != old_receipt          # SQS receipt handles
        assert f.metrics.redeliveries == 1
        assert f.metrics.messages_delivered == 2        # re-billed delivery
        # deleting via the NEW receipt retires it for good
        f.delete_batch(1, [msgs2[0].receipt], t2)
        _, msgs3 = f.poll(1, t2 + 10.0, long_poll=True)
        assert msgs3 == []

    def test_long_poll_wakes_at_visibility_expiry(self):
        """A parked long poll wakes the moment an in-flight deadline passes
        (the redelivery is the earliest thing that can appear), not at the
        window deadline."""
        f = self._fab(visibility_timeout=1.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        t, msgs = f.poll(1, 0.0, long_poll=True)
        assert len(msgs) == 1
        t2, msgs2 = f.poll(1, t, long_poll=True)
        assert len(msgs2) == 1
        assert t2 == pytest.approx(t + 1.0)   # expiry, not t + window
        assert f.metrics.redeliveries == 1

    def test_stale_receipt_delete_is_harmless(self):
        """Deleting an already-requeued receipt is a per-entry no-op (SQS
        semantics); the redelivered copy stays deliverable."""
        f = self._fab(visibility_timeout=1.0)
        f.publish_batch(0, [(1, Chunk(b"m", raw_bytes=1))], at_time=0.0)
        t, msgs = f.poll(1, 0.0, long_poll=True)
        old_receipt = msgs[0].receipt
        t2, msgs2 = f.poll(1, t + 1.0, long_poll=True)  # redelivered
        assert len(msgs2) == 1
        f.delete_batch(1, [old_receipt], t2)            # stale: ignored
        t3, msgs3 = f.poll(1, t2 + 1.0, long_poll=True)  # redelivers again
        assert len(msgs3) == 1
        assert f.metrics.redeliveries == 2

    def test_empty_delete_batch_bills_nothing(self):
        """Regression (ISSUE 10 satellite): an empty DeleteMessageBatch used
        to bill one SQS API call; now it is a full no-op — no call, no RTT."""
        f = QueueFabric(2, poll_rtt=0.008)
        before = f.metrics.sqs_api_calls
        out = f.delete_batch(1, [], at_time=3.25)
        assert out == 3.25                    # no RTT paid
        assert f.metrics.sqs_api_calls == before

    def test_delete_batch_bills_per_ten_receipts(self):
        f = self._fab()
        f.publish_batch(0, [(1, Chunk(bytes([i]), raw_bytes=1))
                            for i in range(10)], at_time=0.0)
        f.publish_batch(0, [(1, Chunk(bytes([i]), raw_bytes=1))
                            for i in range(2)], at_time=0.0)
        t, receipts = 0.0, []
        while f.pending(1):
            t, msgs = f.poll(1, t, long_poll=True)
            receipts.extend(m.receipt for m in msgs)
        assert len(receipts) == 12
        before = f.metrics.sqs_api_calls
        f.delete_batch(1, receipts, t)
        assert f.metrics.sqs_api_calls - before == 2  # ceil(12 / 10)


class TestObjectFabric:
    def test_put_list_get_and_nul(self):
        f = ObjectFabric(4)
        done = f.put_obj(0, src=1, target=2, blob=Chunk(b"data", raw_bytes=4), at_time=0.0)
        f.put_obj(0, src=3, target=2, blob=None, at_time=0.0)
        t, handles = f.list_files(0, worker=2, at_time=done + 1)
        keys = {h.key: h for h in handles}
        assert "1_2.dat" in keys and "3_2.nul" in keys
        assert keys["3_2.nul"].is_nul
        t, blob = f.get_obj(0, 2, "1_2.dat", t)
        assert bytes(blob) == b"data"
        assert f.metrics.puts == 2 and f.metrics.gets == 1 and f.metrics.lists == 1
        assert f.metrics.nul_files == 1

    def test_visibility_time(self):
        f = ObjectFabric(2, put_latency=1.0)
        f.put_obj(0, 0, 1, Chunk(b"zz", raw_bytes=2), at_time=0.0)
        _, handles = f.list_files(0, 1, at_time=0.5)
        assert handles == []  # not visible yet
        _, handles = f.list_files(0, 1, at_time=2.0)
        assert len(handles) == 1

    def test_multipart_roundtrip(self):
        f = ObjectFabric(2)
        parts = [Chunk(bytes([i]) * (i + 1), raw_bytes=i + 1) for i in range(3)]
        f.put_multipart(0, 0, 1, parts, 0.0)
        _, handles = f.list_files(0, 1, at_time=10.0)
        _, blob = f.get_obj(0, 1, handles[0].key, 10.0)
        got = ObjectFabric.split_multipart(bytes(blob))
        assert got == [bytes(p) for p in parts]


class TestLaunchTree:
    def test_rank_relations(self):
        for B in (2, 3, 4):
            for m in range(1, 50):
                assert parent_of(m, B) == (m - 1) // B
                assert m in children_of(parent_of(m, B), 100, B)

    def test_tree_covers_all_workers(self):
        spec = TreeSpec(n_workers=23, branching=4)
        seen = {0}
        frontier = [0]
        while frontier:
            nxt = []
            for m in frontier:
                for c in spec.children(m):
                    assert c not in seen
                    seen.add(c)
                    nxt.append(c)
            frontier = nxt
        assert seen == set(range(23))

    def test_hierarchical_beats_central_and_two_level(self):
        """Paper §III: the tree launch populates the fleet fastest.

        The tree's O(B·log_B P) critical path beats the central O(P) loop at
        every useful P, and beats Lambada's two-level O(√P) once P grows past
        a few dozen (the paper's own experiments ran at P ≤ 62 but its fleet
        sizing argument is asymptotic)."""
        for P in (20, 42, 62, 256, 1000):
            tree = launch_schedule(P, branching=4).max()
            central = central_launch_schedule(P).max()
            assert tree < central
        for P in (256, 1000):
            tree = launch_schedule(P, branching=4).max()
            two = two_level_launch_schedule(P).max()
            assert tree < two

    def test_launch_deterministic(self):
        a = launch_schedule(42, seed=7, cold_start_jitter=0.2)
        b = launch_schedule(42, seed=7, cold_start_jitter=0.2)
        np.testing.assert_array_equal(a, b)

    def test_warm_pool_ready_at_epoch_and_provision_covers_cascade(self):
        """Warm-pool provisioning: every worker is hot at the request epoch
        (ready == 0), and each worker's pre-request runtime spans from its
        own invoke to the pool-hot instant — the slowest worker's cascade
        ready time plus its weight load."""
        cold = launch_schedule(8, seed=3, cold_start_jitter=0.1)
        load = np.full(8, 0.25)
        ready, provision = warm_pool_schedule(8, seed=3, cold_start_jitter=0.1,
                                              weight_load_s=load)
        np.testing.assert_array_equal(ready, np.zeros(8))
        assert provision.shape == (8,)
        assert np.all(provision > 0)
        # worker 0 is invoked at t=0, so its provision time IS pool-hot —
        # the max over the cold cascade's ready times plus the weight load
        np.testing.assert_allclose(provision[0], cold.max() + 0.25)

    def test_warm_pool_same_jitter_stream_as_launch(self):
        """Same seed → the warm cascade replays the cold cascade's jitter
        draws exactly: with no weight load, pool-hot equals the cold
        cascade's makespan, and the root (invoked at t=0) bills all of it;
        every later-invoked worker bills strictly less."""
        cold = launch_schedule(16, seed=11, cold_start_jitter=0.3)
        _, provision = warm_pool_schedule(16, seed=11, cold_start_jitter=0.3)
        np.testing.assert_allclose(provision[0], cold.max())
        assert np.all(provision[1:] < provision[0])


class TestSimulatorConfigSeeding:
    """Seeded-RNG threading (regression): every random draw flows from
    ``SimulatorConfig`` through named, non-colliding streams."""

    def test_straggler_stream_not_the_seed_plus_99_collision(self):
        """Pre-fix, the straggler stream was ``default_rng(seed + 99)`` —
        byte-identical to the LAUNCH stream of a run seeded ``seed + 99``,
        so 'independent' draws were correlated across runs.  The named
        stream must match neither the legacy derivation nor any launch
        stream."""
        draws = SimulatorConfig(seed=0).rng("straggler").random(16)
        legacy = np.random.default_rng(0 + 99).random(16)
        launch_of_99 = SimulatorConfig(seed=99).launch_rng().random(16)
        assert not np.array_equal(draws, legacy)
        assert not np.array_equal(draws, launch_of_99)

    def test_named_streams_distinct_and_reproducible(self):
        sim = SimulatorConfig(seed=4)
        a = sim.rng("straggler").random(8)
        b = sim.rng("straggler").random(8)
        c = sim.rng("short_poll").random(8)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_identical_runs_identical_makespans(self):
        """Two runs with identical config — cold-start jitter AND straggler
        draws live — must produce identical worker times, metrics, and
        bills on both clock models."""
        from repro.data.graphchallenge import make_inputs, make_sparse_dnn

        net = make_sparse_dnn(256, n_layers=4, seed=0)
        x0 = make_inputs(256, 8, seed=1)
        lat = LatencyModel(straggler_prob=0.4, straggler_slowdown=3.0)
        runs = [run_fsi(net, x0, P=4, channel="queue", memory_mb=4000,
                        latency=lat, seed=5) for _ in range(2)]
        np.testing.assert_array_equal(runs[0].worker_times,
                                      runs[1].worker_times)
        assert runs[0].metrics == runs[1].metrics
        assert vars(runs[0].stats) == vars(runs[1].stats)
        assert runs[0].cost.total == runs[1].cost.total
        np.testing.assert_array_equal(runs[0].output, runs[1].output)


class TestCollectives:
    def _fleet(self, P):
        return [WorkerState(rank=m, memory_mb=1000, start_time=0.1 * m) for m in range(P)]

    @pytest.mark.parametrize("fabric_cls", [QueueFabric, ObjectFabric])
    def test_barrier_aligns_clocks(self, fabric_cls):
        workers = self._fleet(7)
        workers[3].charge_seconds(5.0)
        fabric = fabric_cls(7)
        t = barrier(workers, fabric, TreeSpec(7, 2))
        assert t >= 5.0
        for w in workers:
            assert w.abs_time >= 5.0

    @pytest.mark.parametrize("fabric_cls", [QueueFabric, ObjectFabric])
    def test_reduce_sum(self, fabric_cls):
        workers = self._fleet(5)
        payloads = [np.full((2, 2), float(m)) for m in range(5)]
        out = reduce_to_root(workers, fabric_cls(5), TreeSpec(5, 2), payloads, op="sum")
        np.testing.assert_allclose(out, np.full((2, 2), 10.0))

    def test_reduce_concat_rows(self):
        workers = self._fleet(3)
        payloads = [np.full((2, 1), float(m)) for m in range(3)]
        out = reduce_to_root(workers, QueueFabric(3), TreeSpec(3, 2), payloads)
        assert out.shape == (6, 1)
        assert sorted(out.ravel().tolist()) == [0, 0, 1, 1, 2, 2]

    def test_all_reduce(self):
        workers = self._fleet(4)
        payloads = [np.array([float(m + 1)]) for m in range(4)]
        out = all_reduce(workers, QueueFabric(4), TreeSpec(4, 2), payloads)
        assert out.item() == 10.0
