"""BENCH_fsi.json schema guard — trajectory tooling reads (name,
us_per_call) per row; a malformed row must be caught here / in CI, not when
a later PR tries to diff the trend."""

import json
import os

import pytest

from benchmarks.check_schema import validate

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fsi.json")


def _payload():
    # the artifact is committed since PR 5 (it is the bench-delta baseline),
    # but stay graceful on trees that regenerated and removed it
    if not os.path.exists(BENCH_JSON):
        pytest.skip("BENCH_fsi.json not present (run make bench-quick)")
    with open(BENCH_JSON) as f:
        return json.load(f)


class TestCommittedArtifact:
    def test_committed_bench_json_validates(self):
        assert validate(_payload()) == []

    def test_decode_attn_rows_present_per_backend(self):
        """Acceptance: ≥ 1 decode_attn_* row per registered attention
        backend, each carrying a numeric us_per_call."""
        from repro.core.backends import ATTENTION_BACKEND_NAMES

        rows = {r["name"]: r for r in _payload()["rows"]}
        for name in ATTENTION_BACKEND_NAMES:
            row = rows.get(f"decode_attn_{name.replace('-', '_')}")
            assert row is not None, f"no decode_attn row for {name}"
            assert isinstance(row["us_per_call"], (int, float))

    def test_decode_sharded_rows_present(self):
        """PR 4: at least the 1-shard sequence-sharded decode row, numeric
        (wider shard counts appear when the bench host has more devices)."""
        rows = {r["name"]: r for r in _payload()["rows"]}
        sharded = [r for n, r in rows.items()
                   if n.startswith("decode_sharded_")]
        assert sharded, "no decode_sharded_* rows in BENCH_fsi.json"
        for row in sharded:
            assert isinstance(row["us_per_call"], (int, float)), row


class TestValidator:
    BASE = {"meta": {"quick": True}, "rows": [
        {"name": "fsi_serial", "per_sample_ms": 1.25},
        {"name": "decode_attn_dense_ref", "us_per_call": 10.0},
        {"name": "launch_P8", "tree_s": 0.5},
    ]}

    def test_accepts_well_formed(self):
        assert validate(self.BASE) == []

    def test_rejects_missing_name(self):
        bad = json.loads(json.dumps(self.BASE))
        del bad["rows"][0]["name"]
        assert any("missing/empty 'name'" in p for p in validate(bad))

    def test_rejects_duplicate_name(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_serial", "per_sample_ms": 2.0})
        assert any("duplicate name" in p for p in validate(bad))

    def test_rejects_non_numeric_timing(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"][1]["us_per_call"] = "fast"
        assert any("non-numeric" in p for p in validate(bad))

    def test_rejects_timed_family_without_timing(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"][1] = {"name": "decode_attn_dense_ref", "gflops": 1.0}
        assert any("timed family" in p for p in validate(bad))

    def test_rejects_untimed_decode_sharded_row(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "decode_sharded_splitk_d4", "shards": 4})
        assert any("timed family" in p for p in validate(bad))

    def test_allows_empty_timing_with_note(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "spmm_roofline_pallas_bsr",
                           "us_per_call": "", "note": "jax not installed"})
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "spmm_roofline_pallas_bsr",
                            "us_per_call": ""})
        assert any("without a 'note'" in p for p in validate(bad))

    def test_rejects_empty_rows(self):
        assert any("rows" in p for p in validate({"meta": {}, "rows": []}))

    def test_fused_row_rules(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "fsi_sharded_fused_P64_N65536",
                           "per_sample_ms": 500.0, "wall_s": 2.5,
                           "budget_s": 60.0, "within_budget": True})
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_sharded_fused_P64_N1024",
                            "per_sample_ms": 140.0})
        assert any("without numeric 'wall_s'" in p for p in validate(bad))
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_sharded_fused_P64_N65536",
                            "per_sample_ms": 500.0, "wall_s": 2.5,
                            "budget_s": 60.0})
        assert any("within_budget" in p for p in validate(bad))
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_sharded_fused_P64_N65536",
                            "per_sample_ms": 500.0, "wall_s": 2.5,
                            "budget_s": "1min", "within_budget": True})
        assert any("non-numeric budget_s" in p for p in validate(bad))

    def test_fused_row_note_escape_hatch(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "fsi_sharded_fused_P64_N1024",
                           "us_per_call": "", "note": "jax not installed"})
        assert validate(ok) == []

    def test_serving_cb_row_rules(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].extend([
            {"name": "serving_cb_static_S2", "per_token_ms": 0.02,
             "tokens_per_s": 50000.0},
            {"name": "serving_cb_continuous_S2", "per_token_ms": 0.015,
             "tokens_per_s": 66000.0, "beats_static": True},
        ])
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "serving_cb_static_S2",
                            "per_token_ms": 0.02})
        assert any("'tokens_per_s'" in p for p in validate(bad))
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "serving_cb_continuous_S2",
                            "per_token_ms": 0.015, "tokens_per_s": 66000.0})
        assert any("beats_static" in p for p in validate(bad))
        # static rows carry no acceptance bit — nothing to demand of them
        ok2 = json.loads(json.dumps(self.BASE))
        ok2["rows"].append({"name": "serving_cb_static_S2",
                            "per_token_ms": 0.02, "tokens_per_s": 50000.0})
        assert validate(ok2) == []

    def test_eager_row_rules(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "fsi_queue_eager_P8",
                           "per_sample_ms": 45.2, "lazy_per_sample_ms": 46.3,
                           "phased_per_sample_ms": 50.6,
                           "counters_identical": True})
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_queue_eager_P8",
                            "per_sample_ms": 45.2,
                            "counters_identical": True})
        assert any("'lazy_per_sample_ms'" in p for p in validate(bad))
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_queue_eager_P8",
                            "per_sample_ms": 45.2,
                            "lazy_per_sample_ms": 46.3,
                            "phased_per_sample_ms": 50.6})
        assert any("counters_identical" in p for p in validate(bad))

    def test_warm_pool_row_rules(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "fsi_warm_P8", "per_sample_ms": 10.3,
                           "warm_pool_usd": 0.00016,
                           "counters_identical": True})
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_warm_P8", "per_sample_ms": 10.3,
                            "counters_identical": True})
        assert any("warm_pool_usd" in p for p in validate(bad))
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_warm_P8", "per_sample_ms": 10.3,
                            "warm_pool_usd": 0.00016})
        assert any("counters_identical" in p for p in validate(bad))

    def test_lm_autotune_row_rules(self):
        lm = {"name": "lm_pipeline_auto_P2", "per_token_ms": 230.0,
              "phased_per_token_ms": 240.0, "usd_per_1k_tokens": 0.01,
              "counters_identical": True, "chosen_channel_plan": "q+q"}
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append(dict(lm))
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        row = dict(lm)
        del row["chosen_channel_plan"]
        bad["rows"].append(row)
        assert any("chosen_channel_plan" in p for p in validate(bad))
        # the auto row still owes the standard lm_pipeline_* contract
        bad = json.loads(json.dumps(self.BASE))
        row = dict(lm)
        del row["usd_per_1k_tokens"]
        bad["rows"].append(row)
        assert any("'usd_per_1k_tokens'" in p for p in validate(bad))
        # note escape hatch (jax unavailable on the bench host)
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "lm_pipeline_auto_P2", "per_token_ms": "",
                           "note": "jax not installed"})
        assert validate(ok) == []

    def test_serving_cb_note_escape_hatch(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "serving_cb_continuous_S2",
                           "per_token_ms": "", "note": "jax not installed"})
        assert validate(ok) == []


class TestCommittedFusedRows:
    def test_sharded_fused_rows_recorded(self):
        """Acceptance: the megakernel sweep rows (vmap baseline + fused)
        live in the perf artifact; the paper-scale N=65536 budgeted case is
        asserted when the artifact was produced with --paper-scale (the
        committed baseline is — a plain `make bench-quick` regeneration
        is not, and must not fail the suite)."""
        payload = _payload()
        rows = {r["name"]: r for r in payload["rows"]}
        assert "fsi_sharded_P64_N1024" in rows
        fused = {n: r for n, r in rows.items()
                 if n.startswith("fsi_sharded_fused_")}
        assert fused, "no fsi_sharded_fused_* rows in BENCH_fsi.json"
        for row in fused.values():
            if not row.get("note"):  # "" + note = jax unavailable on host
                assert isinstance(row["wall_s"], (int, float)), row
        if not payload["meta"].get("paper_scale"):
            return
        paper = rows.get("fsi_sharded_fused_P64_N65536")
        assert paper is not None, "paper-scale fused row missing"
        if not paper.get("note"):
            assert isinstance(paper["budget_s"], (int, float))
            assert paper["within_budget"] is True
            assert paper["ulp_exact"] is True


class TestCommittedServingCbRows:
    def test_continuous_batching_beats_static_in_artifact(self):
        """Acceptance (PR 8): the committed artifact carries both
        ``serving_cb_*`` rows, and the continuous row's modeled sustained
        throughput is strictly above the padded-static baseline at equal
        slot count."""
        rows = {r["name"]: r for r in _payload()["rows"]}
        static = rows.get("serving_cb_static_S2")
        cont = rows.get("serving_cb_continuous_S2")
        assert static is not None, "no serving_cb_static_S2 row"
        assert cont is not None, "no serving_cb_continuous_S2 row"
        if static.get("note") or cont.get("note"):
            return  # "" + note = jax unavailable on the bench host
        assert cont["beats_static"] is True
        assert cont["tokens_per_s"] > static["tokens_per_s"]
        assert cont["per_token_ms"] < static["per_token_ms"]


class TestBenchDelta:
    """benchmarks/bench_delta.py — the billed-time regression gate."""

    def _payloads(self, base_ms, new_ms):
        mk = lambda ms: {"meta": {}, "rows": [
            {"name": "fsi_serial", "per_sample_ms": ms},
            {"name": "fsi_queue_P8", "per_sample_ms": 50.0},
        ]}
        return mk(base_ms), mk(new_ms)

    def test_within_threshold_passes(self):
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 11.5)
        assert compare(base, new) == []

    def test_regression_fails(self):
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 12.5)
        problems = compare(base, new)
        assert len(problems) == 1 and "fsi_serial" in problems[0]

    def test_improvement_passes(self):
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 4.0)
        assert compare(base, new) == []

    def test_missing_fresh_row_fails_missing_baseline_skipped(self):
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 10.0)
        new["rows"] = [r for r in new["rows"] if r["name"] != "fsi_serial"]
        problems = compare(base, new)
        assert len(problems) == 1 and "missing from" in problems[0]
        # a row absent from the baseline has no trend — never a failure
        base["rows"] = []
        assert compare(base, new) == []

    def test_custom_threshold_and_rows(self):
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 11.5)
        assert compare(base, new, threshold=0.05) != []
        assert compare(base, new, rows=("fsi_queue_P8",), threshold=0.05) == []

    def test_gated_row_going_dark_fails(self):
        """Regression (PR 9): a numeric baseline whose fresh twin degraded
        to a placeholder ("" + note) was silently skipped pre-fix —
        indistinguishable from the row passing."""
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 10.0)
        new["rows"][0] = {"name": "fsi_serial", "per_sample_ms": "",
                          "note": "jax not installed"}
        problems = compare(base, new)
        assert len(problems) == 1
        assert "fsi_serial" in problems[0] and "went dark" in problems[0]
        assert "jax not installed" in problems[0]

    def test_placeholder_baseline_is_a_loud_skip(self):
        """A placeholder *baseline* has no trend to gate against — not a
        failure, but never a silent drop either: it lands in ``skipped``."""
        from benchmarks.bench_delta import compare

        base, new = self._payloads(10.0, 10.0)
        base["rows"][0] = {"name": "fsi_serial", "per_sample_ms": "",
                           "note": "jax not installed"}
        skipped = []
        assert compare(base, new, skipped=skipped) == []
        assert len(skipped) == 1
        assert "fsi_serial" in skipped[0] and "placeholder" in skipped[0]

    def test_committed_baseline_self_compares_clean(self):
        from benchmarks.bench_delta import compare

        payload = _payload()
        assert compare(payload, payload) == []
