"""BENCH_fsi.json schema guard — trajectory tooling reads (name,
us_per_call) per row; a malformed row must be caught here / in CI, not when
a later PR tries to diff the trend."""

import json
import os

import pytest

from benchmarks.check_schema import validate

BENCH_JSON = os.path.join(os.path.dirname(__file__), "..", "BENCH_fsi.json")


def _payload():
    # the artifact is generated (gitignored): absent on a fresh clone until
    # `make bench-quick` runs — CI orders the bench sweep after this suite
    if not os.path.exists(BENCH_JSON):
        pytest.skip("BENCH_fsi.json not generated yet (run make bench-quick)")
    with open(BENCH_JSON) as f:
        return json.load(f)


class TestCommittedArtifact:
    def test_committed_bench_json_validates(self):
        assert validate(_payload()) == []

    def test_decode_attn_rows_present_per_backend(self):
        """Acceptance: ≥ 1 decode_attn_* row per registered attention
        backend, each carrying a numeric us_per_call."""
        from repro.core.backends import ATTENTION_BACKEND_NAMES

        rows = {r["name"]: r for r in _payload()["rows"]}
        for name in ATTENTION_BACKEND_NAMES:
            row = rows.get(f"decode_attn_{name.replace('-', '_')}")
            assert row is not None, f"no decode_attn row for {name}"
            assert isinstance(row["us_per_call"], (int, float))

    def test_decode_sharded_rows_present(self):
        """PR 4: at least the 1-shard sequence-sharded decode row, numeric
        (wider shard counts appear when the bench host has more devices)."""
        rows = {r["name"]: r for r in _payload()["rows"]}
        sharded = [r for n, r in rows.items()
                   if n.startswith("decode_sharded_")]
        assert sharded, "no decode_sharded_* rows in BENCH_fsi.json"
        for row in sharded:
            assert isinstance(row["us_per_call"], (int, float)), row


class TestValidator:
    BASE = {"meta": {"quick": True}, "rows": [
        {"name": "fsi_serial", "per_sample_ms": 1.25},
        {"name": "decode_attn_dense_ref", "us_per_call": 10.0},
        {"name": "launch_P8", "tree_s": 0.5},
    ]}

    def test_accepts_well_formed(self):
        assert validate(self.BASE) == []

    def test_rejects_missing_name(self):
        bad = json.loads(json.dumps(self.BASE))
        del bad["rows"][0]["name"]
        assert any("missing/empty 'name'" in p for p in validate(bad))

    def test_rejects_duplicate_name(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "fsi_serial", "per_sample_ms": 2.0})
        assert any("duplicate name" in p for p in validate(bad))

    def test_rejects_non_numeric_timing(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"][1]["us_per_call"] = "fast"
        assert any("non-numeric" in p for p in validate(bad))

    def test_rejects_timed_family_without_timing(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"][1] = {"name": "decode_attn_dense_ref", "gflops": 1.0}
        assert any("timed family" in p for p in validate(bad))

    def test_rejects_untimed_decode_sharded_row(self):
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "decode_sharded_splitk_d4", "shards": 4})
        assert any("timed family" in p for p in validate(bad))

    def test_allows_empty_timing_with_note(self):
        ok = json.loads(json.dumps(self.BASE))
        ok["rows"].append({"name": "spmm_roofline_pallas_bsr",
                           "us_per_call": "", "note": "jax not installed"})
        assert validate(ok) == []
        bad = json.loads(json.dumps(self.BASE))
        bad["rows"].append({"name": "spmm_roofline_pallas_bsr",
                            "us_per_call": ""})
        assert any("without a 'note'" in p for p in validate(bad))

    def test_rejects_empty_rows(self):
        assert any("rows" in p for p in validate({"meta": {}, "rows": []}))
