"""Pipeline-parallel LM serving over the FaaS fabric (PR 7 acceptance).

* stage planner: contiguous cover, cost balance, embed/head flags;
* pipeline ≡ on-device ``ServingEngine``: identical greedy tokens and
  matching final logits for a dense transformer AND the MoE family across
  P∈{2,4} on both queue and object channels;
* billing: every charge count bit-identical between ``overlap=True`` and
  the phased oracle, overlap makespan ≤ phased makespan;
* stage cold start bills the stage's layer-slice bytes, never the full
  model, and syncs both ledger timelines;
* ``route_decode_plan`` no longer bakes a capacity-1 layout when routed
  without a ``max_len`` hint (the pallas-splitk block_k bucket regression).
"""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.configs.base import get_config
from repro.core.partitioner import plan_stages
from repro.faas.lm_pipeline import (
    build_stage_executors,
    run_lm_pipeline,
    stage_layer_costs,
)
from repro.faas.simulator import LatencyModel, charge_weight_load
from repro.faas.worker import EventLedger, ModelStageWorker, WorkerState
from repro.serving.engine import ServingEngine

COUNT_STATS = ("P", "memory_mb", "publish_units", "bytes_sns_to_sqs",
               "sqs_api_calls", "s3_puts", "s3_gets", "s3_lists")

ARCHS = ("internlm2-1.8b", "deepseek-moe-16b")
MAX_NEW = 3


class TestStagePlanner:
    def test_uniform_split_covers_contiguously(self):
        plan = plan_stages([1.0] * 8, 4)
        assert [s.n_layers for s in plan.stages] == [2, 2, 2, 2]
        assert plan.stages[0].start == 0 and plan.stages[-1].stop == 8
        for a, b in zip(plan.stages, plan.stages[1:]):
            assert a.stop == b.start

    def test_weighted_split_balances_cost(self):
        # one heavy layer up front: the cheap tail should pack together
        costs = [8.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]
        plan = plan_stages(costs, 2)
        loads = [sum(costs[s.start:s.stop]) for s in plan.stages]
        # best contiguous 2-way split of 15 total is 8 | 7
        assert loads == [8.0, 7.0]

    def test_extreme_skew_keeps_every_stage_nonempty(self):
        plan = plan_stages([0.0, 0.0, 0.0, 100.0], 4)
        assert [s.n_layers for s in plan.stages] == [1, 1, 1, 1]

    def test_embed_and_head_flags(self):
        plan = plan_stages([1.0] * 6, 3)
        assert plan.stages[0].has_embed and not plan.stages[0].has_head
        assert plan.stages[-1].has_head and not plan.stages[-1].has_embed
        mid = plan.stages[1]
        assert not mid.has_embed and not mid.has_head
        solo = plan_stages([1.0], 1).stages[0]
        assert solo.has_embed and solo.has_head

    def test_invalid_inputs_raise(self):
        with pytest.raises(ValueError):
            plan_stages([1.0, 1.0], 0)
        with pytest.raises(ValueError):
            plan_stages([1.0, 1.0], 3)   # more stages than layers
        with pytest.raises(ValueError):
            plan_stages([1.0, -1.0], 1)

    def test_moe_layer_costs_weigh_active_params(self):
        cfg = get_config("deepseek-moe-16b").reduced()
        costs = stage_layer_costs(cfg)
        assert len(costs) == cfg.n_layers
        assert all(c > 0 for c in costs)


@pytest.fixture(scope="module")
def served():
    """Per-arch: reduced config, prompts, device engine, reference output."""
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        rng = np.random.default_rng(7)
        prompts = rng.integers(0, cfg.vocab_size, (2, 10), dtype=np.int32)
        engine = ServingEngine(cfg, seed=0)
        ref = engine.generate(prompts, max_new_tokens=MAX_NEW)
        out[arch] = (cfg, prompts, engine, ref, {})
    return out


def _executors(served_entry, P):
    cfg, _, engine, _, cache = served_entry
    if P not in cache:
        cache[P] = build_stage_executors(cfg, engine.params, P)
    return cache[P]


class TestPipelineParity:
    @pytest.mark.parametrize("channel", ["queue", "object"])
    @pytest.mark.parametrize("P", [2, 4])
    @pytest.mark.parametrize("arch", ARCHS)
    def test_matches_device_engine_and_phased_oracle(self, served, arch, P,
                                                     channel):
        cfg, prompts, engine, ref, _ = served[arch]
        executors = _executors(served[arch], P)
        ov = run_lm_pipeline(cfg, prompts, engine.params,
                             max_new_tokens=MAX_NEW, P=P, channel=channel,
                             executors=executors, overlap=True)
        ph = run_lm_pipeline(cfg, prompts, engine.params,
                             max_new_tokens=MAX_NEW, P=P, channel=channel,
                             executors=executors, overlap=False)
        # --- serving parity: same tokens, same final logits ----------------
        np.testing.assert_array_equal(ov.tokens, ref.tokens)
        np.testing.assert_allclose(ov.logits, ref.prefill_logits, atol=3e-2)
        np.testing.assert_array_equal(ov.tokens, ph.tokens)
        np.testing.assert_array_equal(ov.logits, ph.logits)
        # --- billing: counts bit-identical across clock models -------------
        for f in COUNT_STATS:
            assert getattr(ov.stats, f) == getattr(ph.stats, f), f
        assert ov.raw_exchange_bytes == ph.raw_exchange_bytes
        assert ov.wire_exchange_bytes == ph.wire_exchange_bytes
        assert ov.cost.communication == ph.cost.communication
        # --- clocks: overlap can only remove serialization ------------------
        assert ov.makespan <= ph.makespan + 1e-12
        assert ov.metrics["overlap_makespan_s"] == ov.makespan
        assert ph.metrics["phased_makespan_s"] == ph.makespan
        assert ov.metrics["phased_makespan_s"] == \
            ph.metrics["phased_makespan_s"]
        assert ov.metrics["overlap_makespan_s"] == \
            ph.metrics["overlap_makespan_s"]

    def test_kv_stays_worker_resident(self, served):
        """Decode ships only [B, 1, d] activations + the token loopback —
        the KV cache never crosses a stage boundary.  A decode step's raw
        wire delta must therefore not scale with the prefill length."""
        cfg, prompts, engine, _, _ = served["internlm2-1.8b"]
        executors = _executors(served["internlm2-1.8b"], 2)
        one = run_lm_pipeline(cfg, prompts, engine.params, max_new_tokens=1,
                              P=2, channel="queue", executors=executors)
        two = run_lm_pipeline(cfg, prompts, engine.params, max_new_tokens=2,
                              P=2, channel="queue", executors=executors)
        from repro.faas.payload import _HEADER

        B = prompts.shape[0]
        per_step = two.raw_exchange_bytes - one.raw_exchange_bytes
        # one [B, d] hidden hop + one [B, 1] token loopback, fp32 on the
        # wire, each framed as a single chunk (header + row ids + values)
        frame = _HEADER.size + B * 4
        expect = (frame + B * cfg.d_model * 4) + (frame + B * 4)
        assert per_step == expect

    def test_engine_fabric_path(self, served):
        cfg, prompts, engine, ref, _ = served["internlm2-1.8b"]
        fab = ServingEngine(cfg, params=engine.params, engine="fabric",
                            pipeline_P=2, pipeline_channel="queue")
        got = fab.generate(prompts, max_new_tokens=MAX_NEW)
        np.testing.assert_array_equal(got.tokens, ref.tokens)
        np.testing.assert_allclose(got.prefill_logits, ref.prefill_logits,
                                   atol=3e-2)
        assert got.fabric is not None
        assert got.fabric.stats.sqs_api_calls > 0
        assert got.fabric.metrics["phased_makespan_s"] >= \
            got.fabric.metrics["overlap_makespan_s"]

    def test_engine_fabric_stream_fallback(self, served):
        """The fabric engine has no mid-batch admission point (stage
        workers hold per-batch KV), so ``generate_stream`` degrades to
        per-request static pipeline generates behind the same API — each
        result must match its own per-request fabric ``generate``."""
        from repro.serving.scheduler import Request

        cfg, prompts, engine, _, _ = served["internlm2-1.8b"]
        fab = ServingEngine(cfg, params=engine.params, engine="fabric",
                            pipeline_P=2, pipeline_channel="queue")
        reqs = [Request(rid=i, prompt=prompts[i, :3 + i],
                        max_new_tokens=1 + i)
                for i in range(prompts.shape[0])]
        results = {r.rid: r for r in fab.generate_stream(reqs)}
        assert set(results) == {r.rid for r in reqs}
        for req in reqs:
            solo = fab.generate(np.asarray(req.prompt)[None],
                                max_new_tokens=req.max_new_tokens)
            np.testing.assert_array_equal(results[req.rid].tokens,
                                          solo.tokens[0])
            assert results[req.rid].prompt_len == req.prompt.shape[0]

    def test_unknown_engine_rejected(self, served):
        cfg, _, engine, _, _ = served["internlm2-1.8b"]
        with pytest.raises(ValueError):
            ServingEngine(cfg, params=engine.params, engine="telepathy")


class TestStageColdStart:
    def test_stage_slices_partition_the_weights(self, served):
        cfg, _, engine, _, _ = served["internlm2-1.8b"]
        import jax

        full = sum(leaf.nbytes for leaf in jax.tree.leaves(engine.params))
        for P in (2, 4):
            executors = _executors(served["internlm2-1.8b"], P)
            for ex in executors:
                assert 0 < ex.weight_bytes < full
            # stage slices jointly cover the model (tied embeddings may be
            # duplicated on the head stage, hence >=)
            assert sum(ex.weight_bytes for ex in executors) >= full

    def test_cold_start_bills_slice_not_full_model(self, served):
        """Satellite: a stage worker loading only its layer slice must be
        billed for those bytes, not the full-model load."""
        cfg, _, engine, _, _ = served["internlm2-1.8b"]
        import jax

        full = sum(leaf.nbytes for leaf in jax.tree.leaves(engine.params))
        ex = _executors(served["internlm2-1.8b"], 4)[1]
        lat = LatencyModel()
        w = WorkerState(rank=0, memory_mb=1000)
        charge_weight_load(w, ex, lat)
        assert w.clock == pytest.approx(
            ex.weight_bytes / lat.weight_load_bandwidth)
        assert w.clock < full / lat.weight_load_bandwidth

    def test_cold_start_syncs_both_ledger_timelines(self):
        """A weight load occupies the whole worker: both ledger timelines
        meet at the pre-load frontier, then advance together."""
        ex = ModelStageWorker(spec=None, params=None, prefill_fn=None,
                              decode_fn=None, weight_bytes=250_000_000)
        lat = LatencyModel()  # 250 MB/s -> exactly 1.0s
        w = WorkerState(rank=0, memory_mb=1000,
                        ledger=EventLedger(t_compute=0.3, t_channel=2.0))
        charge_weight_load(w, ex, lat)
        assert w.ledger.t_compute == w.ledger.t_channel == pytest.approx(3.0)
        assert w.clock == pytest.approx(1.0)


class TestRouterMaxLenFallback:
    """Regression for the ``cache_layout_for(backend, max_len or 1)``
    fallback: with no hint the old plan pinned block_k from the capacity-1
    bucket (64), which ``pallas-splitk`` rejects once a real ~2k-token cache
    shows up (1984 % 64 == 0 but the right bucket is 256 — and a true
    capacity-1 layout can't represent it at all)."""

    def test_unhinted_plan_defers_layout(self):
        from repro.serving.router import route_decode_plan

        cfg = get_config("internlm2-1.8b").reduced()
        plan = route_decode_plan(cfg, platform="tpu")  # no max_len hint
        assert plan.attn_backend == "pallas-splitk"
        assert plan.cache_layout is None
        # first use: capacity derived from the actual prefill length
        layout = plan.layout_for(1984)
        assert layout.block_k == 256          # table: ≤4096 → 256
        padded = layout.padded_len(1984)
        layout.check_capacity(padded)         # splitk accepts the cache
        assert padded == 2048

    def test_hinted_plan_still_concrete(self):
        from repro.serving.router import route_decode_plan

        cfg = get_config("internlm2-1.8b").reduced()
        plan = route_decode_plan(cfg, max_len=1000, platform="tpu")
        assert plan.cache_layout is not None
        # layout_for defers to the routed layout when one was resolved
        assert plan.layout_for(8) is plan.cache_layout

    def test_old_fallback_would_have_wrong_bucket(self):
        """The failure the fix removes, step by step: the capacity-1 layout
        pads a 1984-token cache within the block_k=64 bucket (1984 is already
        a 64-multiple), but the splitk dispatch re-resolves the layout for
        the *actual* capacity — block_k=256 — and rejects 1984."""
        from repro.core.backends import cache_layout_for, get_backend

        backend = get_backend("attention", "pallas-splitk")
        stale = cache_layout_for(backend, 1)      # what `max_len or 1` built
        assert stale.block_k == 64
        stale_padded = stale.padded_len(1984)     # 1984: no repair happens
        per_step = cache_layout_for(backend, stale_padded)
        assert per_step.block_k == 256
        with pytest.raises(ValueError):
            per_step.check_capacity(stale_padded)
        # the fixed path pads into the right bucket up front
        good = cache_layout_for(backend, 1984)
        good.check_capacity(good.padded_len(1984))
