"""Differential parity harness for the decode-attention backend registry.

Sweeps every registered :class:`AttentionBackend` against the ``dense-ref``
oracle at two levels:

* **op level** — raw ``decode(q, k_cache, v_cache, cache_len)`` over dtype ×
  ragged ``cache_len`` edge cases (1, block_k−1, block_k, block_k+1, S), on
  the canonical kernel-native ``[B, KV, S, D]`` cache layout (PR 4: the
  capacity is padded to a ``block_k`` multiple at prefill, so ``S`` here is
  pre-padded and ``pallas-splitk`` *rejects* non-multiple capacities instead
  of silently re-padding per step);
* **model level** — every decoding family's full ``decode_step`` (dense
  transformer, MoE, hybrid shared-attention, enc-dec self+cross) with the
  cache ``length`` forced to the same edge set, asserting logits parity
  within per-dtype tolerances.

Plus property tests (``_hypothesis_compat``) that the chunked-LSE scan is
invariant to the kv-chunk size, registry-unification checks, and the
``decode_mha`` jit-cache regression tests (no retrace across steps with a
growing ``cache_len``; platform-resolved ``interpret`` default).
"""

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.backends import (
    ATTENTION_BACKEND_NAMES,
    ChunkedLseAttention,
    PallasSplitKAttention,
    get_backend,
)
from repro.models import encdec, hybrid, moe, transformer
from repro.models.registry import get_model, input_specs
from repro.configs.base import ShapeConfig

# Small block so the edge sweep brackets a real block boundary without
# padding tiny smoke caches to 512.
BLOCK_K = 8
CAP = 16                       # decode cache capacity in the family sweep

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}

# One arch per decoding family (ssm has no decode attention).
FAMILY_ARCHS = {
    "transformer": "internlm2-1.8b",
    "moe": "deepseek-moe-16b",
    "hybrid": "zamba2-7b",
    "encdec": "seamless-m4t-medium",
}


def _backend(name):
    """Registered backend configured for tiny smoke shapes."""
    if name == "pallas-splitk":
        return PallasSplitKAttention(block_k=BLOCK_K)
    if name == "chunked-lse":
        return ChunkedLseAttention(kv_chunk=BLOCK_K)  # force a multi-chunk scan
    return get_backend("attention", name)


def _edge_cache_lens(cap: int, block_k: int = BLOCK_K):
    """Ragged valid-prefix edges: 0/1, the block_k boundary, full cache."""
    lens = {0, 1, block_k - 1, block_k, block_k + 1, cap - 1}
    return sorted(l for l in lens if 0 <= l < cap)


# ---------------------------------------------------------------------------
# op level
# ---------------------------------------------------------------------------


class TestOpParity:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("backend", ATTENTION_BACKEND_NAMES)
    def test_matches_dense_ref_across_cache_lens(self, backend, dtype):
        rng = np.random.default_rng(0)
        # capacity pre-padded to a BLOCK_K multiple (the prefill layout
        # contract); raggedness lives in cache_len, swept below
        B, H, KV, S, D = 2, 4, 2, 24, 16
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), dtype)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), dtype)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), dtype)
        ref_be = get_backend("attention", "dense-ref")
        be = _backend(backend)
        for cache_len in (1, BLOCK_K - 1, BLOCK_K, BLOCK_K + 1, 20, S):
            want = ref_be.decode(q, k, v, cache_len)
            got = be.decode(q, k, v, cache_len)
            assert got.shape == (B, 1, H, D) and got.dtype == q.dtype
            np.testing.assert_allclose(
                np.asarray(got, np.float32), np.asarray(want, np.float32),
                err_msg=f"{backend} cache_len={cache_len}", **TOL[dtype])

    def test_splitk_rejects_unpadded_capacity(self):
        """The per-step re-pad is gone by design: a capacity that violates
        the backend's KVCacheLayout must fail loudly, not silently copy."""
        rng = np.random.default_rng(0)
        B, H, KV, S, D = 1, 2, 2, 20, 8          # 20 % BLOCK_K(8) != 0
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        be = PallasSplitKAttention(block_k=BLOCK_K)
        assert be.cache_layout(S).padded_len(S) == 24
        with pytest.raises(ValueError, match="not a multiple of"):
            be.decode(q, k, k, 5)

    @pytest.mark.parametrize("backend", ATTENTION_BACKEND_NAMES)
    def test_traced_cache_len_under_jit(self, backend):
        """cache_len must be a traced operand, not a static recompile key."""
        rng = np.random.default_rng(1)
        B, H, KV, S, D = 1, 4, 4, 16, 8
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        be = _backend(backend)
        f = jax.jit(lambda cl: be.decode(q, k, v, cl))
        ref_be = get_backend("attention", "dense-ref")
        for cl in (1, 5, S):
            np.testing.assert_allclose(
                np.asarray(f(jnp.asarray(cl, jnp.int32))),
                np.asarray(ref_be.decode(q, k, v, cl)),
                rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(
        kv_chunk=st.sampled_from([1, 2, 3, 5, 8, 16, 24, 64]),
        cache_len=st.integers(min_value=1, max_value=24),
        seed=st.integers(min_value=0, max_value=9999),
    )
    def test_property_chunked_lse_chunk_size_invariant(self, kv_chunk,
                                                       cache_len, seed):
        """The chunked-LSE scan is a tiling of the same softmax: its output
        must be invariant to kv_chunk (and equal to the dense oracle)."""
        from repro.models.attention import decode_attention, decode_attention_dense

        rng = np.random.default_rng(seed)
        B, H, KV, S, D = 2, 4, 2, 24, 8
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        got = decode_attention(q, k, v, cache_len=jnp.asarray(cache_len),
                               kv_chunk=kv_chunk)
        want = decode_attention_dense(q, k, v, cache_len=cache_len)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# model level — every decoding family's decode_step
# ---------------------------------------------------------------------------


def _family_fixture(family):
    """(params, token, cache, decode_fn_factory) for one family."""
    cfg = get_config(FAMILY_ARCHS[family]).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    shape = ShapeConfig("smoke", 8, 2, "prefill")
    batch = input_specs(cfg, shape, abstract=False, seed=0)
    logits, cache = jax.jit(lambda p, b: model.prefill(p, b, CAP))(params, batch)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    mod = {"transformer": transformer, "moe": moe, "hybrid": hybrid,
           "encdec": encdec}[family]

    def decode_fn(be):
        return jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg,
                                                       attn_backend=be))

    return params, token, cache, decode_fn


@pytest.fixture(scope="module", params=sorted(FAMILY_ARCHS))
def family_case(request):
    return request.param, _family_fixture(request.param)


# Logits tolerance per KV-cache dtype.  With an fp32 cache every backend
# computes the softmax end-to-end in fp32 and the per-step attention outputs
# round to identical bf16 activations — measured diff is exactly 0.0 across
# all four families; 1e-4 leaves platform headroom.  With a bf16 cache the
# backends round the probability row at different points (before vs after
# normalization), and the MoE router amplifies that to ~2.3e-2 on worst-case
# logits — the same mechanism behind the kimi-k2 decode-drift regression
# (``test_models_smoke``).
FAMILY_TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
              jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _cache_as(cache, dtype):
    cast = (lambda a: a.astype(dtype)
            if hasattr(a, "dtype") and a.dtype == jnp.bfloat16 else a)
    return jax.tree.map(cast, cache)


class TestModelParity:
    @pytest.mark.parametrize("cache_dtype", [jnp.bfloat16, jnp.float32],
                             ids=["bf16", "fp32"])
    @pytest.mark.parametrize("backend",
                             [n for n in ATTENTION_BACKEND_NAMES
                              if n != "dense-ref"])
    def test_decode_step_logits_match_dense_ref(self, family_case, backend,
                                                cache_dtype):
        family, (params, token, cache, decode_fn) = family_case
        ref_fn = decode_fn(get_backend("attention", "dense-ref"))
        got_fn = decode_fn(_backend(backend))
        base = _cache_as(cache, cache_dtype)
        for cache_len in _edge_cache_lens(CAP):
            c = dict(base, length=jnp.asarray(cache_len, jnp.int32))
            ref_logits, ref_cache = ref_fn(params, token, c)
            got_logits, got_cache = got_fn(params, token, c)
            np.testing.assert_allclose(
                np.asarray(got_logits, np.float32),
                np.asarray(ref_logits, np.float32),
                err_msg=f"{family}/{backend} cache_len={cache_len}",
                **FAMILY_TOL[cache_dtype])
            assert int(got_cache["length"]) == cache_len + 1

    def test_engine_tokens_identical_across_backends(self):
        """End-to-end: greedy generation is backend-invariant."""
        from repro.serving.engine import ServingEngine

        cfg = get_config("internlm2-1.8b").reduced()
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
        outs = {}
        for name in ATTENTION_BACKEND_NAMES:
            eng = ServingEngine(cfg, seed=0, attn_backend=_backend(name))
            outs[name] = eng.generate(prompts, max_new_tokens=4).tokens
        for name, toks in outs.items():
            np.testing.assert_array_equal(toks, outs["dense-ref"], err_msg=name)


# ---------------------------------------------------------------------------
# registry unification + routing
# ---------------------------------------------------------------------------


class TestRegistry:
    def test_attention_names_registered(self):
        assert set(ATTENTION_BACKEND_NAMES) == {
            "dense-ref", "chunked-lse", "pallas-splitk"}
        for name in ATTENTION_BACKEND_NAMES:
            assert get_backend("attention", name).name == name

    def test_defaults_per_kind(self):
        assert get_backend("attention", None).name == "dense-ref"
        assert get_backend("compute", None).name == "numpy-fast"
        # legacy one-argument form still means a compute backend
        assert get_backend("numpy-csr").name == "numpy-csr"
        assert get_backend(None).name == "numpy-fast"

    def test_instances_pass_through(self):
        be = ChunkedLseAttention(kv_chunk=64)
        assert get_backend("attention", be) is be
        assert be.state_key == "chunked-lse:kc64"

    def test_wrong_kind_instance_rejected_at_resolution(self):
        from repro.core.backends import NumpyFastBackend

        with pytest.raises(TypeError, match="not a attention backend"):
            get_backend("attention", NumpyFastBackend())
        with pytest.raises(TypeError, match="not a compute backend"):
            get_backend("compute", ChunkedLseAttention())

    def test_unknown_names_rejected(self):
        with pytest.raises(ValueError, match="unknown attention backend"):
            get_backend("attention", "flash-decoding-v3")
        with pytest.raises(ValueError, match="unknown compute backend"):
            get_backend("compute", "cuda-cusparse")
        with pytest.raises(ValueError, match="unknown backend kind"):
            get_backend("communication", "nccl")

    def test_router_picks_by_platform_and_cache(self):
        from repro.serving.router import route_attention_backend

        cfg = get_config("internlm2-1.8b").reduced()
        assert route_attention_backend(cfg, platform="tpu") == "pallas-splitk"
        assert route_attention_backend(cfg, max_len=32_768,
                                       platform="cpu") == "chunked-lse"
        assert route_attention_backend(cfg, max_len=512,
                                       platform="cpu") == "dense-ref"
        ssm = get_config("mamba2-370m").reduced()
        assert route_attention_backend(ssm, platform="tpu") == "dense-ref"

    def test_engine_auto_routes(self):
        from repro.serving.engine import ServingEngine
        from repro.serving.router import route_attention_backend

        cfg = get_config("internlm2-1.8b").reduced()
        eng = ServingEngine(cfg, seed=0, attn_backend="auto")
        assert eng.attn_backend.name == route_attention_backend(cfg)

    def test_route_decode_plan_bundles_layout(self):
        """The router's DecodePlan carries the KVCacheLayout the backend's
        caches must be allocated with (block_k padding for splitk, identity
        for the view-based backends)."""
        from repro.core.backends import SPLITK_BLOCK_K_TABLE
        from repro.serving.router import route_decode_plan

        cfg = get_config("internlm2-1.8b").reduced()
        tpu = route_decode_plan(cfg, max_len=1000, platform="tpu")
        assert tpu.attn_backend == "pallas-splitk"
        assert tpu.cache_layout.block_k == 128          # table: ≤1024 → 128
        assert tpu.cache_layout.padded_len(1000) == 1024
        cpu = route_decode_plan(cfg, max_len=512, platform="cpu")
        assert cpu.attn_backend == "dense-ref"
        assert cpu.cache_layout.block_k == 1
        assert cpu.cache_layout.padded_len(512) == 512
        assert SPLITK_BLOCK_K_TABLE[0][1] == 64  # table shape sanity

    def test_engine_cache_layout_follows_backend(self):
        from repro.core.backends import KVCacheLayout
        from repro.serving.engine import ServingEngine

        cfg = get_config("internlm2-1.8b").reduced()
        eng = ServingEngine(cfg, seed=0,
                            attn_backend=PallasSplitKAttention(block_k=BLOCK_K))
        assert eng.cache_layout(20) == KVCacheLayout(block_k=BLOCK_K)
        assert eng.cache_layout(20).padded_len(20) == 24
        ref = ServingEngine(cfg, seed=0)
        assert ref.cache_layout(20).padded_len(20) == 20


# ---------------------------------------------------------------------------
# decode_mha jit-cache regressions (interpret default + no retrace)
# ---------------------------------------------------------------------------


class TestDecodeMhaJitCache:
    def test_interpret_default_resolved_from_platform(self):
        from repro.kernels.decode_attention.ops import default_interpret

        # this suite runs on CPU/GPU hosts; on a real TPU the default flips
        assert default_interpret() == (jax.default_backend() != "tpu")

    def test_no_retrace_across_growing_cache_len(self):
        """One compiled entry serves the whole decode loop: cache_len is a
        traced operand, so steps 1..N hit the same jit cache entry."""
        from repro.kernels.decode_attention.ops import (
            decode_mha, decode_mha_cache_size)

        rng = np.random.default_rng(0)
        B, H, KV, S, D = 1, 4, 2, 32, 8
        q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        decode_mha(q, k, v, jnp.asarray(1, jnp.int32), block_k=BLOCK_K)
        size_after_first = decode_mha_cache_size()
        for cache_len in range(2, 12):
            decode_mha(q, k, v, jnp.asarray(cache_len, jnp.int32),
                       block_k=BLOCK_K)
        assert decode_mha_cache_size() == size_after_first

    def test_backend_decode_no_retrace(self):
        """Same property through the pallas-splitk backend (native cache)."""
        from repro.kernels.decode_attention.ops import decode_mha_cache_size

        rng = np.random.default_rng(1)
        be = PallasSplitKAttention(block_k=BLOCK_K)
        B, H, KV, S, D = 1, 2, 2, 24, 8   # capacity = layout.padded_len(20)
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        be.decode(q, k, v, 1)
        size_after_first = decode_mha_cache_size()
        for cache_len in range(2, 8):
            be.decode(q, k, v, cache_len)
        assert decode_mha_cache_size() == size_after_first
