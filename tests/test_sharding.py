"""Sharding-rule unit tests (no devices needed — AbstractMesh)."""

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    zero_param_pspecs,
)
from repro.launch.mesh import MeshAxes
from repro.models.registry import cache_specs, get_model, input_specs


def _mesh(shape=(16, 16), axes=("data", "model")):
    try:  # jax ≥ 0.4.36: AbstractMesh(((name, size), ...))
        return AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:  # older signature: AbstractMesh(shape, axis_names)
        return AbstractMesh(shape, axes)


def _ax(mesh):
    return MeshAxes(mesh)


class TestParamSpecs:
    def test_dense_rules(self):
        cfg = get_config("internlm2-1.8b")
        model = get_model(cfg)
        pshape = jax.eval_shape(model.init, jax.random.key(0))
        specs = param_pspecs(cfg, pshape, _ax(_mesh()))
        blocks = specs["blocks"]
        assert blocks["attn"]["wq"] == P(None, None, "model", None)
        # kv=8 does not divide model=16 → replicated heads
        assert blocks["attn"]["wk"] == P(None, None, None, None)
        assert blocks["mlp"]["wi_gate"] == P(None, None, "model")
        assert blocks["mlp"]["wo"] == P(None, "model", None)
        assert specs["embed"] == P("model", None)

    def test_moe_experts_shard(self):
        cfg = get_config("deepseek-moe-16b")
        model = get_model(cfg)
        pshape = jax.eval_shape(model.init, jax.random.key(0))
        specs = param_pspecs(cfg, pshape, _ax(_mesh()))
        moe = specs["moe_blocks"]["moe"]
        assert moe["w_gate"][1] == "model"      # [L, E, D, F] → E sharded
        assert moe["w_down"][1] == "model"

    def test_mamba_heads_shard(self):
        cfg = get_config("mamba2-370m")
        model = get_model(cfg)
        pshape = jax.eval_shape(model.init, jax.random.key(0))
        specs = param_pspecs(cfg, pshape, _ax(_mesh()))
        blocks = specs["blocks"]
        assert blocks["in_x"] == P(None, None, "model")
        assert blocks["A_log"] == P(None, "model")
        assert blocks["out_proj"] == P(None, "model", None)

    def test_fsdp_adds_data_axis(self):
        cfg = get_config("codeqwen1.5-7b")
        model = get_model(cfg)
        pshape = jax.eval_shape(model.init, jax.random.key(0))
        specs = param_pspecs(cfg, pshape, _ax(_mesh()), fsdp=True)
        assert specs["blocks"]["mlp"]["wi_gate"] == P(None, ("data",), "model")

    def test_no_indivisible_sharding(self):
        """Every spec'd axis size divides its dim, for every arch."""
        mesh = _mesh()
        ax = _ax(mesh)
        for arch in ("internlm2-1.8b", "deepseek-moe-16b", "mamba2-370m",
                     "zamba2-7b", "minicpm-2b", "seamless-m4t-medium"):
            cfg = get_config(arch)
            model = get_model(cfg)
            pshape = jax.eval_shape(model.init, jax.random.key(0))
            specs = param_pspecs(cfg, pshape, ax, fsdp=True)

            def check(path, leaf, spec):
                for dim, s in zip(leaf.shape, tuple(spec)):
                    if s is None:
                        continue
                    size = ax.axis_size(s)
                    assert dim % size == 0, (arch, path, leaf.shape, spec)

            jax.tree_util.tree_map_with_path(
                check, pshape, specs,
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    def test_zero_strategy_skips_stacked_dims(self):
        cfg = get_config("internlm2-1.8b")
        model = get_model(cfg)
        pshape = jax.eval_shape(model.init, jax.random.key(0))
        specs = zero_param_pspecs(cfg, pshape, _ax(_mesh()))
        # stacked layer dim (dim 0) must never be sharded
        for path, spec in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]:
            names = [str(e.key) for e in path
                     if isinstance(e, jax.tree_util.DictKey)]
            if "blocks" in names and len(tuple(spec)):
                assert tuple(spec)[0] is None, (path, spec)


class TestBatchCacheSpecs:
    def test_train_batch_over_dp(self):
        cfg = get_config("llama3.2-1b")
        shape = SHAPES["train_4k"]
        batch = input_specs(cfg, shape, abstract=True)
        specs = batch_pspecs(cfg, shape, batch, _ax(_mesh((2, 16, 16),
                                                          ("pod", "data", "model"))))
        assert specs["tokens"] == P(("pod", "data"), None)

    def test_decode_cache_seq_over_model(self):
        cfg = get_config("llama3.2-1b")
        shape = SHAPES["decode_32k"]
        cache = cache_specs(cfg, shape, abstract=True)
        specs = cache_pspecs(cfg, shape, cache, _ax(_mesh()))
        # kernel-native [L, B, KV, S, dh]: batch→data, seq→model
        assert specs["k"] == P(None, ("data",), None, ("model",), None)

    def test_long500k_batch1_seq_over_everything(self):
        cfg = get_config("zamba2-7b")
        shape = SHAPES["long_500k"]
        cache = cache_specs(cfg, shape, abstract=True)
        specs = cache_pspecs(cfg, shape, cache, _ax(_mesh()))
        kv_spec = specs["kv"][0]
        # batch=1 unshardable → sequence (now at -2) takes (data, model)
        assert kv_spec[-2] == ("data", "model")

    def test_ssm_state_heads_over_model(self):
        cfg = get_config("mamba2-370m")
        shape = SHAPES["decode_32k"]
        cache = cache_specs(cfg, shape, abstract=True)
        specs = cache_pspecs(cfg, shape, cache, _ax(_mesh()))
        assert specs["ssm"][2] == "model"   # [L, B, H, P, N] → H sharded
