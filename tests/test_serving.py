"""Serving engine + router tests."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.serving.engine import ServingEngine
from repro.serving.router import (
    route_serverless, route_serving_plan, route_tpu)
from repro.serving.scheduler import Request


class TestEngine:
    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-370m",
                                      "deepseek-moe-16b"])
    def test_generate_deterministic(self, arch):
        cfg = get_config(arch).reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, size=(3, 8)).astype(np.int32)
        a = engine.generate(prompts, max_new_tokens=4)
        b = engine.generate(prompts, max_new_tokens=4)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.tokens.shape == (3, 4)
        assert np.all(a.tokens >= 0) and np.all(a.tokens < cfg.padded_vocab())

    def test_vlm_with_image_embeds(self):
        cfg = get_config("internvl2-2b").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(2)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
        extra = {"extra_embeds": rng.standard_normal(
            (2, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        out = engine.generate(prompts, max_new_tokens=3, extra=extra)
        assert out.tokens.shape == (2, 3)

    def test_encdec_with_frames(self):
        cfg = get_config("seamless-m4t-medium").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
        extra = {"frames": rng.standard_normal(
            (2, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        out = engine.generate(prompts, max_new_tokens=3, extra=extra)
        assert out.tokens.shape == (2, 3)


class TestMixedLengthBatch:
    """The shared-``cache_len`` gap (known since PR 4), test-first.

    The static ``generate`` pads every prompt in a batch to one length and
    tracks ONE ``length`` scalar for the whole batch, so a short request's
    valid prefix is polluted by its padding — its tokens cannot match the
    same request served alone.  The continuous-batching scheduler gives
    every slot its own length and closes the gap bitwise.
    """

    def _ragged(self, cfg, rng):
        short = rng.integers(0, cfg.vocab_size, size=(3,)).astype(np.int32)
        long = rng.integers(0, cfg.vocab_size, size=(9,)).astype(np.int32)
        return short, long

    @pytest.mark.xfail(
        strict=True,
        reason="static generate shares one cache_len across the batch: a "
               "padded short prompt attends over its padding (PR 4 gap); "
               "served per-request by the scheduler instead")
    def test_static_batch_pads_short_requests_wrong(self):
        cfg = get_config("internlm2-1.8b").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(5)
        short, long = self._ragged(cfg, rng)
        # the only way the static API takes ragged prompts: pad to a bucket
        batch = np.stack([np.pad(short, (0, long.size - short.size)), long])
        got = engine.generate(batch, max_new_tokens=4)
        solo = engine.generate(short[None], max_new_tokens=4,
                               max_len=long.size + 4)
        np.testing.assert_array_equal(got.tokens[0], solo.tokens[0])

    def test_scheduler_serves_ragged_prefixes_bitwise(self):
        cfg = get_config("internlm2-1.8b").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(5)
        short, long = self._ragged(cfg, rng)
        reqs = [Request(rid=0, prompt=short, max_new_tokens=4),
                Request(rid=1, prompt=long, max_new_tokens=4)]
        results = {r.rid: r for r in engine.generate_stream(reqs,
                                                            num_slots=2)}
        cap = engine.cache_layout(13).padded_len(13)   # 9 + 4
        for rid, prompt in ((0, short), (1, long)):
            solo = engine.generate(prompt[None], max_new_tokens=4,
                                   max_len=cap)
            np.testing.assert_array_equal(results[rid].tokens,
                                          solo.tokens[0])
            assert np.array_equal(results[rid].final_logits,
                                  solo.prefill_logits[0])


class TestRouter:
    def test_serverless_progression(self):
        """§IV-C: serial → queue → object as the workload grows."""
        small = route_serverless(int(3e7), 1e5, 120)
        assert small.channel == "serial"
        mid = route_serverless(int(8e9), 2e5, 120)
        assert mid.channel == "queue" and mid.workers > 1
        big = route_serverless(int(8e9), 8e7, 120)
        assert big.channel == "object"

    def test_tpu_sizing_monotone(self):
        tiny = route_tpu(get_config("llama3.2-1b"), SHAPES["decode_32k"])
        huge = route_tpu(get_config("kimi-k2-1t-a32b"), SHAPES["decode_32k"])
        assert tiny.chips < huge.chips
        assert huge.chips >= 256  # 1T params don't fit a small slice

    def test_ssm_cache_cheap(self):
        """SSM decode state is O(1) in sequence — fewer chips than a dense
        model of similar size at long context."""
        ssm = route_tpu(get_config("mamba2-370m"), SHAPES["long_500k"])
        assert ssm.chips <= 4

    def test_serving_plan_sizes_pool_for_full_occupancy(self):
        from repro.serving.kv_pool import RESERVED_BLOCKS

        cfg = get_config("internlm2-1.8b").reduced()
        plan = route_serving_plan(cfg, max_request_len=100, num_slots=4,
                                  platform="cpu")
        layout = plan.layout
        assert plan.slot_capacity % max(1, layout.block_k) == 0
        assert plan.slot_capacity >= 100
        per_slot = layout.blocks_for(plan.slot_capacity)
        assert plan.num_blocks == RESERVED_BLOCKS + 4 * per_slot
        # TPU routing picks the splitk kernel, whose block_k pads capacity
        tpu = route_serving_plan(cfg, max_request_len=100, num_slots=4,
                                 platform="tpu")
        assert tpu.decode.attn_backend == "pallas-splitk"
        assert tpu.slot_capacity % tpu.layout.block_k == 0
