"""Serving engine + router tests."""

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.serving.engine import ServingEngine
from repro.serving.router import route_serverless, route_tpu


class TestEngine:
    @pytest.mark.parametrize("arch", ["internlm2-1.8b", "mamba2-370m",
                                      "deepseek-moe-16b"])
    def test_generate_deterministic(self, arch):
        cfg = get_config(arch).reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(1)
        prompts = rng.integers(0, cfg.vocab_size, size=(3, 8)).astype(np.int32)
        a = engine.generate(prompts, max_new_tokens=4)
        b = engine.generate(prompts, max_new_tokens=4)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        assert a.tokens.shape == (3, 4)
        assert np.all(a.tokens >= 0) and np.all(a.tokens < cfg.padded_vocab())

    def test_vlm_with_image_embeds(self):
        cfg = get_config("internvl2-2b").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(2)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
        extra = {"extra_embeds": rng.standard_normal(
            (2, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        out = engine.generate(prompts, max_new_tokens=3, extra=extra)
        assert out.tokens.shape == (2, 3)

    def test_encdec_with_frames(self):
        cfg = get_config("seamless-m4t-medium").reduced()
        engine = ServingEngine(cfg, seed=0)
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 6)).astype(np.int32)
        extra = {"frames": rng.standard_normal(
            (2, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        out = engine.generate(prompts, max_new_tokens=3, extra=extra)
        assert out.tokens.shape == (2, 3)


class TestRouter:
    def test_serverless_progression(self):
        """§IV-C: serial → queue → object as the workload grows."""
        small = route_serverless(int(3e7), 1e5, 120)
        assert small.channel == "serial"
        mid = route_serverless(int(8e9), 2e5, 120)
        assert mid.channel == "queue" and mid.workers > 1
        big = route_serverless(int(8e9), 8e7, 120)
        assert big.channel == "object"

    def test_tpu_sizing_monotone(self):
        tiny = route_tpu(get_config("llama3.2-1b"), SHAPES["decode_32k"])
        huge = route_tpu(get_config("kimi-k2-1t-a32b"), SHAPES["decode_32k"])
        assert tiny.chips < huge.chips
        assert huge.chips >= 256  # 1T params don't fit a small slice

    def test_ssm_cache_cheap(self):
        """SSM decode state is O(1) in sequence — fewer chips than a dense
        model of similar size at long context."""
        ssm = route_tpu(get_config("mamba2-370m"), SHAPES["long_500k"])
        assert ssm.chips <= 4
