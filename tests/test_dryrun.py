"""Dry-run machinery tests.

The full 40-cell × 2-mesh sweep runs via ``repro.launch.dryrun --all
--both-meshes`` (results in dryrun_sweep.json); here we unit-test the cost
extraction and compile two representative cells in a 512-device subprocess
as a regression gate.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.costing import collective_bytes, jaxpr_flops, traced_flops


class TestFlopCounting:
    def test_dot_flops_exact(self):
        def f(a, b):
            return a @ b

        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        assert traced_flops(f, a, b) == 2 * 64 * 128 * 32

    def test_scan_multiplies_by_length(self):
        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=7)
            return out

        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        assert traced_flops(f, x) == 7 * 2 * 32 * 32 * 32

    def test_nested_jit_and_remat_counted(self):
        def inner(x):
            return jnp.einsum("ij,jk->ik", x, x)

        def f(x):
            return jax.checkpoint(inner)(x) + jax.jit(inner)(x)

        x = jax.ShapeDtypeStruct((16, 16), jnp.float32)
        flops = traced_flops(f, x)
        assert flops >= 2 * (2 * 16 ** 3)  # both calls counted

    def test_grad_includes_backward(self):
        def loss(w, x):
            return jnp.sum((x @ w) ** 2)

        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        fwd = traced_flops(loss, w, x)
        both = traced_flops(jax.grad(loss), w, x)
        assert both > 2 * fwd  # fwd + 2 backward matmuls


class TestCollectiveParsing:
    HLO = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %r = f32[] add(%a, %b)
    }

    %body (p: (s32[], f32[128,256])) -> (s32[], f32[128,256]) {
      %p = (s32[], f32[128,256]) parameter(0)
      %g = f32[128,256] get-tuple-element(%p), index=1
      %ar = f32[128,256]{1,0} all-reduce(%g), replica_groups=[16,16]<=[256], to_apply=%add
      %i = s32[] get-tuple-element(%p), index=0
      ROOT %t = (s32[], f32[128,256]) tuple(%i, %ar)
    }

    %cond (p: (s32[], f32[128,256])) -> pred[] {
      %p = (s32[], f32[128,256]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(24)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    ENTRY %main (x: f32[128,256]) -> f32[128,256] {
      %x = f32[128,256] parameter(0)
      %init = (s32[], f32[128,256]) tuple(s32[] constant(0), %x)
      %w = (s32[], f32[128,256]) while(%init), condition=%cond, body=%body
      %ag = f32[128,256]{1,0} all-gather(%x), replica_groups=[32,8]<=[256], dimensions={0}
      ROOT %out = f32[128,256] get-tuple-element(%w), index=1
    }
    """)

    def test_while_trip_count_multiplication(self):
        per_kind, total = collective_bytes(self.HLO)
        ar_one = 2 * 128 * 256 * 4 * 15 / 16       # ring all-reduce
        ag_one = 128 * 256 * 4 * 7 / 8              # all-gather, groups of 8
        assert per_kind["all-reduce"] == pytest.approx(24 * ar_one)
        assert per_kind["all-gather"] == pytest.approx(ag_one)
        assert total == pytest.approx(24 * ar_one + ag_one)


@pytest.mark.slow
class TestCompileCells:
    def test_two_cells_compile_on_512_devices(self, tmp_path):
        """Regression gate: one train + one decode cell must lower+compile
        against the production mesh (subprocess owns the 512-device init)."""
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
            from repro.launch.dryrun import run_cell
            from repro.launch.mesh import make_production_mesh
            mesh = make_production_mesh()
            r1 = run_cell("llama3.2-1b", "train_4k", mesh=mesh, verbose=False)
            r2 = run_cell("internlm2-1.8b", "decode_32k", mesh=mesh, verbose=False)
            assert r1.status == "ok", r1.note
            assert r2.status == "ok", r2.note
            assert r1.bottleneck in ("compute", "memory", "collective")
            assert r2.flops_per_device > 0
            print("CELLS_OK")
        """)
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run([sys.executable, "-c", script], env=env,
                             capture_output=True, text=True, cwd="/root/repo",
                             timeout=900)
        assert "CELLS_OK" in out.stdout, out.stderr[-3000:]


class TestSweepArtifact:
    def test_sweep_json_complete(self):
        """The checked-in sweep covers all 40 cells × 2 meshes, error-free."""
        path = os.path.join(os.path.dirname(__file__), "..", "dryrun_sweep.json")
        if not os.path.exists(path):
            pytest.skip("sweep artifact not generated yet")
        cells = json.load(open(path))
        assert len(cells) == 80
        assert sum(c["status"] == "error" for c in cells) == 0
        assert sum(c["status"] == "ok" for c in cells) == 64
        # every ok cell carries the three roofline terms
        for c in cells:
            if c["status"] == "ok":
                assert c["compute_term_s"] >= 0
                assert c["memory_term_s"] > 0
                assert c["bottleneck"] in ("compute", "memory", "collective")
