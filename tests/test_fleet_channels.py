"""Fleet-batched channel hot path ≡ per-worker path — bit-identical billing.

The tentpole invariant of the batched pack/drain rewrite: ``run_fsi`` with
``channel_batching=True`` (one ``pack_rows_fleet`` call + one vectorized
drain scatter per layer) must produce byte-identical wire traffic and
bit-identical billing — publish units, SQS calls, S3 puts/gets/lists,
message counts, raw/wire volumes, and every per-worker clock — against the
per-worker reference path, on both channels.
"""

import numpy as np
import pytest

from repro.core.fsi import (
    FleetRecvBuffers,
    fsi_object_recv,
    fsi_object_recv_fleet,
    fsi_object_send_and_local,
    fsi_object_send_and_local_fleet,
    fsi_queue_recv,
    fsi_queue_recv_fleet,
    fsi_queue_send_and_local,
    fsi_queue_send_and_local_fleet,
    prepare_worker_artifacts,
)
from repro.core.partitioner import partition_network
from repro.core.send_recv import build_comm_plans
from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import pack_rows, pack_rows_fleet
from repro.faas.queue_service import QueueFabric
from repro.faas.simulator import run_fsi
from repro.faas.worker import ComputeModel, WorkerState

HAVE_JAX = True
try:
    import jax  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    HAVE_JAX = False


class TestPackRowsFleet:
    def test_byte_identical_to_per_job_pack(self):
        """The batched entry point must emit exactly the bytes of one
        ``pack_rows`` call per job (wire volume is billed — any drift would
        silently change costs between the two send paths)."""
        rng = np.random.default_rng(3)
        jobs = []
        for src in range(5):
            n = int(rng.integers(0, 400))
            rows = np.sort(rng.choice(10**5, size=n, replace=False)).astype(np.int32)
            vals = rng.standard_normal((n, 8)).astype(np.float32)
            jobs.append((2, src, rows, vals))
        for cap in (512, 4096, 262144):
            batched = list(pack_rows_fleet(jobs, cap))
            for job, got in zip(jobs, batched):
                want = pack_rows(*job, cap)
                assert [bytes(c) for c in got] == [bytes(c) for c in want]
                assert [c.raw_bytes for c in got] == [c.raw_bytes for c in want]

    def test_uncompressed_mode(self):
        rows = np.arange(10, dtype=np.int32)
        vals = np.ones((10, 4), np.float32)
        a = list(pack_rows_fleet([(0, 1, rows, vals)], 4096, compress=False))[0]
        b = pack_rows(0, 1, rows, vals, 4096, compress=False)
        assert [bytes(c) for c in a] == [bytes(c) for c in b]


class TestFleetRecvBuffers:
    def test_views_alias_flat(self):
        net = make_sparse_dnn(64, n_layers=1, seed=0)
        partition = partition_network(net.layers, 3, method="hgp", seed=0)
        plans = build_comm_plans(net.layers, partition)
        arts = [a.layers[0] for a in
                prepare_worker_artifacts(net.layers, partition, plans)]
        fb = FleetRecvBuffers.allocate(arts, batch=4)
        assert fb.flat.shape[0] == sum(len(a.needed_rows) for a in arts)
        for m, art in enumerate(arts):
            assert fb.views[m].base is fb.flat
            assert fb.views[m].shape == (len(art.needed_rows), 4)
        fb.views[1][:] = 7.0
        lo, hi = int(fb.offsets[1]), int(fb.offsets[2])
        assert np.all(fb.flat[lo:hi] == 7.0)


def _phase_workers(P):
    return [WorkerState(rank=m, memory_mb=2000) for m in range(P)]


class TestFunctionLevelParity:
    """Per-worker and fleet send/drain must leave identical buffers, clocks,
    counters, and fabric metrics for the same layer inputs."""

    P = 4

    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(128, n_layers=2, seed=1)
        x0 = make_inputs(128, 8, seed=2)
        partition = partition_network(net.layers, self.P, method="hgp", seed=0)
        plans = build_comm_plans(net.layers, partition)
        artifacts = prepare_worker_artifacts(net.layers, partition, plans)
        return net, x0, artifacts

    def _snap(self, workers, fabric):
        return ([(w.clock, w.messages_sent, w.bytes_sent,
                  w.messages_received, w.bytes_received) for w in workers],
                dict(vars(fabric.metrics)))

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_layer_parity(self, case, channel):
        net, x0, artifacts = case
        compute = ComputeModel()
        results = {}
        for mode in ("perworker", "fleet"):
            fabric = (QueueFabric(self.P) if channel == "queue"
                      else ObjectFabric(self.P))
            workers = _phase_workers(self.P)
            panels = [x0[artifacts[m].x0_rows].astype(np.float32)
                      for m in range(self.P)]
            arts = [artifacts[m].layers[0] for m in range(self.P)]
            if mode == "perworker":
                if channel == "queue":
                    bufs = [fsi_queue_send_and_local(
                        arts[m], panels[m], workers[m], fabric, compute)
                        for m in range(self.P)]
                    bufs = [fsi_queue_recv(arts[m], bufs[m], workers[m],
                                           fabric, compute)
                            for m in range(self.P)]
                else:
                    bufs = [fsi_object_send_and_local(
                        arts[m], panels[m], workers[m], fabric, compute)
                        for m in range(self.P)]
                    bufs = [fsi_object_recv(arts[m], bufs[m], workers[m],
                                            fabric, compute)
                            for m in range(self.P)]
            else:
                if channel == "queue":
                    fb = fsi_queue_send_and_local_fleet(
                        arts, panels, workers, fabric, compute)
                    bufs = fsi_queue_recv_fleet(arts, fb, workers, fabric,
                                                compute)
                else:
                    fb = fsi_object_send_and_local_fleet(
                        arts, panels, workers, fabric, compute)
                    bufs = fsi_object_recv_fleet(arts, fb, workers, fabric,
                                                 compute)
            results[mode] = ([b.copy() for b in bufs],
                             self._snap(workers, fabric))
        bufs_a, snap_a = results["perworker"]
        bufs_b, snap_b = results["fleet"]
        for a, b in zip(bufs_a, bufs_b):
            np.testing.assert_array_equal(a, b)
        assert snap_a == snap_b


class TestEndToEndBillingInvariance:
    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(256, n_layers=8, seed=0)
        x0 = make_inputs(256, 24, seed=1)
        return net, x0, dense_inference(net, x0)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_run_fsi_bit_identical(self, case, channel):
        net, x0, oracle = case
        a = run_fsi(net, x0, P=5, channel=channel, memory_mb=4000,
                    channel_batching=False)
        b = run_fsi(net, x0, P=5, channel=channel, memory_mb=4000,
                    channel_batching=True)
        np.testing.assert_array_equal(a.output, b.output)
        np.testing.assert_allclose(b.output, oracle, rtol=1e-4, atol=1e-4)
        # clocks and billing must be EXACT — the batched path changes host
        # execution only, never the simulated algorithm
        np.testing.assert_array_equal(a.worker_times, b.worker_times)
        assert a.cost.total == b.cost.total
        assert a.raw_exchange_bytes == b.raw_exchange_bytes
        assert a.wire_exchange_bytes == b.wire_exchange_bytes
        assert vars(a.stats) == vars(b.stats)
        assert a.metrics == b.metrics

    @pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
    def test_run_fsi_bit_identical_sharded_fused(self, case):
        """The full fused stack (megakernel dispatch + batched channels) vs
        the PR 3 semantics (vmap dispatch + per-worker channels): outputs
        bitwise, billing bit-identical."""
        from repro.core.backends import PallasBsrShardedBackend
        from repro.launch.mesh import make_worker_mesh

        net, x0, oracle = case
        mesh = make_worker_mesh()
        a = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                    compute_backend=PallasBsrShardedBackend(
                        mesh=mesh, dispatch="vmap"),
                    mesh=mesh, channel_batching=False)
        b = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                    compute_backend="pallas-bsr-sharded", mesh=mesh,
                    channel_batching=True)
        np.testing.assert_array_equal(a.output, b.output)
        np.testing.assert_allclose(b.output, oracle, rtol=1e-4, atol=1e-4)
        np.testing.assert_array_equal(a.worker_times, b.worker_times)
        assert a.cost.total == b.cost.total
        assert vars(a.stats) == vars(b.stats)
