"""Overlapped layer pipeline: event-ledger invariants + billing invariance.

The tentpole contract of the double-buffered ``run_fsi`` pipeline:

* the event ledger's per-worker timelines are monotone (a dependency edge
  can delay an event, never rewind a clock);
* ``overlap=True`` makespan ≤ phased makespan on every channel × P (the
  ledger removes serialization, it never adds work);
* every charge COUNT — publish units, publish/SQS API calls, S3
  puts/gets/lists, message counts, raw/wire bytes — is bit-identical
  between ``overlap=True`` and ``overlap=False``, because the phased clock
  alone drives every fabric interaction and the ledger is pure arithmetic
  riding along;
* FMI-style aggregation: a worker's per-layer sends and each collective
  sweep step cost O(1) publish API calls, not O(out-degree).
"""

from dataclasses import replace

import numpy as np
import pytest

from repro.core.cost_model import AWS_PRICING
from repro.core.fsi import (
    fsi_queue_send_and_local_fleet,
    prepare_worker_artifacts,
)
from repro.core.partitioner import partition_network
from repro.core.send_recv import build_comm_plans
from repro.data.graphchallenge import dense_inference, make_inputs, make_sparse_dnn
from repro.faas.collectives import barrier, reduce_to_root
from repro.faas.launch_tree import TreeSpec
from repro.faas.object_service import ObjectFabric
from repro.faas.queue_service import QueueFabric
from repro.faas.simulator import run_fsi
from repro.faas.worker import ComputeModel, EventLedger, WorkerState

COUNT_STATS = ("P", "memory_mb", "publish_units", "bytes_sns_to_sqs",
               "sqs_api_calls", "s3_puts", "s3_gets", "s3_lists")


class TestEventLedger:
    def test_monotone_under_all_mutators(self):
        led = EventLedger(t_compute=1.0, t_channel=1.0)
        prev = (led.t_compute, led.t_channel)

        def check():
            nonlocal prev
            assert led.t_compute >= prev[0] and led.t_channel >= prev[1]
            prev = (led.t_compute, led.t_channel)

        led.compute(0.5); check()
        led.channel_busy_from(0.2, 0.1); check()   # ready in the past: no rewind
        led.channel_busy_from(9.0, 0.1); check()   # gated on a later dependency
        led.receive(0.0, 0.0); check()             # stale arrival: no rewind
        led.receive(20.0, 0.3); check()
        led.join_compute(); check()
        assert led.t_compute == led.t_channel == 20.3
        led.sync(0.7); check()
        led.sync_to(5.0); check()                  # already past: no rewind
        led.sync_to(50.0); check()
        assert led.done == 50.0

    def test_channel_gating_hides_publish_under_compute(self):
        """The canonical overlap: compute proceeds while the channel lane is
        busy, and the finish join only pays the later of the two."""
        led = EventLedger()
        led.compute(1.0)                       # pack
        led.channel_busy_from(led.t_compute, 3.0)  # publish lanes
        led.compute(2.0)                       # local MVP under the publish
        assert led.t_compute == 3.0 and led.t_channel == 4.0
        led.join_compute()
        assert led.t_compute == 4.0            # not 1+3+2=6: overlap won 2s


class TestRunFsiLedgerInvariants:
    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(256, n_layers=8, seed=0)
        x0 = make_inputs(256, 24, seed=1)
        return net, x0, dense_inference(net, x0)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    @pytest.mark.parametrize("P", [2, 4, 8])
    def test_overlap_vs_phased(self, case, channel, P):
        net, x0, oracle = case
        a = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000, overlap=True)
        b = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000, overlap=False)
        # same algorithm, same bytes, same answer
        np.testing.assert_array_equal(a.output, b.output)
        np.testing.assert_allclose(a.output, oracle, rtol=1e-4, atol=1e-4)
        # charge counts bit-identical (durations are the only delta)
        for f in COUNT_STATS:
            assert getattr(a.stats, f) == getattr(b.stats, f), f
        assert a.raw_exchange_bytes == b.raw_exchange_bytes
        assert a.wire_exchange_bytes == b.wire_exchange_bytes
        assert a.cost.communication == b.cost.communication
        assert a.metrics == b.metrics
        # overlap can only remove serialization
        assert a.makespan <= b.makespan + 1e-12
        np.testing.assert_array_compare(np.less_equal, a.worker_times,
                                        b.worker_times + 1e-12)
        # both makespans are reported identically from either run
        assert a.metrics["overlap_makespan_s"] == a.makespan
        assert b.metrics["phased_makespan_s"] == b.makespan
        assert a.cost.total <= b.cost.total + 1e-12

    def test_batching_invariance_holds_under_overlap(self, case):
        """The PR 5 invariant extended: host-side fleet batching must not
        move the LEDGER clocks either (both paths share the charge sites)."""
        net, x0, _ = case
        a = run_fsi(net, x0, P=5, channel="queue", memory_mb=4000,
                    channel_batching=False, overlap=True)
        b = run_fsi(net, x0, P=5, channel="queue", memory_mb=4000,
                    channel_batching=True, overlap=True)
        np.testing.assert_array_equal(a.worker_times, b.worker_times)
        assert a.metrics == b.metrics
        assert vars(a.stats) == vars(b.stats)


class TestEagerWarmAuto:
    """PR 9: eager polling, warm-pool provisioning and per-hop channel
    autotune all ride the dual-clock contract — each mechanism may move the
    ledger clock, never a billable count."""

    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(256, n_layers=8, seed=0)
        x0 = make_inputs(256, 24, seed=1)
        return net, x0, dense_inference(net, x0)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    @pytest.mark.parametrize("P", [2, 4, 8])
    def test_eager_vs_lazy_counts_identical(self, case, channel, P):
        net, x0, oracle = case
        e = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000)
        l = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000,
                    eager_poll=False)
        # same algorithm, same bytes, same answer
        np.testing.assert_array_equal(e.output, l.output)
        np.testing.assert_allclose(e.output, oracle, rtol=1e-4, atol=1e-4)
        # eager re-times ledger events only: every charge count, both byte
        # totals, the billed cost AND the phased clock are bit-identical
        for f in COUNT_STATS:
            assert getattr(e.stats, f) == getattr(l.stats, f), f
        assert e.raw_exchange_bytes == l.raw_exchange_bytes
        assert e.wire_exchange_bytes == l.wire_exchange_bytes
        assert e.cost.communication == l.cost.communication
        assert e.metrics["phased_makespan_s"] == l.metrics["phased_makespan_s"]
        # opening the next long-poll before the publisher finishes can only
        # pull arrivals earlier, never push them later
        assert e.makespan <= l.makespan + 1e-12
        if channel == "queue":
            # the queue hop hides half the publish RTT under the consumer's
            # already-open poll (poll_rtt < publish_latency by default), so
            # the win is strict once there is at least one hop
            assert e.makespan < l.makespan

    def test_warm_pool_cost_only_in_the_new_line(self, case):
        net, x0, oracle = case
        warm = run_fsi(net, x0, P=8, channel="queue", memory_mb=4000,
                       warm_pool=True)
        cold = run_fsi(net, x0, P=8, channel="queue", memory_mb=4000)
        np.testing.assert_array_equal(warm.output, cold.output)
        np.testing.assert_allclose(warm.output, oracle, rtol=1e-4, atol=1e-4)
        # provisioning moves worker ready times (hence poll alignment) but
        # never what is shipped: payload-determined charges are identical
        # (poll counts may legitimately DROP — hot workers drain in sync)
        assert warm.stats.publish_units == cold.stats.publish_units
        assert warm.stats.bytes_sns_to_sqs == cold.stats.bytes_sns_to_sqs
        assert warm.stats.sqs_api_calls <= cold.stats.sqs_api_calls
        assert warm.raw_exchange_bytes == cold.raw_exchange_bytes
        assert warm.wire_exchange_bytes == cold.wire_exchange_bytes
        # the pre-request GB-seconds land ONLY on the explicit new line
        assert cold.cost.warm_pool == 0.0
        assert "warm_pool_usd" not in cold.metrics
        assert warm.cost.warm_pool > 0.0
        assert warm.cost.total == (warm.cost.compute
                                   + warm.cost.communication
                                   + warm.cost.warm_pool)
        assert warm.metrics["warm_pool_usd"] == warm.cost.warm_pool
        assert warm.metrics["warm_pool_provision_s"] > 0.0
        # ...and they buy the cascade + weight load off the critical path
        assert warm.makespan < cold.makespan

    def test_warm_pool_overlap_vs_phased_counters_identical(self, case):
        net, x0, _ = case
        a = run_fsi(net, x0, P=8, channel="queue", memory_mb=4000,
                    warm_pool=True, overlap=True)
        b = run_fsi(net, x0, P=8, channel="queue", memory_mb=4000,
                    warm_pool=True, overlap=False)
        np.testing.assert_array_equal(a.output, b.output)
        for f in COUNT_STATS:
            assert getattr(a.stats, f) == getattr(b.stats, f), f
        assert a.metrics == b.metrics
        assert a.cost.warm_pool == b.cost.warm_pool
        assert a.cost.communication == b.cost.communication

    @pytest.mark.parametrize("P", [2, 4])
    def test_auto_channel_plan_correct_and_deterministic(self, case, P):
        net, x0, oracle = case
        a = run_fsi(net, x0, P=P, channel="auto", memory_mb=4000,
                    overlap=True)
        b = run_fsi(net, x0, P=P, channel="auto", memory_mb=4000,
                    overlap=False)
        np.testing.assert_array_equal(a.output, b.output)
        np.testing.assert_allclose(a.output, oracle, rtol=1e-4, atol=1e-4)
        plan = a.metrics["chosen_channel_plan"]
        layers, gather = plan.split("+")
        assert len(layers) == net.n_layers
        assert set(layers) <= {"q", "o"} and gather in ("q", "o")
        # the plan depends only on the partition + pricing: the phased twin
        # sees the same plan and bit-identical counts
        assert a.metrics == b.metrics
        for f in COUNT_STATS:
            assert getattr(a.stats, f) == getattr(b.stats, f), f
        assert a.cost.communication == b.cost.communication

    def test_auto_follows_the_tariff(self, case):
        """At these payloads the queue tariff wins every hop; making publish
        units three orders of magnitude pricier flips every paying hop to
        object — the planner reads the live cost model, not a constant."""
        net, x0, oracle = case
        cheap_q = run_fsi(net, x0, P=4, channel="auto", memory_mb=4000)
        assert cheap_q.metrics["chosen_channel_plan"] == \
            "q" * net.n_layers + "+q"
        dear_q = replace(AWS_PRICING, sns_publish_64kb=1.0)
        forced_o = run_fsi(net, x0, P=4, channel="auto", memory_mb=4000,
                           pricing=dear_q)
        plan = forced_o.metrics["chosen_channel_plan"]
        layers, gather = plan.split("+")
        # every layer that actually ships bytes flips to object (zero-payload
        # layers tie at $0 and keep the queue default); the gather flips too
        assert "o" in layers and gather == "o"
        assert plan != cheap_q.metrics["chosen_channel_plan"]
        np.testing.assert_allclose(forced_o.output, oracle,
                                   rtol=1e-4, atol=1e-4)


class TestLmPipelineLedgerInvariants:
    """PR 7: the pipeline-parallel LM executor rides the same dual-clock
    contract as ``run_fsi`` — the phased clock drives every activation hop
    and token loopback, the ledger re-times them, and switching the reported
    clock cannot move a single billable count."""

    @pytest.fixture(scope="class")
    def lm_case(self):
        pytest.importorskip("jax")
        from repro.configs.base import get_config
        from repro.faas.lm_pipeline import build_stage_executors
        from repro.serving.engine import ServingEngine

        cfg = get_config("internlm2-1.8b").reduced()
        rng = np.random.default_rng(3)
        prompts = rng.integers(0, cfg.vocab_size, (2, 8), dtype=np.int32)
        engine = ServingEngine(cfg, seed=0)
        ref = engine.generate(prompts, max_new_tokens=2)
        executors = {P: build_stage_executors(cfg, engine.params, P)
                     for P in (2, 4)}
        return cfg, prompts, engine.params, ref, executors

    @pytest.mark.parametrize("channel", ["queue", "object"])
    @pytest.mark.parametrize("P", [2, 4])
    def test_pipeline_counts_identical_overlap_vs_phased(self, lm_case,
                                                         channel, P):
        from repro.faas.lm_pipeline import run_lm_pipeline

        cfg, prompts, params, ref, executors = lm_case
        a = run_lm_pipeline(cfg, prompts, params, max_new_tokens=2, P=P,
                            channel=channel, executors=executors[P],
                            overlap=True)
        b = run_lm_pipeline(cfg, prompts, params, max_new_tokens=2, P=P,
                            channel=channel, executors=executors[P],
                            overlap=False)
        # same algorithm, same bytes, same answer — and it is the answer
        np.testing.assert_array_equal(a.tokens, ref.tokens)
        np.testing.assert_array_equal(a.tokens, b.tokens)
        np.testing.assert_array_equal(a.logits, b.logits)
        # charge counts bit-identical (durations are the only delta)
        for f in COUNT_STATS:
            assert getattr(a.stats, f) == getattr(b.stats, f), f
        assert a.raw_exchange_bytes == b.raw_exchange_bytes
        assert a.wire_exchange_bytes == b.wire_exchange_bytes
        assert a.cost.communication == b.cost.communication
        # overlap can only remove serialization
        assert a.makespan <= b.makespan + 1e-12
        np.testing.assert_array_compare(np.less_equal, a.worker_times,
                                        b.worker_times + 1e-12)
        # both makespans reported identically from either run
        assert a.metrics["phased_makespan_s"] == b.makespan
        assert b.metrics["overlap_makespan_s"] == a.makespan


class TestAggregatedSends:
    """Acceptance: per-layer publish API calls are O(1) per worker, not
    O(out-degree) — all of a worker's per-peer messages ride one batched
    publish (entries ≤10 messages / ≤256KB)."""

    def test_layer_send_one_publish_per_worker(self):
        P = 8
        net = make_sparse_dnn(256, n_layers=4, seed=3)
        x0 = make_inputs(256, 8, seed=4)
        partition = partition_network(net.layers, P, method="hgp", seed=0)
        plans = build_comm_plans(net.layers, partition)
        artifacts = prepare_worker_artifacts(net.layers, partition, plans)
        compute = ComputeModel()
        # pick the layer (k ≥ 1 so the input panel shape is known from the
        # previous layer's out_rows) with the widest fan-out in the plan
        k = max(range(1, net.n_layers),
                key=lambda k: max(len(a.layers[k].send_global)
                                  for a in artifacts))
        arts = [a.layers[k] for a in artifacts]
        out_degree = [len(a.send_global) for a in arts]
        assert max(out_degree) > 1, "case must exercise multi-peer fan-out"
        fabric = QueueFabric(P)
        workers = [WorkerState(rank=m, memory_mb=2000) for m in range(P)]
        # all-ones x^{k-1} panels (activation sparsity then drops nothing)
        panels = [np.ones((len(a.layers[k - 1].out_rows), 8), np.float32)
                  for a in artifacts]
        fsi_queue_send_and_local_fleet(arts, panels, workers, fabric, compute)
        senders = sum(1 for d in out_degree if d > 0)
        # one publish API call per sending worker — NOT sum(out_degree)
        assert fabric.metrics.publish_api_calls == senders
        assert senders < sum(out_degree)


class TestAggregatedCollectives:
    def _fleet(self, P, t0=5.0):
        return [WorkerState(rank=m, memory_mb=2000, clock=t0 - m * 0.1)
                for m in range(P)]

    def test_barrier_fewer_api_calls(self):
        P = 9
        tree = TreeSpec(n_workers=P, branching=4)
        calls = {}
        for agg in (False, True):
            fabric = QueueFabric(P)
            barrier(self._fleet(P), fabric, tree, aggregate=agg)
            calls[agg] = (fabric.metrics.publish_api_calls,
                          fabric.metrics.sqs_api_calls)
        # down-sweep: one publish per parent instead of one per child;
        # up-sweep: one poll+delete per parent instead of per edge
        assert calls[True][0] < calls[False][0]
        assert calls[True][1] < calls[False][1]

    def test_barrier_object_fewer_lists(self):
        P = 9
        tree = TreeSpec(n_workers=P, branching=4)
        lists = {}
        for agg in (False, True):
            fabric = ObjectFabric(P)
            barrier(self._fleet(P), fabric, tree, aggregate=agg)
            lists[agg] = fabric.metrics.lists
        assert lists[True] < lists[False]  # one LIST per node, not per edge

    def test_reduce_drain_side_aggregation(self):
        """In a reduce up-sweep every edge has a distinct source, so the
        publish count can't shrink — the aggregation win is on the receiver:
        each parent drains its whole step with batched polls + ONE batched
        delete instead of a poll + delete per edge.  Bytes and results are
        identical — aggregation batches API calls, it does not change what
        is sent."""
        import dataclasses as _dc

        from repro.core.cost_model import AWS_PRICING
        small = _dc.replace(AWS_PRICING, max_publish_payload=1 << 10)
        P = 5
        tree = TreeSpec(n_workers=P, branching=2)
        payloads = [np.full((64, 16), float(m), np.float32) for m in range(P)]
        outs, calls = {}, {}
        for agg in (False, True):
            fabric = QueueFabric(P, pricing=small)
            outs[agg] = reduce_to_root(self._fleet(P), fabric, tree,
                                       [p.copy() for p in payloads],
                                       op="sum", aggregate=agg)
            calls[agg] = (fabric.metrics.publish_api_calls,
                          fabric.metrics.sqs_api_calls,
                          fabric.metrics.bytes_sns_to_sqs)
        np.testing.assert_array_equal(outs[True], outs[False])
        assert calls[True][0] == calls[False][0]  # same publishes
        assert calls[True][2] == calls[False][2]  # same bytes
        assert calls[True][1] < calls[False][1]   # fewer polls + deletes

    def test_fused_sync_reduce_advances_all_workers(self):
        """reduce_to_root(sync=True) doubles as the barrier: every worker's
        clock lands at/after its subtree hand-off, and the root dominates."""
        P = 7
        tree = TreeSpec(n_workers=P, branching=2)
        workers = self._fleet(P)
        before = [w.abs_time for w in workers]
        payloads = [np.full((4, 2), float(m), np.float32) for m in range(P)]
        reduce_to_root(workers, QueueFabric(P), tree, payloads, op="sum",
                       sync=True)
        after = [w.abs_time for w in workers]
        assert all(a >= b for a, b in zip(after, before))
        assert max(after) == after[0]  # the root finishes last
