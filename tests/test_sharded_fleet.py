"""Mesh-sharded fleet parity: ``pallas-bsr-sharded`` ≡ ``numpy-csr`` oracle
≡ single-device ``pallas-bsr``, over ``worker`` host-device meshes.

Two layers of coverage:

* **in-process** — meshes built from whatever devices this pytest process
  sees (1 on a plain host; 4 under the CI matrix entry that sets
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before jax init),
  including a P-not-divisible-by-device-count fleet;
* **subprocess** — a forced 4-device host platform sweeping meshes of
  1, 2 and 4 devices with P=6 (not divisible by 4 → zero-worker padding),
  so the multi-device shard_map path is exercised even when the parent
  process initialized jax with a single device.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core.backends import (  # noqa: E402
    PallasBsrBackend,
    PallasBsrShardedBackend,
    get_backend,
)
from repro.core.sparse import random_sparse  # noqa: E402
from repro.data.graphchallenge import (  # noqa: E402
    dense_inference,
    make_inputs,
    make_sparse_dnn,
)
from repro.faas.simulator import run_fsi  # noqa: E402
from repro.launch.mesh import make_worker_mesh  # noqa: E402


@pytest.fixture(scope="module")
def case():
    net = make_sparse_dnn(256, n_layers=6, seed=0)
    x0 = make_inputs(256, 16, seed=1)
    return net, x0, dense_inference(net, x0)


class TestShardedFleetBackend:
    def test_registry_resolves_and_rejects_meshless(self):
        be = get_backend("pallas-bsr-sharded")
        assert isinstance(be, PallasBsrShardedBackend)
        assert be.n_devices == len(jax.devices())
        # numpy backends cannot take a mesh through run_fsi
        net = make_sparse_dnn(128, n_layers=2, seed=0)
        x0 = make_inputs(128, 4, seed=1)
        with pytest.raises(ValueError, match="does not take a mesh"):
            run_fsi(net, x0, P=2, channel="queue", memory_mb=2000,
                    compute_backend="numpy-fast", mesh=make_worker_mesh(1))

    def test_state_key_includes_mesh_layout(self):
        a = PallasBsrShardedBackend(mesh=make_worker_mesh(1))
        assert a.state_key != PallasBsrBackend().state_key
        assert ":d1:worker" in a.state_key

    def test_fleet_apply_matches_per_worker_and_vmapped_fleet(self):
        """Sharded dispatch ≡ per-worker apply ≡ the plain vmapped fleet,
        with a worker count that does not divide multi-device meshes (P=3)."""
        rng = np.random.default_rng(11)
        plain = PallasBsrBackend()
        sharded = PallasBsrShardedBackend(mesh=make_worker_mesh())
        shards = [random_sparse(64 + 32 * i, 96, 6, rng) for i in range(3)]
        states = [sharded.prepare(W) for W in shards]
        xs = [rng.standard_normal((W.ncols, 16)).astype(np.float32)
              for W in shards]
        fleet = sharded.fleet_prepare_all([states])
        D = sharded.n_devices
        assert fleet[0].p_pad % D == 0 and fleet[0].p_pad >= 3
        got = sharded.fleet_apply(fleet[0], xs, -0.3)
        ref_fleet = plain.fleet_apply(plain.fleet_prepare_all([states])[0],
                                      xs, -0.3)
        for W, st, x, y, yf in zip(shards, states, xs, got, ref_fleet):
            assert y.shape == (W.nrows, 16)
            np.testing.assert_allclose(y, sharded.apply(st, x, -0.3),
                                       rtol=1e-5, atol=1e-5)
            np.testing.assert_allclose(y, yf, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_run_fsi_matches_oracle_and_plain_backend(self, case, channel):
        """End-to-end on both channels: output ≡ oracle ≡ pallas-bsr, and
        billed accounting is backend-invariant (charges derive from the CSR
        shard, never from the device layout)."""
        net, x0, oracle = case
        ref = run_fsi(net, x0, P=6, channel=channel, memory_mb=4000,
                      compute_backend="numpy-csr")
        r = run_fsi(net, x0, P=6, channel=channel, memory_mb=4000,
                    compute_backend="pallas-bsr-sharded")
        np.testing.assert_allclose(r.output, oracle, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(r.output, ref.output, rtol=1e-4, atol=1e-4)
        assert r.metrics["flops_total"] == ref.metrics["flops_total"]
        assert r.raw_exchange_bytes == ref.raw_exchange_bytes
        assert r.cost.total == pytest.approx(ref.cost.total, rel=0.05)

    def test_explicit_mesh_threads_through_run_fsi(self, case):
        net, x0, oracle = case
        mesh = make_worker_mesh(1)
        r = run_fsi(net, x0, P=5, channel="queue", memory_mb=4000,
                    compute_backend="pallas-bsr-sharded", mesh=mesh)
        np.testing.assert_allclose(r.output, oracle, rtol=1e-4, atol=1e-4)


class TestFusedMegakernelDispatch:
    """The per-device fleet megakernel (``dispatch="fused"``, the default)
    against the PR 3 vmap-within-shard dispatch: bitwise panel parity (the
    count-bounded K loop only drops exact +0.0 padding terms, and the host
    lowering preserves the per-block contraction and k-sum order), plus the
    config plumbing around the ``dispatch`` knob."""

    def test_default_dispatch_is_fused_and_validated(self):
        assert PallasBsrShardedBackend().dispatch == "fused"
        with pytest.raises(ValueError, match="dispatch"):
            PallasBsrShardedBackend(dispatch="einsum")

    def test_state_key_and_with_mesh_carry_dispatch(self):
        mesh = make_worker_mesh(1)
        a = PallasBsrShardedBackend(mesh=mesh)
        b = PallasBsrShardedBackend(mesh=mesh, dispatch="vmap")
        assert a.state_key != b.state_key
        assert a.state_key.endswith(":fused") and b.state_key.endswith(":vmap")
        assert b.with_mesh(mesh).dispatch == "vmap"

    def test_fleet_apply_bitwise_vs_vmap_dispatch(self):
        """Ragged worker shards, P=3 (not divisible by multi-device meshes →
        zero-worker padding): fused ≡ vmap bitwise, and both match the
        per-worker apply."""
        rng = np.random.default_rng(11)
        mesh = make_worker_mesh()
        fused = PallasBsrShardedBackend(mesh=mesh)
        vmapped = PallasBsrShardedBackend(mesh=mesh, dispatch="vmap")
        shards = [random_sparse(64 + 32 * i, 96, 6, rng) for i in range(3)]
        states = [fused.prepare(W) for W in shards]
        xs = [rng.standard_normal((W.ncols, 16)).astype(np.float32)
              for W in shards]
        got_f = fused.fleet_apply(fused.fleet_prepare_all([states])[0],
                                  xs, -0.3)
        got_v = vmapped.fleet_apply(vmapped.fleet_prepare_all([states])[0],
                                    xs, -0.3)
        for st, x, yf, yv in zip(states, xs, got_f, got_v):
            np.testing.assert_array_equal(yf, yv)
            np.testing.assert_allclose(yf, fused.apply(st, x, -0.3),
                                       rtol=1e-5, atol=1e-5)

    def test_run_fsi_fused_bitwise_vs_vmap(self, case):
        net, x0, oracle = case
        mesh = make_worker_mesh()
        r_v = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                      compute_backend=PallasBsrShardedBackend(
                          mesh=mesh, dispatch="vmap"),
                      mesh=mesh, channel_batching=False)
        r_f = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                      compute_backend="pallas-bsr-sharded", mesh=mesh)
        np.testing.assert_array_equal(r_f.output, r_v.output)
        np.testing.assert_allclose(r_f.output, oracle, rtol=1e-4, atol=1e-4)
        assert r_f.metrics["flops_total"] == r_v.metrics["flops_total"]
        assert r_f.raw_exchange_bytes == r_v.raw_exchange_bytes


@pytest.mark.mesh
@pytest.mark.slow
def test_multi_device_mesh_parity():
    """Forced 4-device host platform: meshes of 1, 2, 4 devices, P=6 workers
    (not divisible by 4 → the zero-worker padding path), parity vs the
    numpy-csr oracle run and billing invariance at every width."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax
        from repro.data.graphchallenge import (
            dense_inference, make_inputs, make_sparse_dnn)
        from repro.faas.simulator import run_fsi
        from repro.launch.mesh import make_worker_mesh

        assert len(jax.devices()) == 4, jax.devices()
        from repro.core.backends import PallasBsrShardedBackend
        net = make_sparse_dnn(256, n_layers=4, seed=0)
        x0 = make_inputs(256, 16, seed=1)
        oracle = dense_inference(net, x0)
        ref = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                      compute_backend="numpy-csr")
        for d in (1, 2, 4):
            mesh = make_worker_mesh(d)
            r = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                        compute_backend="pallas-bsr-sharded", mesh=mesh)
            assert np.allclose(r.output, oracle, rtol=1e-4, atol=1e-4), d
            assert np.allclose(r.output, ref.output, rtol=1e-4, atol=1e-4), d
            assert r.metrics["flops_total"] == ref.metrics["flops_total"], d
            assert r.raw_exchange_bytes == ref.raw_exchange_bytes, d
            # fused megakernel ≡ vmap-within-shard, bitwise, on a real
            # multi-device shard_map (incl. the zero-worker padding path)
            rv = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000,
                         compute_backend=PallasBsrShardedBackend(
                             mesh=mesh, dispatch="vmap"),
                         mesh=mesh, channel_batching=False)
            assert np.array_equal(r.output, rv.output), d
        print("SHARDED_MESH_OK")
    """)
    pythonpath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH", "")) if p
    )
    env = dict(os.environ, PYTHONPATH=pythonpath)
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert "SHARDED_MESH_OK" in out.stdout, out.stderr[-3000:]
