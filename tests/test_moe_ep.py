"""shard_map expert-parallel MoE ≡ the pjit dispatch (numerics), verified on
an 8-device subprocess mesh."""

import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_ep_shardmap_matches_pjit_dispatch():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import moe as M
        from repro.models import layers as L
        from repro.launch.mesh import make_mesh, MeshAxes

        cfg = get_config("deepseek-moe-16b").reduced()
        # 8 experts over a 4-wide model axis; huge capacity → no drops, so
        # both dispatch algorithms compute the identical function
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
        key = jax.random.key(0)
        p = M.init_moe_ffn(key, cfg)
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                              jnp.float32)

        ref, _ = M.moe_ffn(p, x, cfg)

        mesh = make_mesh((2, 4), ("data", "model"))
        ax = MeshAxes(mesh)
        L.set_shard_ctx(mesh, ax.dp, ax.model)
        with mesh:
            got, _ = jax.jit(lambda p, x: M.moe_ffn_shardmap(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=2e-2, atol=2e-2)
        print("EP_OK")
    """)
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, cwd="/root/repo",
                         timeout=600)
    assert "EP_OK" in out.stdout, out.stderr[-3000:]
