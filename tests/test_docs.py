"""Top-level docs stay navigable: the files exist, the relative links
resolve (the CI docs-check in-process), and the backend registries named in
the README actually exist in code."""

import os
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "tools"))

from check_doc_links import DEFAULT_DOCS, check_file  # noqa: E402


@pytest.mark.parametrize("doc", DEFAULT_DOCS)
def test_doc_exists(doc):
    assert os.path.exists(os.path.join(REPO, doc)), f"{doc} missing"


@pytest.mark.parametrize("doc", DEFAULT_DOCS)
def test_relative_links_resolve(doc):
    problems = check_file(os.path.join(REPO, doc))
    assert not problems, "\n".join(problems)


def test_link_checker_flags_broken_links(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text("ok [a](#anchor) [b](https://x.test) bad [c](missing.md)\n")
    problems = check_file(str(md))
    assert len(problems) == 1 and "missing.md" in problems[0]


def test_readme_backend_names_are_real():
    """The README's backend matrices must not drift from the registries."""
    from repro.core.backends import ATTENTION_BACKEND_NAMES, BACKEND_NAMES

    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as f:
        readme = f.read()
    for name in (*BACKEND_NAMES, *ATTENTION_BACKEND_NAMES):
        assert f"`{name}`" in readme, f"README missing backend {name!r}"
