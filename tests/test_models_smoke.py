"""Per-architecture smoke tests (assignment requirement).

Each of the 10 assigned archs instantiates a REDUCED config of the same
family and runs one forward/train step on CPU, asserting output shapes and
no NaNs.  Decode paths get one prefill + one decode step.
"""

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, ShapeConfig, get_config, list_archs
from repro.models.registry import get_model, input_specs

ARCHS = list(list_archs())


def _small_shape(cfg):
    return ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


def _make_batch(cfg, kind="train"):
    shape = ShapeConfig("smoke", 32, 2, kind)
    return input_specs(cfg, shape, abstract=False, seed=0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_loss(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, "train")
    loss = jax.jit(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    assert float(loss) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, "train")
    grads = jax.jit(jax.grad(model.loss_fn))(params, batch)
    flat, _ = jax.tree.flatten(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat), f"{arch}: NaN grads"
    # at least one nonzero gradient
    assert any(float(jnp.abs(g).max()) > 0 for g in flat)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_roundtrip(arch):
    cfg = get_config(arch).reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _make_batch(cfg, "prefill")
    max_len = 40
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, max_len)
    )(params, batch)
    assert logits.shape[0] == 2 and logits.shape[1] == 1
    assert jnp.all(jnp.isfinite(logits)), f"{arch}: prefill NaNs"
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits2, cache2 = jax.jit(model.decode_step)(params, token, cache)
    assert logits2.shape == logits.shape
    assert jnp.all(jnp.isfinite(logits2)), f"{arch}: decode NaNs"
    assert int(cache2["length"]) == int(cache["length"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_teacher_forcing(arch):
    """Decode-with-cache must agree with full forward on the same prefix."""
    import dataclasses

    # kimi-k2 no longer xfails here: the PR 2 bisect (bf16 latent/KV-cache
    # rounding) became the product fix — MoE decode holds its cache at fp32
    # (moe.DECODE_CACHE_DTYPE), so the reduced config decodes within the
    # standard 2e-2 tolerance like every other family.

    cfg = get_config(arch).reduced()
    if cfg.family == "encdec":
        pytest.skip("encdec forward consumes dict batches; covered separately")
    if cfg.family == "moe":
        # capacity dropping is data-dependent and differs between a 9-token
        # forward and a 1-token decode — disable drops for the equivalence
        cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 9)), jnp.int32)

    full_batch = {"tokens": toks, "labels": toks}
    shape = ShapeConfig("smoke", 9, 2, "train")
    batch = input_specs(cfg, shape, abstract=False, seed=0)
    batch["tokens"] = toks
    logits_full = jax.jit(model.forward)(params, batch)
    n_extra = logits_full.shape[1] - 9

    pre_batch = dict(batch)
    pre_batch.pop("labels", None)
    pre_batch["tokens"] = toks[:, :8]
    # cache capacity: prompt (+ any frontend prefix) + decode headroom
    cap = 16 + cfg.frontend_tokens
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, cap))(params, pre_batch)
    logits_dec, _ = jax.jit(model.decode_step)(params, toks[:, 8:9], cache)

    ref = logits_full[:, n_extra + 8]
    got = logits_dec[:, 0]
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_kimi_decode_matches_teacher_forcing_fp32_latent_cache():
    """Regression gate for the kimi-k2 decode-drift fix: the PR 2 bisect
    showed the drift was entirely bf16 rounding of cached K/V (the dense
    decode path rounds the probability row against the cache dtype), and MoE
    decode now holds its latent/KV cache at fp32 (``moe.DECODE_CACHE_DTYPE``)
    as the product fix.  This test pins the bisect itself: even with every
    bf16 leaf force-cast to fp32 (a no-op now that prefill emits fp32 caches),
    decode-with-cache agrees with the teacher-forced forward pass within the
    standard 2e-2 tolerance (measured max |Δ| ≈ 1.9e-2)."""
    import dataclasses

    cfg = get_config("kimi-k2-1t-a32b").reduced()
    # capacity dropping is data-dependent and differs between a 9-token
    # forward and a 1-token decode — disable drops for the equivalence
    cfg = dataclasses.replace(cfg, moe_capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 9)), jnp.int32)

    logits_full = jax.jit(model.forward)(params, {"tokens": toks, "labels": toks})
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 16))(
        params, {"tokens": toks[:, :8]})
    cache_fp32 = jax.tree.map(
        lambda a: a.astype(jnp.float32)
        if hasattr(a, "dtype") and a.dtype == jnp.bfloat16 else a,
        cache,
    )
    logits_dec, _ = jax.jit(model.decode_step)(params, toks[:, 8:9], cache_fp32)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, 8], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_encdec_decode_matches_forward():
    cfg = get_config("seamless-m4t-medium").reduced()
    model = get_model(cfg)
    params = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    frames = jnp.asarray(rng.standard_normal((2, cfg.frontend_tokens, cfg.d_model)),
                         jnp.bfloat16)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 9)), jnp.int32)
    logits_full = jax.jit(model.forward)(params, {"frames": frames, "tokens": toks})
    _, cache = jax.jit(lambda p, b: model.prefill(p, b, 16))(
        params, {"frames": frames, "tokens": toks[:, :8]})
    logits_dec, _ = jax.jit(model.decode_step)(params, toks[:, 8:9], cache)
    np.testing.assert_allclose(
        np.asarray(logits_dec[:, 0], np.float32),
        np.asarray(logits_full[:, 8], np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_param_counts_match_analytic():
    """init() parameter totals ≈ the analytic count used for MODEL_FLOPS."""
    for arch in ARCHS:
        cfg = get_config(arch).reduced()
        model = get_model(cfg)
        params = model.init(jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / analytic < 0.15, (
            f"{arch}: actual={actual} analytic={analytic}"
        )


def test_full_configs_param_counts():
    """Full (non-reduced) analytic param counts land near the public figures."""
    expect = {
        "llama3.2-1b": (1.0e9, 1.8e9),
        "internlm2-1.8b": (1.5e9, 2.3e9),
        "minicpm-2b": (2.0e9, 3.3e9),
        "codeqwen1.5-7b": (6.0e9, 8.5e9),
        "zamba2-7b": (6.0e9, 9.0e9),
        "mamba2-370m": (3.0e8, 4.9e8),
        "deepseek-moe-16b": (1.3e10, 2.0e10),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
        "seamless-m4t-medium": (0.4e9, 1.6e9),
        "internvl2-2b": (1.5e9, 2.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]B"
    # MoE active counts
    kimi = get_config("kimi-k2-1t-a32b")
    assert 2.0e10 <= kimi.active_param_count() <= 4.5e10  # "a32b"
