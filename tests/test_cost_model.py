"""Cost model tests incl. the paper's §VI-F validation numbers."""

import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.cost_model import (
    AWS_PRICING,
    WorkloadStats,
    billed_publish_units,
    lambda_cost,
    object_cost,
    queue_cost,
    recommend_configuration,
    serial_cost,
)


class TestEquations:
    def test_lambda_cost_formula(self):
        # Eq. 4 by hand: P=20, T̄=150s, M=2000MB
        s = WorkloadStats(P=20, mean_runtime_s=150.0, memory_mb=2000)
        expected = 20 * AWS_PRICING.lambda_invoke + 20 * 150 * 2000 * AWS_PRICING.lambda_mb_second
        assert math.isclose(lambda_cost(s), expected)

    def test_publish_billing_increments(self):
        u = AWS_PRICING.publish_billing_unit
        assert billed_publish_units(1) == 1
        assert billed_publish_units(u) == 1
        assert billed_publish_units(u + 1) == 2
        assert billed_publish_units(4 * u) == 4  # 256KB publish = 4 requests

    def test_serial_has_no_comm_cost(self):
        s = WorkloadStats(P=1, mean_runtime_s=60, memory_mb=10240)
        assert serial_cost(s).communication == 0.0


class TestPaperValidation:
    """§VI-F: N=16384, P=20, 10000 samples.

    Queue:  Pred (Comp $0.10, Comms $0.25, Total $0.35)
    Object: Pred (Comp $0.09, Comms $0.28, Total $0.37)

    We reconstruct the billable quantities from the paper's own reported
    workload statistics (runtime ≈ 12.97ms/sample ⇒ T̄≈150s at P=20;
    HGP exchange volume ≈2.5GB over 120 layers per Table III scaling) and
    check the model lands on the paper's dollar figures.
    """

    T_BAR = 150.0
    MEM_MB = 2000
    LAYERS = 120
    P = 20
    EXCHANGE_BYTES = int(2.5e9)

    def test_queue_total_matches(self):
        z = self.EXCHANGE_BYTES
        units = max(
            self.LAYERS * self.P,  # ≥1 publish unit per worker-layer
            math.ceil(z / AWS_PRICING.publish_billing_unit),
        )
        polls = self.LAYERS * self.P * (2 + math.ceil((self.P - 1) / 10))
        stats = WorkloadStats(
            P=self.P, mean_runtime_s=self.T_BAR, memory_mb=self.MEM_MB,
            publish_units=units, bytes_sns_to_sqs=z, sqs_api_calls=polls,
        )
        cost = queue_cost(stats)
        assert cost.compute == pytest.approx(0.10, abs=0.03)
        assert cost.communication == pytest.approx(0.25, abs=0.08)
        assert cost.total == pytest.approx(0.35, abs=0.09)

    def test_object_total_matches(self):
        # HGP trims the all-pairs pattern; paper-scale fit: ~60% of P·(P-1)
        # pairs exchange per layer, ~3 LISTs per worker-layer
        pairs = int(0.6 * self.P * (self.P - 1))
        v = self.LAYERS * pairs
        stats = WorkloadStats(
            P=self.P, mean_runtime_s=self.T_BAR * 0.95, memory_mb=self.MEM_MB,
            s3_puts=v, s3_gets=v, s3_lists=self.LAYERS * self.P * 3,
        )
        cost = object_cost(stats)
        assert cost.compute == pytest.approx(0.09, abs=0.03)
        assert cost.communication == pytest.approx(0.28, abs=0.10)
        assert cost.total == pytest.approx(0.37, abs=0.11)

    def test_api_price_gap_queue_vs_object(self):
        """§IV-C: SNS/SQS API requests ≈1 OOM cheaper than S3 PUT/LIST."""
        assert AWS_PRICING.s3_put / AWS_PRICING.sns_publish_64kb >= 9
        assert AWS_PRICING.s3_list / AWS_PRICING.sqs_api_request >= 9


class TestRecommendations:
    def test_small_model_prefers_serial(self):
        ch, P, _ = recommend_configuration(
            model_bytes=int(0.03e9), per_layer_exchange_bytes=1e5, n_layers=120
        )
        assert ch == "serial" and P == 1

    def test_large_model_requires_parallel(self):
        ch, P, _ = recommend_configuration(
            model_bytes=int(16e9), per_layer_exchange_bytes=5e6, n_layers=120,
            memory_mb_per_worker=4000,
        )
        assert ch in ("queue", "object") and P > 1

    def test_queue_wins_at_high_parallelism_low_volume(self):
        _, _, table = recommend_configuration(
            model_bytes=int(8e9), per_layer_exchange_bytes=2e5, n_layers=120,
            memory_mb_per_worker=4000,
        )
        for P in (42, 62):
            if ("queue", P) in table and ("object", P) in table:
                assert (
                    table[("queue", P)].communication
                    < table[("object", P)].communication
                )

    def test_infeasible_raises(self):
        with pytest.raises(ValueError):
            recommend_configuration(
                model_bytes=int(5e12), per_layer_exchange_bytes=1e9, n_layers=120,
                memory_mb_per_worker=1000, P_candidates=(1, 8),
            )


@settings(max_examples=30, deadline=None)
@given(
    p=st.integers(min_value=1, max_value=512),
    t=st.floats(min_value=0.1, max_value=900.0),
    m=st.integers(min_value=128, max_value=10240),
    z=st.integers(min_value=0, max_value=10**11),
)
def test_property_cost_monotonic(p, t, m, z):
    """Costs are monotone in every billable quantity and never negative."""
    base = WorkloadStats(P=p, mean_runtime_s=t, memory_mb=m,
                         publish_units=10, bytes_sns_to_sqs=z, sqs_api_calls=10)
    more = WorkloadStats(P=p, mean_runtime_s=t * 1.5, memory_mb=m,
                         publish_units=20, bytes_sns_to_sqs=z * 2, sqs_api_calls=20)
    c0, c1 = queue_cost(base), queue_cost(more)
    assert c0.total >= 0
    assert c1.compute >= c0.compute
    assert c1.communication >= c0.communication
