"""Degrade gracefully when ``hypothesis`` is not installed.

The property tests import ``given``/``settings``/``st`` from here instead of
from ``hypothesis`` directly.  With hypothesis present this module is a pure
re-export.  Without it, each ``@given`` collapses to a deterministic
``pytest.mark.parametrize`` over a handful of seeded draws — the property
still gets exercised (as a smoke test) rather than the whole module dying at
collection, which is how the seed repo failed.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as _np
    import pytest as _pytest

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 5

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1))
            )

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value))
            )

        @staticmethod
        def sampled_from(items):
            pool = list(items)
            return _Strategy(lambda rng: pool[int(rng.integers(len(pool)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    def settings(**_kwargs):
        return lambda f: f

    def given(**strategies):
        def deco(f):
            # seed from the test name so draws are stable across runs
            rng = _np.random.default_rng(zlib.crc32(f.__name__.encode()))
            names = list(strategies)
            cases = [
                tuple(strategies[n].example(rng) for n in names)
                for _ in range(_FALLBACK_EXAMPLES)
            ]
            return _pytest.mark.parametrize(",".join(names), cases)(f)

        return deco
