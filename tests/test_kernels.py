"""Per-kernel validation: pallas_call (interpret=True) vs ref.py oracles,
swept over shapes and dtypes (assignment requirement)."""

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import bsr_from_dense, random_sparse
from repro.kernels.bsr_spmm.ops import prepare_bsr_operands, bsr_spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_fused_ref
from repro.kernels.decode_attention.ops import decode_mha
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import mha
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_scan_ref

TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


class TestBsrSpmm:
    @pytest.mark.parametrize("n,bm,bn,batch", [
        (256, 32, 32, 64), (512, 64, 32, 128), (128, 16, 16, 32),
    ])
    def test_matches_ref_random(self, n, bm, bn, batch):
        rng = np.random.default_rng(0)
        csr = random_sparse(n, n, 16, rng)
        bsr = bsr_from_dense(csr.to_dense(), (bm, bn))
        blocks, cols = prepare_bsr_operands(bsr)
        x = jnp.asarray(rng.standard_normal((n, batch)), jnp.float32)
        got = bsr_spmm(blocks, cols, x, bias=-0.3, clip=32.0,
                       interpret=True)
        want = bsr_spmm_fused_ref(blocks, cols, x, bias=-0.3, clip=32.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_graphchallenge_layer(self):
        """Kernel == dense oracle on an actual butterfly layer + epilogue."""
        from repro.data.graphchallenge import make_sparse_dnn, make_inputs

        net = make_sparse_dnn(256, n_layers=1, seed=3)
        x = make_inputs(256, 64, seed=4)
        bsr = bsr_from_dense(net.layers[0].to_dense(), (32, 32))
        blocks, cols = prepare_bsr_operands(bsr)
        got = bsr_spmm(blocks, cols, jnp.asarray(x), bias=net.bias,
                       interpret=True)
        from repro.data.graphchallenge import relu_bias_threshold
        want = relu_bias_threshold(net.layers[0].to_dense() @ x, net.bias)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_batch_panels(self):
        rng = np.random.default_rng(5)
        csr = random_sparse(128, 128, 8, rng)
        bsr = bsr_from_dense(csr.to_dense(), (32, 32))
        blocks, cols = prepare_bsr_operands(bsr)
        x = jnp.asarray(rng.standard_normal((128, 256)), jnp.float32)
        got = bsr_spmm(blocks, cols, x, bias=0.0, interpret=True)
        want = bsr_spmm_fused_ref(blocks, cols, x, bias=0.0)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestFleetMegakernel:
    """The per-device fleet megakernel: the interpreted Pallas grid
    (``force_grid=True``, the lowering the compiled TPU dispatch shares
    BlockSpecs with) must agree bitwise with the vectorized host lowering
    the CPU backends route through, and both with the per-worker kernel."""

    def _fleet(self, p=3, nbr=2, k=3, bm=8, bn=8, n=48, b=6, seed=0):
        rng = np.random.default_rng(seed)
        blocks = rng.standard_normal((p, nbr, k, bm, bn)).astype(np.float32)
        counts = rng.integers(1, k + 1, (p, nbr)).astype(np.int32)
        for pi in range(p):          # zero the padding blocks beyond counts
            for r in range(nbr):
                blocks[pi, r, counts[pi, r]:] = 0.0
        cols = rng.integers(0, n // bn, (p, nbr, k)).astype(np.int32)
        cols[blocks.sum(axis=(-1, -2)) == 0.0] = 0
        x = rng.standard_normal((p, n, b)).astype(np.float32)
        return tuple(jnp.asarray(a) for a in (blocks, cols, counts, x))

    def test_grid_matches_host_lowering_bitwise(self):
        from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_fleet_megakernel

        blocks, cols, counts, x = self._fleet()
        host = np.asarray(bsr_spmm_fleet_megakernel(
            blocks, cols, counts, x, bias=-0.2, batch_block=6))
        grid = np.asarray(bsr_spmm_fleet_megakernel(
            blocks, cols, counts, x, bias=-0.2, batch_block=6,
            force_grid=True))
        np.testing.assert_array_equal(host, grid)

    def test_count_bounded_grid_matches_static(self):
        """The compiled-dispatch branch (count-bounded nested fori over
        ``pl.ds`` slices) run under the interpreter on tiny shapes: padding
        blocks are zero, so skipping them must be exact."""
        import functools

        from jax.experimental import pallas as pl

        from repro.kernels.bsr_spmm.bsr_spmm import (
            _fleet_kernel,
            bsr_spmm_fleet_megakernel,
        )

        blocks, cols, counts, x = self._fleet()
        p, nbr, k_max, bm, bn = blocks.shape
        n, b = x.shape[1:]
        want = np.asarray(bsr_spmm_fleet_megakernel(
            blocks, cols, counts, x, bias=-0.2, batch_block=b))
        got = pl.pallas_call(
            functools.partial(_fleet_kernel, bn=bn, k_max=k_max, bias=-0.2,
                              clip=32.0, count_bounded=True),
            grid=(p, 1),
            in_specs=[
                pl.BlockSpec((1, nbr), lambda w, j: (w, 0)),
                pl.BlockSpec((1, nbr, k_max), lambda w, j: (w, 0, 0)),
                pl.BlockSpec((1, nbr, k_max, bm, bn),
                             lambda w, j: (w, 0, 0, 0, 0)),
                pl.BlockSpec((1, n, b), lambda w, j: (w, 0, j)),
            ],
            out_specs=pl.BlockSpec((1, nbr * bm, b), lambda w, j: (w, 0, j)),
            out_shape=jax.ShapeDtypeStruct((p, nbr * bm, b), jnp.float32),
            interpret=True,
        )(counts, cols, blocks, x)
        np.testing.assert_array_equal(np.asarray(got), want)

    def test_matches_per_worker_kernel(self):
        """Each worker's panel through the megakernel equals its standalone
        ``bsr_spmm`` dispatch (same padded operands)."""
        from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_fleet_megakernel

        blocks, cols, counts, x = self._fleet(seed=7)
        y = np.asarray(bsr_spmm_fleet_megakernel(
            blocks, cols, counts, x, bias=-0.1, batch_block=6))
        for w in range(blocks.shape[0]):
            want = bsr_spmm(blocks[w], cols[w], x[w], bias=-0.1,
                            batch_block=6, interpret=True)
            np.testing.assert_allclose(y[w], np.asarray(want),
                                       rtol=1e-6, atol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,KV,S,D", [
        (2, 4, 4, 256, 64),    # MHA
        (2, 8, 2, 256, 64),    # GQA
        (1, 4, 4, 512, 128),   # longer, wide head
    ])
    def test_matches_ref(self, dtype, B, H, KV, S, D):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, H, S, D), dtype)
        k = jax.random.normal(ks[1], (B, KV, S, D), dtype)
        v = jax.random.normal(ks[2], (B, KV, S, D), dtype)
        got = mha(q, k, v, causal=True, block_q=128, block_k=128)
        want = flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **TOL[dtype])

    def test_non_causal(self):
        ks = jax.random.split(jax.random.key(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        got = mha(q, k, v, causal=False)
        want = flash_attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_shape_invariance(self):
        ks = jax.random.split(jax.random.key(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
        a = mha(q, k, v, block_q=128, block_k=128)
        b = mha(q, k, v, block_q=256, block_k=64)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


class TestDecodeAttention:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,KV,S,D,length", [
        (2, 8, 2, 1024, 64, 1000),
        (4, 4, 4, 2048, 128, 2048),
        (1, 16, 2, 512, 64, 77),     # ragged valid prefix
    ])
    def test_matches_ref(self, dtype, B, H, KV, S, D, length):
        ks = jax.random.split(jax.random.key(0), 3)
        q = jax.random.normal(ks[0], (B, H, D), dtype)
        kc = jax.random.normal(ks[1], (B, KV, S, D), dtype)
        vc = jax.random.normal(ks[2], (B, KV, S, D), dtype)
        got_o, got_lse = decode_mha(q, kc, vc, length, block_k=256)
        want_o, want_lse = decode_attention_ref(q, kc, vc, length)
        np.testing.assert_allclose(
            np.asarray(got_o, np.float32), np.asarray(want_o, np.float32),
            **TOL[dtype])
        np.testing.assert_allclose(got_lse, want_lse,
                                   rtol=2e-2 if dtype == jnp.bfloat16 else 1e-4,
                                   atol=2e-2 if dtype == jnp.bfloat16 else 1e-4)

    def test_split_kv_combine_equals_full(self):
        """Sharded partials + lse combine ≡ attention over the full cache."""
        from repro.models.attention import decode_attention as ref_chunked

        ks = jax.random.split(jax.random.key(3), 3)
        B, H, KV, S, D = 2, 4, 2, 1024, 64
        q = jax.random.normal(ks[0], (B, H, D), jnp.float32)
        kc = jax.random.normal(ks[1], (B, KV, S, D), jnp.float32)
        vc = jax.random.normal(ks[2], (B, KV, S, D), jnp.float32)
        full_o, _ = decode_mha(q, kc, vc, S)
        # two halves as if seq-sharded on two devices
        o1, l1 = decode_mha(q, kc[:, :, :512], vc[:, :, :512], 512)
        o2, l2 = decode_mha(q, kc[:, :, 512:], vc[:, :, 512:], 512)
        m = np.maximum(l1, l2)
        w1, w2 = np.exp(l1 - m), np.exp(l2 - m)
        combined = (np.asarray(o1) * w1[..., None] + np.asarray(o2) * w2[..., None]) / (
            (w1 + w2)[..., None])
        np.testing.assert_allclose(combined, full_o, rtol=1e-5, atol=1e-5)


class TestSsdScan:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    @pytest.mark.parametrize("B,H,G,L,P,N,chunk", [
        (2, 4, 1, 256, 32, 16, 64),
        (1, 4, 2, 512, 64, 32, 128),
        (2, 2, 2, 128, 32, 64, 128),   # single chunk
    ])
    def test_matches_ref(self, dtype, B, H, G, L, P, N, chunk):
        ks = jax.random.split(jax.random.key(0), 4)
        x = jax.random.normal(ks[0], (B, H, L, P), dtype)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, L))).astype(jnp.float32)
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, G, L, N), dtype)
        Cm = jax.random.normal(jax.random.key(9), (B, G, L, N), dtype)
        got_y, got_s = ssd(x, dt, A, Bm, Cm, chunk=chunk)
        want_y, want_s = ssd_scan_ref(x, dt, A, Bm, Cm, chunk=chunk)
        tol = TOL[dtype]
        np.testing.assert_allclose(
            np.asarray(got_y, np.float32), np.asarray(want_y, np.float32), **tol)
        np.testing.assert_allclose(
            np.asarray(got_s, np.float32), np.asarray(want_s, np.float32),
            rtol=tol["rtol"] * 5, atol=tol["atol"] * 5)

    def test_state_carry_across_chunks(self):
        """Final state must match a sequential per-token recurrence."""
        B, H, G, L, P, N = 1, 2, 1, 64, 16, 8
        ks = jax.random.split(jax.random.key(7), 4)
        x = jax.random.normal(ks[0], (B, H, L, P), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, L)))
        A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
        Bm = jax.random.normal(ks[3], (B, G, L, N), jnp.float32)
        Cm = jax.random.normal(jax.random.key(8), (B, G, L, N), jnp.float32)
        _, s_kernel = ssd(x, dt, A, Bm, Cm, chunk=32)
        # sequential oracle
        s = np.zeros((B, H, P, N), np.float32)
        for t in range(L):
            a = np.exp(np.asarray(dt[:, :, t]) * np.asarray(A)[None])
            s = s * a[..., None, None] + np.einsum(
                "bh,bn,bhp->bhpn", np.asarray(dt[:, :, t]),
                np.asarray(Bm[:, 0, t]), np.asarray(x[:, :, t]))
        np.testing.assert_allclose(s_kernel, s, rtol=1e-4, atol=1e-4)
