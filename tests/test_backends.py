"""Compute-backend parity: numpy-csr (oracle) ≡ numpy-fast ≡ pallas-bsr.

Covers the kernel layer (per-shard apply, including non-multiple-of-block-size
shapes that exercise BSR padding), the vectorized sparse-container rewrites,
and the ``run_fsi`` end-to-end path on both channels — where billed cost and
FLOP accounting must be identical across backends, not just the outputs.
"""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.backends import BACKEND_NAMES, get_backend
from repro.core.sparse import (
    CSRMatrix,
    bsr_from_csr,
    bsr_from_dense,
    csr_from_dense,
    random_sparse,
)
from repro.data.graphchallenge import (
    dense_inference,
    make_inputs,
    make_sparse_dnn,
    relu_bias_threshold,
)
from repro.faas.simulator import run_fsi

HAVE_JAX = True
try:
    import jax  # noqa: F401
except ModuleNotFoundError:  # pragma: no cover
    HAVE_JAX = False

ALL_BACKENDS = [
    n for n in BACKEND_NAMES if HAVE_JAX or not n.startswith("pallas")
]


def _cases():
    """(W, x) shard cases: uniform butterfly, ragged random, and a
    non-multiple-of-block-size shard (exercises BSR zero-padding)."""
    rng = np.random.default_rng(7)
    net = make_sparse_dnn(256, n_layers=1, seed=0)
    cases = [("butterfly-256", net.layers[0], make_inputs(256, 24, seed=1))]
    ragged = random_sparse(128, 128, 8, rng)
    # knock out some rows entirely → ragged counts (reduceat path)
    d = ragged.to_dense()
    d[::7] = 0.0
    cases.append(("ragged-128", csr_from_dense(d),
                  rng.standard_normal((128, 16)).astype(np.float32)))
    # 100x130 is not a multiple of the (32, 32) block grid in either dim
    odd = random_sparse(100, 130, 5, rng)
    cases.append(("odd-100x130", odd,
                  rng.standard_normal((130, 24)).astype(np.float32)))
    return cases


class TestKernelParity:
    @pytest.mark.parametrize("name,W,x", _cases(), ids=lambda c: c if isinstance(c, str) else "")
    @pytest.mark.parametrize("backend", ALL_BACKENDS)
    def test_apply_matches_oracle(self, backend, name, W, x):
        bias = -0.3
        oracle = relu_bias_threshold(W.matmul_dense_scatter(x), bias)
        be = get_backend(backend)
        got = be.apply(be.prepare(W), x, bias)
        np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-5)

    def test_numpy_fast_matches_scatter_bitwise_uniform(self):
        """Uniform-row bmm path vs scatter: allclose at fp32 (the batched
        matmul may reassociate the k-sum)."""
        net = make_sparse_dnn(256, n_layers=1, seed=2)
        x = make_inputs(256, 32, seed=3)
        a = net.layers[0].matmul_dense_scatter(x)
        b = net.layers[0].matmul_dense_fast(x)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_empty_and_zero_row_edges(self):
        empty = CSRMatrix(
            shape=(4, 8),
            indptr=np.zeros(5, np.int64),
            indices=np.zeros(0, np.int32),
            data=np.zeros(0, np.float32),
        )
        x = np.ones((8, 3), np.float32)
        assert empty.matmul_dense_fast(x).shape == (4, 3)
        assert np.all(empty.matmul_dense_fast(x) == 0)
        for backend in ALL_BACKENDS:
            be = get_backend(backend)
            y = be.apply(be.prepare(empty), x, -0.5)
            np.testing.assert_allclose(y, np.zeros((4, 3)))

    @pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
    def test_fleet_apply_matches_per_worker(self):
        """One stacked vmap dispatch ≡ P independent dispatches."""
        rng = np.random.default_rng(11)
        be = get_backend("pallas-bsr")
        shards = [random_sparse(64 + 32 * i, 96, 6, rng) for i in range(3)]
        states = [be.prepare(W) for W in shards]
        xs = [rng.standard_normal((W.ncols, 16)).astype(np.float32)
              for W in shards]
        fleet = be.fleet_prepare_all([states])
        got = be.fleet_apply(fleet[0], xs, -0.3)
        for W, st, x, y in zip(shards, states, xs, got):
            np.testing.assert_allclose(
                y, be.apply(st, x, -0.3), rtol=1e-5, atol=1e-5
            )
            assert y.shape == (W.nrows, 16)

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="unknown compute backend"):
            get_backend("cuda-cusparse")

    @pytest.mark.skipif(not HAVE_JAX, reason="jax not installed")
    def test_state_cache_keyed_by_config(self):
        """Two differently-configured pallas backends must not share cached
        per-artifact states (keys include block shape / interpret / clip)."""
        from repro.core.backends import PallasBsrBackend

        a = PallasBsrBackend(block_shape=(32, 32))
        b = PallasBsrBackend(block_shape=(16, 16))
        assert a.state_key != b.state_key
        assert get_backend("numpy-fast").state_key == "numpy-fast"


class TestVectorizedContainers:
    """The rewritten select_rows / padded must equal the naive formulations."""

    def test_select_rows_matches_naive(self):
        rng = np.random.default_rng(0)
        d = rng.standard_normal((64, 48))
        d[np.abs(d) < 1.0] = 0.0
        csr = csr_from_dense(d.astype(np.float32))
        rows = np.array([3, 0, 17, 17, 63, 41])
        sub = csr.select_rows(rows)
        np.testing.assert_allclose(sub.to_dense(), d[rows].astype(np.float32))
        empty = csr.select_rows(np.zeros(0, np.int64))
        assert empty.shape == (0, 48) and empty.nnz == 0

    def test_ragged_blocked_reduceat_matches_scatter(self):
        """The batch-tiled ragged path (bounded [nnz, bt] contrib panels)
        must equal the scatter oracle for every tile width, including tiles
        that do not divide the batch."""
        rng = np.random.default_rng(5)
        ragged = random_sparse(96, 96, 9, rng)
        d = ragged.to_dense()
        d[::5] = 0.0  # empty rows → ragged counts, reduceat path
        csr = csr_from_dense(d)
        x = rng.standard_normal((96, 40)).astype(np.float32)
        oracle = csr.matmul_dense_scatter(x)
        # tile_elems below nnz → bt=1; mid sizes → several tiles; huge → one
        for tile_elems in (1, csr.nnz * 3, csr.nnz * 7, 1 << 22):
            got = csr.matmul_dense_fast(x, tile_elems=tile_elems)
            np.testing.assert_allclose(got, oracle, rtol=1e-6, atol=1e-6,
                                       err_msg=f"tile_elems={tile_elems}")

    @settings(max_examples=25, deadline=None)
    @given(
        nrows=st.integers(min_value=0, max_value=70),
        ncols=st.integers(min_value=1, max_value=70),
        density_pct=st.integers(min_value=0, max_value=30),
        block=st.sampled_from([(4, 4), (8, 16), (3, 5), (32, 32)]),
        pad=st.booleans(),
        seed=st.integers(min_value=0, max_value=9999),
    )
    def test_property_bsr_from_csr_roundtrip(self, nrows, ncols, density_pct,
                                             block, pad, seed):
        """CSR→BSR→dense ≡ the (zero-padded) dense oracle, and the
        coordinate-built structure is identical to the densify path —
        without ever materializing the dense matrix (the N=65536 fleet-prep
        bottleneck).  Hypothesis sweeps empty matrices, empty rows, and
        shapes from sub-single-block up to many blocks."""
        rng = np.random.default_rng(seed)
        dense = rng.standard_normal((nrows, ncols)).astype(np.float32)
        dense[rng.random((nrows, ncols)) >= density_pct / 100.0] = 0.0
        dense[::3] = 0.0                       # guaranteed empty rows
        csr = csr_from_dense(dense)
        bm, bn = block
        if not pad and (nrows % bm or ncols % bn):
            with pytest.raises(ValueError, match="not divisible"):
                bsr_from_csr(csr, block, pad=False)
            return
        bsr = bsr_from_csr(csr, block, pad=pad)
        mp = -(-max(nrows, 1) // bm) * bm if pad else nrows
        np_ = -(-max(ncols, 1) // bn) * bn if pad else ncols
        oracle = np.zeros((mp, np_), np.float32)
        oracle[:nrows, :ncols] = dense
        np.testing.assert_array_equal(bsr.to_dense(), oracle)
        # structure parity vs the old to_dense round-trip
        ref = bsr_from_dense(oracle, block)
        np.testing.assert_array_equal(bsr.indptr, ref.indptr)
        np.testing.assert_array_equal(bsr.indices, ref.indices)
        np.testing.assert_array_equal(bsr.blocks, ref.blocks)

    def test_bsr_from_csr_single_block_and_empty_edges(self):
        # single dense block, exactly one block wide/tall
        dense = np.arange(16, dtype=np.float32).reshape(4, 4)
        bsr = bsr_from_csr(csr_from_dense(dense), (4, 4))
        assert bsr.n_blocks == 1 and bsr.indices.tolist() == [0]
        np.testing.assert_array_equal(bsr.to_dense(), dense)
        # fully empty matrix (0 rows) pads to one all-zero block grid
        empty = CSRMatrix(shape=(0, 5), indptr=np.zeros(1, np.int64),
                          indices=np.zeros(0, np.int32),
                          data=np.zeros(0, np.float32))
        b = bsr_from_csr(empty, (4, 4), pad=True)
        assert b.shape == (4, 8) and b.n_blocks == 0
        np.testing.assert_array_equal(b.to_dense(), np.zeros((4, 8)))

    def test_padded_matches_naive(self):
        rng = np.random.default_rng(1)
        csr = random_sparse(128, 128, 8, rng)
        bsr = bsr_from_dense(csr.to_dense(), (32, 32))
        blocks, cols, counts = bsr.padded()
        # reconstruct and compare against the unpadded dense matrix
        recon = np.zeros(bsr.shape, np.float32)
        for br in range(bsr.n_block_rows):
            for j in range(int(counts[br])):
                c = int(cols[br, j])
                recon[br * 32:(br + 1) * 32, c * 32:(c + 1) * 32] += blocks[br, j]
        np.testing.assert_allclose(recon, csr.to_dense())
        assert blocks.shape[1] == int(counts.max())


class TestEndToEndParity:
    @pytest.fixture(scope="class")
    def case(self):
        net = make_sparse_dnn(256, n_layers=8, seed=0)
        x0 = make_inputs(256, 24, seed=1)
        return net, x0, dense_inference(net, x0)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    def test_run_fsi_backend_parity(self, case, channel):
        net, x0, oracle = case
        results = {
            b: run_fsi(net, x0, P=4, channel=channel, memory_mb=4000,
                       compute_backend=b)
            for b in ALL_BACKENDS
        }
        ref = results["numpy-csr"]
        # 8 stacked layers of fp32 with different-but-valid summation orders
        # (scatter vs batched-matmul vs block tiles) drift past 1e-5
        np.testing.assert_allclose(ref.output, oracle, rtol=1e-4, atol=1e-4)
        for b, r in results.items():
            np.testing.assert_allclose(r.output, ref.output,
                                       rtol=1e-4, atol=1e-4, err_msg=b)
            # billed accounting is backend-invariant where it is determined
            # by the algorithm: identical FLOPs, identical messages, and an
            # identical PRE-compression exchange volume (same rows survive
            # activation-sparsity pruning).  Wire bytes — and anything
            # quantized over them: publish batching, per-64KB billing units —
            # may wiggle: zlib compresses the slightly different fp32 bit
            # patterns of each backend's sums differently.
            assert r.metrics["flops_total"] == ref.metrics["flops_total"], b
            assert r.metrics.get("messages") == ref.metrics.get("messages"), b
            assert r.raw_exchange_bytes == ref.raw_exchange_bytes, b
            assert r.cost.total == pytest.approx(ref.cost.total, rel=0.05), b
            np.testing.assert_allclose(r.worker_times, ref.worker_times,
                                       rtol=2e-2, err_msg=b)

    def test_serial_backend_parity(self, case):
        net, x0, oracle = case
        ref = run_fsi(net, x0, channel="serial", compute_backend="numpy-csr")
        for b in ALL_BACKENDS:
            r = run_fsi(net, x0, channel="serial", compute_backend=b)
            np.testing.assert_allclose(r.output, oracle, rtol=1e-4, atol=1e-4)
            # serial has no channel: billed cost is pure compute+invocation,
            # so it must match the oracle backend exactly
            assert r.metrics["flops"] == ref.metrics["flops"], b
            assert r.cost.total == pytest.approx(ref.cost.total, rel=1e-12), b
