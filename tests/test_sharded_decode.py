"""Cross-family sharded-decode parity suite (PR 4 gate).

The canonical KV-cache layout is kernel-native ``[B, KV, S, D]`` with the
capacity padded to a ``block_k`` multiple at prefill
(``repro.core.backends.KVCacheLayout``), and every decoding family's
``decode_step`` grew a real sequence-sharded branch: inside a ``shard_map``
binding ``seq_shard_axes`` over the cache's S dim, each shard inserts the
new token's KV iff it owns the global position, runs the attention backend's
split-KV form (``decode_partial`` → ``(out, lse)``) over its local slice,
and shards lse-combine via ``combine_split_kv``.  This file gates all of it:

* **op-level sharded parity** — insert + ``decode_partial`` + combine over
  1/2/4 shards vs the replicated dense oracle, pure fp32: measured
  ulp-exact (≤ 2e-7), asserted at 1e-5 — this is the numerical gate;
* **model-level sharded parity** — every attention backend × all four
  decoding families × 1/2/4 host devices × ragged ``cache_len`` edges
  (including the non-``block_k``-divisible requested capacity), checked
  against the single-device ``dense-ref`` ``decode_step``: 1e-4 at one
  shard (PR 2's fp32-cache envelope), 2e-2 beyond (reordered fp32 partial
  sums round differently through bf16 activations), bf16 caches at 3e-2,
  plus ulp-tolerance reassembly of the updated cache shards;
* **no-relayout jaxpr assertion** — the jitted ``pallas-splitk`` decode step
  must contain no ``transpose``/``moveaxis``/``pad`` op on a KV-cache-sized
  operand (the re-layout PR 4 deleted), with a self-test proving the
  detector catches exactly that pattern;
* **jit bucket behavior** — growing ``cache_len`` inside one padded bucket
  never retraces; crossing into a new bucket retraces exactly once;
* **combine_split_kv shard-count invariance** — 1 vs 2 vs 4 splits of the
  same cache agree to fp32 ulp-level (the merge is associative in exact
  arithmetic; observed differences are ≤ ~2 ulp, asserted at 1e-5),
  mirroring PR 2's kv_chunk-invariance property tests.

Multi-device meshes come from ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
(the CI ``host-mesh-4`` matrix entry); the ``pytest.mark.mesh`` subprocess
sweep forces its own 4-device platform so the 2- and 4-shard paths are
covered even from a single-device parent process.
"""

import os
import subprocess
import sys
import textwrap

import pytest

pytest.importorskip("jax")  # accelerator dep is optional for the numpy core

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.backends import (
    ATTENTION_BACKEND_NAMES,
    ChunkedLseAttention,
    KVCacheLayout,
    PallasSplitKAttention,
    get_backend,
)
from repro.distributed.sharding import shard_map_compat
from repro.launch.mesh import make_mesh
from repro.models import encdec, hybrid, moe, transformer
from repro.models.registry import get_model, input_specs

BLOCK_K = 4                  # tiny kernel block so 4-way shards stay legal
CAP_REQ = 13                 # requested capacity — NOT a block_k multiple
LAYOUT = KVCacheLayout(block_k=BLOCK_K)
CAP = LAYOUT.padded_len(CAP_REQ)          # 16
AXIS = "seq"

FAMILY_MODS = {
    "transformer": ("internlm2-1.8b", transformer),
    "moe": ("deepseek-moe-16b", moe),
    "hybrid": ("zamba2-7b", hybrid),
    "encdec": ("seamless-m4t-medium", encdec),
}

# PR 2 tolerances: with an fp32 cache all backends produce ulp-identical
# logits (1e-4 leaves platform headroom); a bf16 cache rounds the
# probability row at backend-dependent points → 3e-2.
FAMILY_TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
              jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}
# Multi-shard decode genuinely reorders the fp32 softmax partial sums
# (measured ulp-exact at op level — TestShardedOpParity asserts ≤1e-5);
# through a model with bf16 activations a 1-ulp fp32 difference can flip a
# bf16 rounding and compound across layers — and the MoE router amplifies
# worst-case rows to ~2.3e-2, the same mechanism PR 2 pinned for bf16
# caches — so model-level logits get the 3e-2 envelope once d > 1.
SHARDED_MODEL_TOL = dict(rtol=3e-2, atol=3e-2)


def _backend(name):
    if name == "pallas-splitk":
        return PallasSplitKAttention(block_k=BLOCK_K)
    if name == "chunked-lse":
        return ChunkedLseAttention(kv_chunk=3)    # non-divisor chunk
    return get_backend("attention", name)


def _edge_cache_lens():
    """Ragged valid-prefix edges inside the padded CAP=16 bucket: around the
    block_k boundary, the unpadded requested capacity, and the last slot."""
    return (1, BLOCK_K - 1, BLOCK_K, BLOCK_K + 1, CAP_REQ, CAP - 1)


def _family_fixture(family):
    import dataclasses

    arch, mod = FAMILY_MODS[family]
    cfg = get_config(arch).reduced()
    if family == "moe":
        # MoE routing is discontinuous: the splitk kernel's per-shard
        # partials differ from the dense partial at fp32 ulp level
        # (blockwise running-max vs one global max), and when a routing
        # score sits within an ulp of the top-k boundary the flip swaps an
        # expert — order-1 logit jumps that have nothing to do with
        # attention parity (greedy argmax stays equal).  Disable capacity
        # drops (like test_models_smoke's teacher-forcing equivalence) and
        # use an init seed whose routing scores sit away from the boundary
        # (key 0 has a near-tie: 0.196 worst-case vs 0.017 at key 1).
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.n_experts))
    model = get_model(cfg)
    params = model.init(jax.random.key(1 if family == "moe" else 0))
    shape = ShapeConfig("smoke", 8, 2, "prefill")
    batch = input_specs(cfg, shape, abstract=False, seed=0)

    if family == "transformer":
        pre = lambda p, b: transformer.prefill(p, b["tokens"], cfg, CAP_REQ,
                                               layout=LAYOUT)
    elif family == "moe":
        pre = lambda p, b: moe.prefill(p, b["tokens"], cfg, CAP_REQ, 1,
                                       layout=LAYOUT)
    elif family == "hybrid":
        pre = lambda p, b: hybrid.prefill(p, b["tokens"], cfg, CAP_REQ,
                                          layout=LAYOUT)
    else:
        pre = lambda p, b: encdec.prefill(p, b, cfg, CAP_REQ, layout=LAYOUT)
    logits, cache = jax.jit(pre)(params, batch)
    token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return cfg, mod, params, token, cache


@pytest.fixture(scope="module", params=sorted(FAMILY_MODS))
def family_case(request):
    return request.param, _family_fixture(request.param)


def _cache_shard_specs(cache):
    """PartitionSpec tree sharding every *growing* KV buffer's S dim over
    AXIS; cross-attention caches, SSM states and scalars stay replicated."""
    def spec(path, leaf):
        names = [str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        nd = getattr(leaf, "ndim", 0)
        if nd >= 4 and (name in ("k", "v") or "kv" in names
                        or "tail_kv" in names) and "kc" != name != "vc":
            return P(*([None] * (nd - 2)), AXIS, None)
        return P()
    return jax.tree_util.tree_map_with_path(spec, cache)


def _sharded_decode_fn(mod, cfg, be, mesh, cache):
    cspecs = _cache_shard_specs(cache)
    body = lambda p, t, c: mod.decode_step(p, t, c, cfg, attn_backend=be,
                                           seq_shard_axes=AXIS)
    return jax.jit(shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(), P(), cspecs),
        out_specs=(P(), cspecs),
    ))


def _cache_as(cache, dtype):
    cast = (lambda a: a.astype(dtype)
            if hasattr(a, "dtype") and a.dtype == jnp.bfloat16 else a)
    return jax.tree.map(cast, cache)


def _device_counts():
    return [d for d in (1, 2, 4) if d <= len(jax.devices())]


# ---------------------------------------------------------------------------
# op-level sharded parity: insert + decode_partial + combine ≡ dense oracle
# ---------------------------------------------------------------------------


class TestShardedOpParity:
    """The numerical core, isolated from model weights: shard-local token
    insert + ``decode_partial`` + ``combine_split_kv`` over 1/2/4 shards
    must reproduce the replicated dense decode to fp32 ulp-level (measured
    ≤ 2e-7; asserted at 1e-5) at every insert position, including shards
    whose local valid prefix is empty."""

    @pytest.mark.parametrize("backend", ATTENTION_BACKEND_NAMES)
    def test_sharded_combine_matches_dense(self, backend):
        from repro.models.attention import (
            decode_attention_dense, sharded_decode_attend)

        rng = np.random.default_rng(0)
        B, H, KV, S, D = 2, 4, 2, CAP, 8
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        k_new = jnp.asarray(rng.standard_normal((B, KV, 1, D)), jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((B, KV, 1, D)), jnp.float32)
        be = _backend(backend)

        def body(q, k, v, pos):
            # the exact production recipe the families/bench dispatch
            o, _, _ = sharded_decode_attend(be, q, k_new, v_new, k, v, pos,
                                            AXIS)
            return o

        kv_spec = P(None, None, AXIS, None)
        for d in _device_counts():
            mesh = make_mesh((d,), (AXIS,))
            f = jax.jit(shard_map_compat(
                body, mesh=mesh,
                in_specs=(P(), kv_spec, kv_spec, P()),
                out_specs=P()))
            for pos_i in (0, BLOCK_K - 1, BLOCK_K, CAP_REQ - 1, CAP - 1):
                pos = jnp.asarray(pos_i, jnp.int32)
                kr = jax.lax.dynamic_update_slice(k, k_new, (0, 0, pos, 0))
                vr = jax.lax.dynamic_update_slice(v, v_new, (0, 0, pos, 0))
                ref = decode_attention_dense(q, kr, vr, pos + 1)
                got = f(q, k, v, pos)
                np.testing.assert_allclose(
                    np.asarray(got, np.float32), np.asarray(ref, np.float32),
                    rtol=1e-5, atol=1e-5,
                    err_msg=f"{backend} d={d} pos={pos_i}")


# ---------------------------------------------------------------------------
# sharded parity: backends × families × device counts × ragged cache_len
# ---------------------------------------------------------------------------


class TestShardedDecodeParity:
    @pytest.mark.parametrize("backend", ATTENTION_BACKEND_NAMES)
    def test_matches_single_device_dense_ref(self, family_case, backend):
        family, (cfg, mod, params, token, cache) = family_case
        # fp32 cache for the tight-tolerance sweep (PR 2: a bf16 cache
        # rounds the probability row at backend-dependent points — that
        # dtype axis is covered at 3e-2 below)
        cache = _cache_as(cache, jnp.float32)
        ref_fn = jax.jit(lambda p, t, c: mod.decode_step(
            p, t, c, cfg, attn_backend=get_backend("attention", "dense-ref")))
        for d in _device_counts():
            mesh = make_mesh((d,), (AXIS,))
            got_fn = _sharded_decode_fn(mod, cfg, _backend(backend), mesh,
                                        cache)
            for cache_len in _edge_cache_lens():
                c = dict(cache, length=jnp.asarray(cache_len, jnp.int32))
                ref_logits, ref_cache = ref_fn(params, token, c)
                got_logits, got_cache = got_fn(params, token, c)
                tol = (FAMILY_TOL[jnp.float32] if d == 1
                       else SHARDED_MODEL_TOL)
                np.testing.assert_allclose(
                    np.asarray(got_logits, np.float32),
                    np.asarray(ref_logits, np.float32),
                    err_msg=f"{family}/{backend} d={d} len={cache_len}",
                    **tol)
                assert int(got_cache["length"]) == cache_len + 1
                # shard-local inserts reassemble to the replicated update.
                # This guards token *placement*: a wrong shard/offset puts
                # whole [KV, D] rows of order-1 values into zero slots, far
                # outside the band.  The band itself must absorb per-element
                # drift — inserted K/V derive from bf16 activations whose
                # fp32 partial sums reorder across shards, and a late-layer
                # element can wander a few bf16 ulps (measured ≤ 0.034).
                for leaf_ref, leaf_got in zip(
                        jax.tree.leaves(ref_cache), jax.tree.leaves(got_cache)):
                    np.testing.assert_allclose(
                        np.asarray(leaf_got, np.float32),
                        np.asarray(leaf_ref, np.float32),
                        **(dict(rtol=1e-2, atol=1e-2) if d == 1
                           else dict(rtol=0.1, atol=0.1)),
                        err_msg=f"{family}/{backend} d={d} cache reassembly")

    def test_splitk_bf16_cache_within_tolerance(self, family_case):
        """The acceptance dtype sweep: a bf16 cache through the sharded
        splitk path stays within the PR 2 bf16 envelope vs dense-ref."""
        family, (cfg, mod, params, token, cache) = family_case
        base = _cache_as(cache, jnp.bfloat16)
        ref_fn = jax.jit(lambda p, t, c: mod.decode_step(
            p, t, c, cfg, attn_backend=get_backend("attention", "dense-ref")))
        for d in _device_counts():
            mesh = make_mesh((d,), (AXIS,))
            got_fn = _sharded_decode_fn(mod, cfg, _backend("pallas-splitk"),
                                        mesh, base)
            for cache_len in (1, BLOCK_K, CAP_REQ):
                c = dict(base, length=jnp.asarray(cache_len, jnp.int32))
                ref_logits, _ = ref_fn(params, token, c)
                got_logits, _ = got_fn(params, token, c)
                np.testing.assert_allclose(
                    np.asarray(got_logits, np.float32),
                    np.asarray(ref_logits, np.float32),
                    err_msg=f"{family} bf16 d={d} len={cache_len}",
                    **FAMILY_TOL[jnp.bfloat16])

    def test_prefill_capacity_is_layout_padded(self, family_case):
        family, (cfg, mod, params, token, cache) = family_case
        k = (cache["stacks"][-1]["k"] if family == "moe"
             else cache["kv"][0] if family == "hybrid" else cache["k"])
        assert k.shape[3] == CAP, (family, k.shape)
        if family == "encdec":  # cross cache padded under the same rule
            assert cache["kc"].shape[3] % BLOCK_K == 0
            assert int(cache["src_length"]) == cfg.frontend_tokens


# ---------------------------------------------------------------------------
# jaxpr assertion: the per-step re-layout is really gone
# ---------------------------------------------------------------------------


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for sub in vs:
                inner = getattr(sub, "jaxpr", sub)
                if hasattr(inner, "eqns"):
                    yield from _iter_eqns(inner)


def _cache_relayout_eqns(jaxpr, seq_cap):
    """transpose/pad equations whose operand looks like a KV-cache slice
    (≥4-D with the cache capacity as a dimension)."""
    bad = []
    for eqn in _iter_eqns(jaxpr):
        if eqn.primitive.name not in ("transpose", "pad"):
            continue
        aval = eqn.invars[0].aval
        if getattr(aval, "ndim", 0) >= 4 and seq_cap in aval.shape:
            bad.append(eqn)
    return bad


class TestNoPerStepRelayout:
    def test_detector_catches_relayout(self):
        """Self-test: the detector flags exactly the moveaxis+pad pattern
        the old PallasSplitKAttention.decode used."""
        k = jnp.zeros((2, CAP, 2, 8))

        def old_style(k):
            kT = jnp.moveaxis(k, 1, 2)
            return jnp.pad(kT, ((0, 0), (0, 0), (0, 3), (0, 0)))

        jaxpr = jax.make_jaxpr(old_style)(k)
        assert len(_cache_relayout_eqns(jaxpr.jaxpr, CAP)) == 2

    def test_splitk_decode_jaxpr_has_no_cache_relayout(self, family_case):
        family, (cfg, mod, params, token, cache) = family_case
        be = _backend("pallas-splitk")
        jaxpr = jax.make_jaxpr(
            lambda p, t, c: mod.decode_step(p, t, c, cfg, attn_backend=be)
        )(params, token, cache)
        bad = _cache_relayout_eqns(jaxpr.jaxpr, CAP)
        assert not bad, (
            f"{family}: per-step KV-cache re-layout reappeared in the "
            f"splitk decode path: {[str(e) for e in bad]}")


# ---------------------------------------------------------------------------
# jit bucket behavior: no retrace within a padded bucket
# ---------------------------------------------------------------------------


class TestPaddedBucketRetrace:
    def test_retrace_only_on_bucket_growth(self):
        cfg = get_config("internlm2-1.8b").reduced()
        model = get_model(cfg,
                          attn_backend=PallasSplitKAttention(block_k=BLOCK_K))
        params = model.init(jax.random.key(0))
        shape = ShapeConfig("smoke", 8, 2, "prefill")
        batch = input_specs(cfg, shape, abstract=False, seed=0)
        prefill = jax.jit(model.prefill, static_argnums=(2,))
        decode = jax.jit(lambda p, t, c: model.decode_step(p, t, c))

        logits, cache = prefill(params, batch, CAP_REQ)      # bucket: CAP=16
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits, cache = decode(params, token, cache)
        n0 = decode._cache_size()
        for _ in range(4):                                    # length grows
            logits, cache = decode(params, token, cache)
        assert decode._cache_size() == n0, "retraced within one bucket"

        # a different max_len in the SAME bucket → same padded shapes → hit
        _, cache14 = prefill(params, batch, CAP_REQ + 1)      # pads to 16 too
        decode(params, token, cache14)
        assert decode._cache_size() == n0, "same-bucket capacity retraced"

        # crossing the bucket boundary → exactly one new trace
        _, cache17 = prefill(params, batch, CAP + 1)          # pads to 20
        decode(params, token, cache17)
        assert decode._cache_size() == n0 + 1, "bucket growth must retrace once"
        decode(params, token, cache17)
        assert decode._cache_size() == n0 + 1


# ---------------------------------------------------------------------------
# combine_split_kv: shard-count invariance (property)
# ---------------------------------------------------------------------------


class TestCombineSplitKvInvariance:
    @settings(max_examples=15, deadline=None)
    @given(cache_len=st.integers(min_value=1, max_value=16),
           seed=st.integers(min_value=0, max_value=9999))
    def test_fp32_output_invariant_to_shard_count(self, cache_len, seed):
        """Splitting one cache into 1/2/4 KV shards and lse-merging the
        partials is the same softmax re-tiled: fp32 outputs agree to
        ulp-level (≤ ~2 ulp observed; asserted at 1e-5) and every split
        count matches the unsharded dense oracle."""
        from repro.models.attention import (
            combine_split_kv_stacked, decode_attention_dense)

        rng = np.random.default_rng(seed)
        B, H, KV, S, D = 2, 4, 2, 16, 8
        q = jnp.asarray(rng.standard_normal((B, 1, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, KV, S, D)), jnp.float32)

        def combined(n):
            sl = S // n
            outs, lses = [], []
            for i in range(n):
                local_len = np.clip(cache_len - i * sl, 0, sl)
                o, l = decode_attention_dense(
                    q, k[:, :, i * sl:(i + 1) * sl],
                    v[:, :, i * sl:(i + 1) * sl],
                    jnp.asarray(local_len), return_lse=True)
                outs.append(o)
                lses.append(l)
            return combine_split_kv_stacked(jnp.stack(outs), jnp.stack(lses))

        r1, r2, r4 = combined(1), combined(2), combined(4)
        oracle = decode_attention_dense(q, k, v, cache_len)
        for name, r in (("n=1", r1), ("n=2", r2), ("n=4", r4)):
            np.testing.assert_allclose(np.asarray(r), np.asarray(r2),
                                       rtol=1e-5, atol=1e-5, err_msg=name)
            np.testing.assert_allclose(np.asarray(r, np.float32),
                                       np.asarray(oracle, np.float32),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{name} vs dense oracle")


# ---------------------------------------------------------------------------
# forced 4-device subprocess sweep (CI host-mesh-4 / `-m mesh`)
# ---------------------------------------------------------------------------


@pytest.mark.mesh
@pytest.mark.slow
def test_multi_device_sharded_decode_parity():
    """Forced 4-device host platform: transformer decode through the
    sharded splitk branch over 1/2/4-device meshes vs single-device
    dense-ref, at ragged cache_len edges — covers the multi-shard
    combine even when the parent pytest process has one device."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.core.backends import (
            KVCacheLayout, PallasSplitKAttention, get_backend)
        from repro.distributed.sharding import shard_map_compat
        from repro.launch.mesh import make_mesh
        from repro.models import transformer
        from repro.models.registry import get_model, input_specs

        assert len(jax.devices()) == 4, jax.devices()
        BLOCK_K, CAP_REQ, AXIS = 4, 13, "seq"
        layout = KVCacheLayout(block_k=BLOCK_K)
        cfg = get_config("internlm2-1.8b").reduced()
        model = get_model(cfg)
        params = model.init(jax.random.key(0))
        batch = input_specs(cfg, ShapeConfig("smoke", 8, 2, "prefill"),
                            abstract=False, seed=0)
        logits, cache = jax.jit(lambda p, b: transformer.prefill(
            p, b["tokens"], cfg, CAP_REQ, layout=layout))(params, batch)
        cache = jax.tree.map(
            lambda a: a.astype(jnp.float32)
            if hasattr(a, "dtype") and a.dtype == jnp.bfloat16 else a, cache)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        be = PallasSplitKAttention(block_k=BLOCK_K)
        ref_fn = jax.jit(lambda p, t, c: transformer.decode_step(
            p, t, c, cfg, attn_backend=get_backend("attention", "dense-ref")))
        kv_spec = P(None, None, None, AXIS, None)
        cspec = {"k": kv_spec, "v": kv_spec, "length": P()}
        for d in (1, 2, 4):
            mesh = make_mesh((d,), (AXIS,))
            got_fn = jax.jit(shard_map_compat(
                lambda p, t, c: transformer.decode_step(
                    p, t, c, cfg, attn_backend=be, seq_shard_axes=AXIS),
                mesh=mesh, in_specs=(P(), P(), cspec),
                out_specs=(P(), cspec)))
            # d>1 reorders fp32 partial sums; through bf16 activations the
            # logits get the 2e-2 envelope (op-level parity is ulp-exact)
            tol = 1e-4 if d == 1 else 2e-2
            for cache_len in (1, 3, 4, 5, 13, 15):
                c = dict(cache, length=jnp.asarray(cache_len, jnp.int32))
                ref, ref_cache = ref_fn(params, token, c)
                got, got_cache = got_fn(params, token, c)
                assert np.allclose(np.asarray(got, np.float32),
                                   np.asarray(ref, np.float32),
                                   rtol=tol, atol=tol), (d, cache_len)
                ktol = 1e-2 if d == 1 else 1e-1
                assert np.allclose(
                    np.asarray(got_cache["k"], np.float32),
                    np.asarray(ref_cache["k"], np.float32),
                    rtol=ktol, atol=ktol), (d, cache_len)
        print("SHARDED_DECODE_OK")
    """)
    pythonpath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH", "")) if p
    )
    env = dict(os.environ, PYTHONPATH=pythonpath)
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert "SHARDED_DECODE_OK" in out.stdout, out.stderr[-3000:]
