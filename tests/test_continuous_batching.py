"""Continuous batching: differential scheduler parity + paged-pool properties.

The contract under test (PR 8): every request served through the
continuous-batching scheduler (``ServingEngine.generate_stream`` /
``serving.scheduler.RequestScheduler``) produces tokens and final-step
logits **bitwise equal** to the same request served alone through the static
``generate`` oracle at equal cache capacity — across attention backends ×
model families × ragged prompt lengths × staggered arrival orders.  The
bitwise bar holds because vmap-of-B=1 decode is bit-identical to solo B=1
decode under XLA, and masked cache positions contribute exactly +0.0
regardless of the stale values reused pool pages hold.

Also here:
* hypothesis property tests for ``BlockAllocator``/``KVBlockPool`` (no
  double allocation, no freed-page reads, pool drains to empty; block-table
  → flat-cache round-trip exact);
* the no-retrace regression: admissions/retirements inside one slot bucket
  never recompile the jitted decode step (PR 4 ``_cache_size`` harness),
  with a detector self-test;
* scheduler beats the padded-static-batch baseline on slot-step efficiency
  for ragged streams (deterministic step counts, the quantity the
  ``serving_cb_*`` bench rows gate);
* a mesh-marked forced-4-device sweep of the sequence-sharded scheduler
  (``make test-mesh``).
"""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core.backends import (
    ChunkedLseAttention, KVCacheLayout, PallasSplitKAttention)
from repro.models.registry import cache_specs, get_model
from repro.configs.base import ShapeConfig
from repro.serving.engine import ServingEngine
from repro.serving.kv_pool import (
    BlockAllocator, KVBlockPool, PoolExhausted, RESERVED_BLOCKS, SINK_BLOCK,
    split_cache)
from repro.serving.scheduler import Request, RequestScheduler

BLOCK_K = 4          # tiny kernel block so pool pages + 4-way shards stay legal
NUM_SLOTS = 2

FAMILY_ARCHS = {
    "transformer": "internlm2-1.8b",
    "moe": "deepseek-moe-16b",
    "hybrid": "zamba2-7b",
    "encdec": "seamless-m4t-medium",
}

# backends per family: the dense transformer sweeps all three; the heavier
# families get the oracle + the compiled kernel (chunked-lse shares the
# vmap-level bitwise proof with dense-ref).
BACKENDS = {
    "transformer": ("dense-ref", "chunked-lse", "pallas-splitk"),
    "moe": ("dense-ref", "pallas-splitk"),
    "hybrid": ("dense-ref", "pallas-splitk"),
    "encdec": ("dense-ref", "chunked-lse", "pallas-splitk"),
}


def _backend(name):
    if name == "pallas-splitk":
        return PallasSplitKAttention(block_k=BLOCK_K)
    if name == "chunked-lse":
        return ChunkedLseAttention(kv_chunk=3)
    return name                      # "dense-ref" via the registry


def _family_cfg(family):
    cfg = get_config(FAMILY_ARCHS[family]).reduced()
    if family == "moe":
        # disable capacity drops + pick a routing-tie-free init (same
        # reasoning as tests/test_sharded_decode.py)
        cfg = dataclasses.replace(cfg,
                                  moe_capacity_factor=float(cfg.n_experts))
    return cfg


def _mk_requests(cfg, rng, n, arrivals):
    """Ragged prompts (2..7) and budgets (1..4) with per-family extras."""
    reqs = []
    for i in range(n):
        extra = None
        if cfg.family == "vlm":
            extra = {"extra_embeds": rng.standard_normal(
                (1, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        elif cfg.family == "encdec":
            extra = {"frames": rng.standard_normal(
                (1, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)}
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                (int(rng.integers(2, 8)),)).astype(np.int32),
            max_new_tokens=int(rng.integers(1, 5)),
            extra=extra,
            arrival=int(arrivals[i]),
        ))
    return reqs


def _stream_capacity(eng, reqs):
    need = max(np.asarray(r.prompt).reshape(-1).shape[0] + r.max_new_tokens
               + (eng.cfg.frontend_tokens or 0) for r in reqs)
    return eng.cache_layout(need).padded_len(need)


ENGINE_CASES = [(fam, be) for fam in FAMILY_ARCHS for be in BACKENDS[fam]]


@pytest.fixture(scope="module", params=ENGINE_CASES,
                ids=[f"{f}-{b}" for f, b in ENGINE_CASES])
def diff_case(request):
    """(engine, requests, oracle) for one family × backend cell.

    The oracle result per request is the static ``generate`` at
    ``max_len = slot capacity`` — the scheduler and the oracle then run the
    same reduction shapes, which is what makes bitwise comparison fair."""
    family, backend = request.param
    cfg = _family_cfg(family)
    eng = ServingEngine(cfg, seed=1 if family == "moe" else 0,
                        attn_backend=_backend(backend))
    rng = np.random.default_rng(7)
    reqs = _mk_requests(cfg, rng, 4, arrivals=np.zeros(4, int))
    cap = _stream_capacity(eng, reqs)
    oracle = {}
    for r in reqs:
        ref = eng.generate(np.asarray(r.prompt)[None], r.max_new_tokens,
                           extra=r.extra, max_len=cap)
        oracle[r.rid] = (ref.tokens[0], ref.prefill_logits[0])
    return eng, reqs, cap, oracle


ARRIVAL_ORDERS = {
    "together": lambda n: [0] * n,
    "staggered": lambda n: list(range(n)),
    "reversed": lambda n: list(range(n - 1, -1, -1)),
}


class TestDifferentialParity:
    """Scheduler output ≡ solo static oracle, bitwise."""

    @pytest.mark.parametrize("order", sorted(ARRIVAL_ORDERS))
    def test_stream_matches_solo_oracle(self, diff_case, order):
        eng, base_reqs, cap, oracle = diff_case
        arrivals = ARRIVAL_ORDERS[order](len(base_reqs))
        reqs = [dataclasses.replace(r, arrival=a)
                for r, a in zip(base_reqs, arrivals)]
        results = eng.generate_stream(reqs, num_slots=NUM_SLOTS,
                                      max_request_len=cap)
        assert sorted(r.rid for r in results) == sorted(r.rid for r in reqs)
        for res in results:
            ref_tokens, ref_logits = oracle[res.rid]
            np.testing.assert_array_equal(
                res.tokens, ref_tokens,
                err_msg=f"rid={res.rid} order={order}")
            assert np.array_equal(res.final_logits, ref_logits), \
                f"rid={res.rid} order={order}: logits not bitwise"

    def test_mid_stream_admission_reuses_freed_pages(self, diff_case):
        """More requests than the pool holds at once: retirements must free
        pages that later admissions reuse — and stale page contents must not
        leak into any request's logits (bitwise vs the oracle)."""
        eng, base_reqs, cap, oracle = diff_case
        # two waves of the same requests under new rids: wave 2 decodes on
        # pages wave 1 dirtied
        wave2 = [dataclasses.replace(r, rid=r.rid + len(base_reqs),
                                     arrival=3) for r in base_reqs]
        results = eng.generate_stream(list(base_reqs) + wave2,
                                      num_slots=NUM_SLOTS,
                                      max_request_len=cap)
        assert len(results) == 2 * len(base_reqs)
        for res in results:
            ref_tokens, ref_logits = oracle[res.rid % len(base_reqs)]
            np.testing.assert_array_equal(res.tokens, ref_tokens)
            assert np.array_equal(res.final_logits, ref_logits)


class TestSchedulerEfficiency:
    def test_ragged_stream_beats_padded_static_batching(self):
        """The quantity the ``serving_cb_*`` bench rows gate, asserted
        strictly: on a ragged stream, continuous batching spends fewer
        slot-steps than padding static batches of the same width (every
        slot in a static batch decodes until the batch max)."""
        cfg = _family_cfg("transformer")
        eng = ServingEngine(cfg, attn_backend=_backend("pallas-splitk"))
        rng = np.random.default_rng(11)
        budgets = [1, 8, 1, 8, 1, 8]
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (4,)).astype(np.int32),
                        max_new_tokens=b)
                for i, b in enumerate(budgets)]
        cap = _stream_capacity(eng, reqs)
        layout = eng.cache_layout(cap)
        sched = RequestScheduler(eng.model, eng.params, eng._prefill,
                                 num_slots=NUM_SLOTS, slot_capacity=cap,
                                 layout=layout)
        sched.run(reqs)
        continuous_slot_steps = sched.steps_run * NUM_SLOTS
        static_slot_steps = sum(
            max(budgets[i:i + NUM_SLOTS]) * NUM_SLOTS
            for i in range(0, len(budgets), NUM_SLOTS))
        assert sched.tokens_emitted == sum(budgets)
        assert continuous_slot_steps < static_slot_steps, \
            (continuous_slot_steps, static_slot_steps)

    def test_oversized_request_rejected_up_front(self):
        """A request that can never fit a slot fails loudly at submission,
        not after spinning through the step budget."""
        cfg = _family_cfg("transformer")
        eng = ServingEngine(cfg)
        layout = eng.cache_layout(8)
        sched = RequestScheduler(eng.model, eng.params, eng._prefill,
                                 num_slots=2,
                                 slot_capacity=layout.padded_len(8),
                                 layout=layout)
        rng = np.random.default_rng(0)
        big = Request(rid=0, prompt=rng.integers(0, cfg.vocab_size,
                                                 (4,)).astype(np.int32),
                      max_new_tokens=64)
        with pytest.raises(ValueError, match="slot_capacity"):
            sched.run([big])


# ---------------------------------------------------------------------------
# KVBlockPool / BlockAllocator properties
# ---------------------------------------------------------------------------


class TestBlockAllocatorProperties:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=99999),
           num_blocks=st.integers(min_value=3, max_value=64))
    def test_random_interleavings_keep_invariants(self, seed, num_blocks):
        """Random admit/retire interleavings: a live page is never handed
        out again, frees reject non-live pages, and the pool returns to
        fully free once every request retires."""
        rng = np.random.default_rng(seed)
        alloc = BlockAllocator(num_blocks)
        total_free = alloc.free_blocks
        live = {}                                   # rid -> page list
        ever = set()
        for step in range(40):
            if live and rng.random() < 0.45:
                rid = list(live)[int(rng.integers(len(live)))]
                alloc.free(live.pop(rid))
            else:
                n = int(rng.integers(1, 4))
                if n > alloc.free_blocks:
                    with pytest.raises(PoolExhausted):
                        alloc.alloc(n)
                    continue
                ids = alloc.alloc(n)
                flat = [b for pages in live.values() for b in pages]
                assert not set(ids) & set(flat), "double allocation"
                assert all(b >= RESERVED_BLOCKS for b in ids), \
                    "reserved page handed out"
                live[step] = ids
                ever.update(ids)
        for pages in live.values():
            alloc.free(pages)
        assert alloc.free_blocks == total_free
        assert alloc.live_blocks == 0
        # double free of anything previously live must be rejected
        if ever:
            with pytest.raises(ValueError):
                alloc.free([next(iter(ever))])

    def test_freed_page_never_read_by_live_request(self):
        """The scheduler-level form of 'never read a freed block': inactive
        slots' writes land in the sink page, so a page freed and re-handed
        to a live request is only ever written by its new owner."""
        layout = KVCacheLayout(block_k=2)
        template = {"k": jnp.zeros((1, 1, 2, 8, 3)),    # [L,B,KV,S,D]
                    "v": jnp.zeros((1, 1, 2, 8, 3)),
                    "length": jnp.zeros((), jnp.int32)}
        from repro.models.kvcache import seq_axis_tree

        axes = seq_axis_tree(template)
        pool = KVBlockPool.build(template, axes, layout, num_blocks=12)
        cache = {"k": jnp.arange(1 * 1 * 2 * 8 * 3, dtype=jnp.float32)
                 .reshape(1, 1, 2, 8, 3) + 1.0,
                 "v": jnp.zeros((1, 1, 2, 8, 3)), "length": None}
        table = pool.admit(split_cache(cache, axes)[0], 8)
        owned = np.asarray(table[:4], np.int32)
        # a retired slot (active=False) writing at any position must only
        # touch the sink page
        before = np.asarray(pool.buffers["k"][owned])
        chunks = {"k": jnp.full((1, 1, 1, 2, 3), -7.0),
                  "v": jnp.full((1, 1, 1, 2, 3), -7.0), "length": None}
        tables = jnp.asarray(np.stack([table]), jnp.int32)
        new = pool.scatter_token(pool.buffers, chunks, tables,
                                 jnp.asarray([5], jnp.int32),
                                 jnp.asarray([False]))
        np.testing.assert_array_equal(np.asarray(new["k"][owned]), before)
        assert np.all(np.asarray(new["k"][SINK_BLOCK, 1]) == -7.0)


class TestBlockTableRoundTrip:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=99999),
           block_k=st.integers(min_value=1, max_value=5),
           n_blocks_req=st.integers(min_value=1, max_value=6))
    def test_admit_gather_is_exact(self, seed, block_k, n_blocks_req):
        """block-table → flat-cache round trip: admit a random cache into
        randomly interleaved physical pages, gather through the table, and
        get the original buffer back bit-for-bit (beyond the request's own
        pages the gather reads the zero null page)."""
        rng = np.random.default_rng(seed)
        layout = KVCacheLayout(block_k=block_k)
        width = 6
        S_slot = width * block_k
        shape = (2, 1, 2, S_slot, 3)                 # [L,B,KV,S,D]
        template = {"k": jnp.zeros(shape), "v": jnp.zeros(shape),
                    "length": jnp.zeros((), jnp.int32)}
        from repro.models.kvcache import seq_axis_tree

        axes = seq_axis_tree(template)
        pool = KVBlockPool.build(template, axes, layout,
                                 num_blocks=RESERVED_BLOCKS + 3 * width)
        # fragment the free list so this admit lands on interleaved pages
        for _ in range(int(rng.integers(0, 4))):
            ids = pool.allocator.alloc(int(rng.integers(1, 4)))
            if rng.random() < 0.5:
                pool.allocator.free(ids)
        cache = {"k": jnp.asarray(rng.standard_normal(shape), jnp.float32),
                 "v": jnp.asarray(rng.standard_normal(shape), jnp.float32),
                 "length": None}
        table = pool.admit(cache, n_blocks_req * block_k)
        got = pool.gather(pool.buffers, jnp.asarray(table[None], jnp.int32))
        valid = n_blocks_req * block_k
        for leaf in ("k", "v"):
            np.testing.assert_array_equal(
                np.asarray(got[leaf][0, ..., :valid, :]),
                np.asarray(cache[leaf][..., :valid, :]))
            # table tail is the null page → exact zeros
            assert np.all(np.asarray(got[leaf][0, ..., valid:, :]) == 0.0)

    def test_scatter_then_gather_reads_back_written_token(self):
        layout = KVCacheLayout(block_k=3)
        shape = (1, 1, 2, 9, 4)
        template = {"k": jnp.zeros(shape), "v": jnp.zeros(shape),
                    "length": jnp.zeros((), jnp.int32)}
        from repro.models.kvcache import seq_axis_tree

        axes = seq_axis_tree(template)
        pool = KVBlockPool.build(template, axes, layout, num_blocks=10)
        cache = {"k": jnp.zeros(shape), "v": jnp.zeros(shape), "length": None}
        table = pool.admit(cache, 9)
        rng = np.random.default_rng(0)
        for pos in (0, 2, 3, 8):                    # block edges + interior
            chunk = {"k": jnp.asarray(rng.standard_normal((1, 1, 1, 2, 4)),
                                      jnp.float32),
                     "v": jnp.zeros((1, 1, 1, 2, 4)), "length": None}
            pool.buffers = pool.scatter_token(
                pool.buffers, chunk, jnp.asarray(table[None], jnp.int32),
                jnp.asarray([pos], jnp.int32), jnp.asarray([True]))
            got = pool.gather(pool.buffers,
                              jnp.asarray(table[None], jnp.int32))
            np.testing.assert_array_equal(
                np.asarray(got["k"][0, ..., pos, :]),
                np.asarray(chunk["k"][0]))


# ---------------------------------------------------------------------------
# cache_seq_axes classification (drives what the pool owns)
# ---------------------------------------------------------------------------


class TestCacheSeqAxes:
    @pytest.mark.parametrize("arch,family", [
        ("internlm2-1.8b", "dense"), ("deepseek-moe-16b", "moe"),
        ("zamba2-7b", "hybrid"), ("seamless-m4t-medium", "encdec"),
        ("mamba2-370m", "ssm"),
    ])
    def test_classification_per_family(self, arch, family):
        cfg = get_config(arch).reduced()
        model = get_model(cfg)
        cache = cache_specs(cfg, ShapeConfig("smoke", 1, 8, "decode"),
                            abstract=True)
        axes = model.cache_seq_axes(cache)
        flat = {jax.tree_util.keystr(p): v
                for p, v in jax.tree_util.tree_flatten_with_path(
                    axes, is_leaf=lambda x: x is None)[0]}
        growing = sorted(k for k, v in flat.items() if v == -2)
        resident = sorted(k for k, v in flat.items() if v is None)
        if family == "ssm":
            assert not growing and resident
        else:
            assert growing, flat
            assert "['length']" in flat and flat["['length']"] is None
        if family == "dense":
            assert growing == ["['k']", "['v']"]
        if family == "encdec":
            assert all("kc" not in k and "vc" not in k for k in growing)
            assert any("kc" in k for k in resident)
        if family == "hybrid":
            assert any("kv" in k for k in growing)
            assert all("states" not in k for k in growing)


# ---------------------------------------------------------------------------
# no-retrace regression (PR 4 _cache_size harness)
# ---------------------------------------------------------------------------


class TestNoRetrace:
    def test_detector_self_test(self):
        """The retrace counter must actually count: a fresh jit traces once
        per distinct input shape."""
        f = jax.jit(lambda x: x * 2)
        f(jnp.zeros((2,)))
        n0 = f._cache_size()
        f(jnp.ones((2,)))                    # same shape → cache hit
        assert f._cache_size() == n0
        f(jnp.zeros((3,)))                   # new shape → one new trace
        assert f._cache_size() == n0 + 1

    def test_admission_and_retirement_never_retrace(self):
        """Nine requests churning through three slots (staggered arrivals,
        mixed budgets): the jitted decode step traces exactly once."""
        cfg = _family_cfg("transformer")
        eng = ServingEngine(cfg, attn_backend=_backend("pallas-splitk"))
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (int(rng.integers(2, 8)),))
                        .astype(np.int32),
                        max_new_tokens=int(rng.integers(1, 6)),
                        arrival=int(rng.integers(0, 6)))
                for i in range(9)]
        cap = _stream_capacity(eng, reqs)
        sched = RequestScheduler(eng.model, eng.params, eng._prefill,
                                 num_slots=3, slot_capacity=cap,
                                 layout=eng.cache_layout(cap))
        res = sched.run(reqs)
        assert len(res) == 9
        assert sched._step_fn._cache_size() == 1, \
            "admission/retirement retraced the decode step"
        assert sched.pool.allocator.live_blocks == 0


# ---------------------------------------------------------------------------
# forced 4-device sharded-scheduler sweep (`make test-mesh`)
# ---------------------------------------------------------------------------


@pytest.mark.mesh
@pytest.mark.slow
def test_multi_device_sharded_scheduler_parity():
    """Forced 4-device host platform: the sequence-sharded scheduler
    (shard_map over the paged leaves' S axis, ``decode_partial`` +
    ``combine_split_kv`` under vmap) serves the same stream as the
    unsharded scheduler — tokens equal, logits inside the PR 4 multi-shard
    envelope — over 1/2/4-device meshes."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import numpy as np, jax
        from repro.configs import get_config
        from repro.core.backends import PallasSplitKAttention
        from repro.launch.mesh import make_mesh
        from repro.serving.engine import ServingEngine
        from repro.serving.scheduler import Request

        assert len(jax.devices()) == 4, jax.devices()
        rng = np.random.default_rng(0)
        cfg = get_config("internlm2-1.8b").reduced()
        eng = ServingEngine(cfg, attn_backend=PallasSplitKAttention(block_k=4))
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            (int(rng.integers(2, 7)),))
                        .astype(np.int32),
                        max_new_tokens=int(rng.integers(1, 5)),
                        arrival=int(rng.integers(0, 3)))
                for i in range(5)]
        CAP = 16                                # 4 shards x block_k=4
        ref = {r.rid: r for r in eng.generate_stream(
            list(reqs), num_slots=2, max_request_len=CAP)}
        for d in (1, 2, 4):
            mesh = make_mesh((d,), ("seq",))
            got = eng.generate_stream(list(reqs), num_slots=2,
                                      max_request_len=CAP, mesh=mesh)
            assert sorted(r.rid for r in got) == sorted(ref)
            tol = 1e-6 if d == 1 else 2e-2
            for r in got:
                assert np.array_equal(ref[r.rid].tokens, r.tokens), (d, r.rid)
                assert np.allclose(r.final_logits, ref[r.rid].final_logits,
                                   rtol=tol, atol=tol), (d, r.rid)
        print("SHARDED_SCHEDULER_OK")
    """)
    pythonpath = os.pathsep.join(
        p for p in ("src", os.environ.get("PYTHONPATH", "")) if p
    )
    env = dict(os.environ, PYTHONPATH=pythonpath)
    env.pop("XLA_FLAGS", None)  # the script pins its own device count
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True,
                         cwd=os.path.dirname(os.path.dirname(__file__)),
                         timeout=600)
    assert "SHARDED_SCHEDULER_OK" in out.stdout, out.stderr[-3000:]
