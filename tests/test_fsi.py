"""End-to-end FSI correctness: both channels ≡ serial ≡ dense oracle."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.data.graphchallenge import (
    dense_inference,
    make_inputs,
    make_sparse_dnn,
)
from repro.faas.simulator import LatencyModel, run_fsi


@pytest.fixture(scope="module")
def small_case():
    net = make_sparse_dnn(256, n_layers=10, seed=0)
    x0 = make_inputs(256, 24, seed=1)
    oracle = dense_inference(net, x0)
    return net, x0, oracle


class TestFsiCorrectness:
    def test_serial_matches_oracle(self, small_case):
        net, x0, oracle = small_case
        r = run_fsi(net, x0, channel="serial")
        np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("channel", ["queue", "object"])
    @pytest.mark.parametrize("P", [2, 5, 8])
    def test_parallel_matches_oracle(self, small_case, channel, P):
        net, x0, oracle = small_case
        r = run_fsi(net, x0, P=P, channel=channel, memory_mb=4000)
        np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("method", ["hgp", "random", "block"])
    def test_partition_method_invariance(self, small_case, method):
        net, x0, oracle = small_case
        r = run_fsi(net, x0, P=6, channel="queue", partition_method=method,
                    memory_mb=4000)
        np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)

    def test_sparsity_exploitation_identical_output(self, small_case):
        net, x0, oracle = small_case
        r1 = run_fsi(net, x0, P=4, channel="queue", exploit_sparsity=True,
                     memory_mb=4000)
        r2 = run_fsi(net, x0, P=4, channel="queue", exploit_sparsity=False,
                     memory_mb=4000)
        np.testing.assert_allclose(r1.output, r2.output)
        assert r1.wire_exchange_bytes <= r2.wire_exchange_bytes

    def test_mvp_single_sample(self):
        net = make_sparse_dnn(128, n_layers=6, seed=3)
        x0 = make_inputs(128, 1, seed=4)
        oracle = dense_inference(net, x0)
        for ch in ["queue", "object"]:
            r = run_fsi(net, x0, P=4, channel=ch, memory_mb=2000)
            np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)


class TestFsiAccounting:
    def test_costs_positive_and_structured(self, small_case):
        net, x0, _ = small_case
        rq = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000)
        ro = run_fsi(net, x0, P=6, channel="object", memory_mb=4000)
        assert rq.cost.compute > 0 and rq.cost.communication > 0
        assert ro.cost.communication > 0
        assert rq.stats.publish_units > 0 and rq.stats.sqs_api_calls > 0
        assert ro.stats.s3_puts > 0 and ro.stats.s3_lists > 0
        # object PUT/LIST pricing is ~1 OOM above SNS/SQS API pricing, so at
        # equal volume queue comms must be cheaper at this scale (§IV-C)
        assert rq.cost.communication < ro.cost.communication

    def test_compression_reduces_wire_volume(self, small_case):
        net, x0, _ = small_case
        r = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000)
        assert 0 < r.wire_exchange_bytes < r.raw_exchange_bytes

    def test_hgp_reduces_wire_volume_vs_rp(self, small_case):
        net, x0, _ = small_case
        rh = run_fsi(net, x0, P=8, channel="object", partition_method="hgp",
                     memory_mb=4000)
        rr = run_fsi(net, x0, P=8, channel="object", partition_method="random",
                     memory_mb=4000)
        assert rh.wire_exchange_bytes < rr.wire_exchange_bytes

    def test_memory_gate(self):
        net = make_sparse_dnn(1024, n_layers=4, seed=0)
        x0 = make_inputs(1024, 2048, seed=1)
        with pytest.raises(MemoryError):
            run_fsi(net, x0, P=2, channel="queue", memory_mb=8)

    def test_worker_times_monotone_with_stragglers(self, small_case):
        net, x0, _ = small_case
        fast = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000)
        # slowdown scales *active* work (compute/pack), which is µs-scale at
        # this tiny config — use a large factor so it dominates the latency
        slow = run_fsi(
            net, x0, P=6, channel="queue", memory_mb=4000,
            latency=LatencyModel(straggler_prob=0.9, straggler_slowdown=5e4),
        )
        assert slow.makespan > fast.makespan

    def test_straggler_mitigation_helps(self, small_case):
        net, x0, _ = small_case
        lat = LatencyModel(straggler_prob=0.9, straggler_slowdown=5e4)
        plain = run_fsi(net, x0, P=6, channel="queue", memory_mb=4000, latency=lat)
        mitigated = run_fsi(
            net, x0, P=6, channel="queue", memory_mb=4000, latency=lat,
            reinvoke_stragglers=True, straggler_timeout=2.0,
        )
        np.testing.assert_allclose(mitigated.output, plain.output)
        assert mitigated.makespan <= plain.makespan


class TestReduceGatherOrder:
    """Regression: ``reduce_to_root(op='concat_rows')`` must stack panels in
    worker-RANK order, not launch-tree traversal order.  With branching 4 the
    root aggregates [p0] + subtree(1) + ... — so p5 arrived between p1 and p2
    and every rank ≥ 6 run misassembled its output gather (masked at tiny N
    where the permuted activation rows happened to coincide; exposed by the
    paper-scale P≥64 sweeps)."""

    def test_concat_rows_is_rank_ordered(self):
        from repro.core.cost_model import AWS_PRICING
        from repro.faas.collectives import reduce_to_root
        from repro.faas.launch_tree import TreeSpec
        from repro.faas.queue_service import QueueFabric
        from repro.faas.worker import WorkerState

        P = 6  # rank 5 is a child of rank 1 → tree order [0, 1, 5, 2, 3, 4]
        workers = [WorkerState(rank=m, memory_mb=1000) for m in range(P)]
        fabric = QueueFabric(P, pricing=AWS_PRICING, seed=0)
        panels = [np.full((2, 3), m, dtype=np.float32) for m in range(P)]
        out = reduce_to_root(workers, fabric, TreeSpec(n_workers=P, branching=4),
                             panels, op="concat_rows")
        np.testing.assert_array_equal(out, np.concatenate(panels, axis=0))

    def test_paper_scale_p_matches_oracle(self):
        """P=64 (the paper's smallest high-parallelism fleet) end-to-end —
        deep trees with interleaved subtrees everywhere."""
        net = make_sparse_dnn(512, n_layers=4, seed=0)
        x0 = make_inputs(512, 8, seed=1)
        oracle = dense_inference(net, x0)
        r = run_fsi(net, x0, P=64, channel="queue", memory_mb=4000)
        np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=10**6),
    P=st.sampled_from([2, 3, 4, 6]),
    channel=st.sampled_from(["queue", "object"]),
)
def test_property_fsi_equals_oracle(seed, P, channel):
    """FSI over any random sparse net ≡ dense oracle (both channels)."""
    net = make_sparse_dnn(128, n_layers=4, seed=seed, mode="random")
    x0 = make_inputs(128, 8, seed=seed + 1)
    oracle = dense_inference(net, x0)
    r = run_fsi(net, x0, P=P, channel=channel, memory_mb=2000, seed=seed)
    np.testing.assert_allclose(r.output, oracle, rtol=1e-5, atol=1e-5)
