"""Partitioner unit + property tests (paper §II-C, Table III)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import partitioner as pt
from repro.core.send_recv import build_comm_plans
from repro.core.sparse import random_sparse
from repro.data.graphchallenge import make_sparse_dnn


def _net(n=256, layers=8, seed=0, mode="radix"):
    return make_sparse_dnn(n, n_layers=layers, seed=seed, mode=mode)


class TestPartitionBasics:
    def test_random_partition_balanced(self):
        parts = pt.random_partition(1000, 7, seed=3)
        counts = np.bincount(parts, minlength=7)
        assert counts.max() - counts.min() <= 1

    def test_block_partition_contiguous(self):
        parts = pt.block_partition(100, 8)
        assert np.all(np.diff(parts) >= 0)
        assert np.bincount(parts).max() <= int(np.ceil(100 / 8)) + 1

    @pytest.mark.parametrize("method", ["hgp", "random", "block"])
    def test_cover_and_shapes(self, method):
        net = _net()
        res = pt.partition_network(net.layers, P=8, method=method, seed=0)
        assert len(res.parts) == len(net.layers) + 1
        for p in res.parts:
            assert p.shape == (256,)
            assert p.min() >= 0 and p.max() < 8

    def test_hgp_balance(self):
        net = _net()
        res = pt.partition_network(net.layers, P=8, method="hgp", seed=0)
        assert res.imbalance(net.layers) <= 1.10  # eps=0.05 + slack


class TestCommVolume:
    def test_hgp_beats_random_structured(self):
        """Table III: HGP-DNN reduces inter-worker volume vs RP by a large
        factor on structured (RadiX-Net-like) sparsity."""
        net = _net(n=512, layers=16)
        hgp = pt.partition_network(net.layers, P=8, method="hgp", seed=0)
        rp = pt.partition_network(net.layers, P=8, method="random", seed=0)
        v_hgp = pt.measure_comm_volume(net.layers, hgp).total_rows_sent
        v_rp = pt.measure_comm_volume(net.layers, rp).total_rows_sent
        assert v_hgp < v_rp / 3.0  # paper: ~9.3x; structured synthetic: >3x

    def test_hgp_never_worse_than_block(self):
        for mode, rewire in [("radix", 0.0), ("radix", 0.3), ("random", 0.0)]:
            net = make_sparse_dnn(256, n_layers=6, seed=1, mode=mode, rewire_frac=rewire)
            hgp = pt.partition_network(net.layers, P=4, method="hgp", seed=0)
            blk = pt.partition_network(net.layers, P=4, method="block", seed=0)
            v_h = pt.measure_comm_volume(net.layers, hgp).total_rows_sent
            v_b = pt.measure_comm_volume(net.layers, blk).total_rows_sent
            assert v_h <= v_b

    def test_volume_zero_single_worker(self):
        net = _net(n=128, layers=4)
        res = pt.partition_network(net.layers, P=1, method="hgp", seed=0)
        rep = pt.measure_comm_volume(net.layers, res)
        assert rep.total_rows_sent == 0


class TestSendRecvPlans:
    def test_send_recv_duality(self):
        net = _net(n=256, layers=6)
        res = pt.partition_network(net.layers, P=8, method="hgp", seed=0)
        plans = build_comm_plans(net.layers, res)
        for lp in plans:
            for w in lp.workers:
                for tgt, rows in w.send.items():
                    assert tgt != w.worker
                    np.testing.assert_array_equal(rows, lp.workers[tgt].recv[w.worker])

    def test_plan_matches_evaluator(self):
        net = _net(n=256, layers=6)
        for method in ["hgp", "random"]:
            res = pt.partition_network(net.layers, P=8, method=method, seed=0)
            plans = build_comm_plans(net.layers, res)
            total = sum(lp.total_rows_sent() for lp in plans)
            rep = pt.measure_comm_volume(net.layers, res)
            assert total == rep.total_rows_sent

    def test_needed_rows_cover_weights(self):
        net = _net(n=256, layers=6)
        res = pt.partition_network(net.layers, P=8, method="random", seed=2)
        plans = build_comm_plans(net.layers, res)
        for k, W in enumerate(net.layers):
            for w in plans[k].workers:
                if len(w.owned_out_rows) == 0:
                    continue
                sub = W.select_rows(w.owned_out_rows)
                needed_cols = np.unique(sub.indices)
                assert np.all(np.isin(needed_cols, w.needed_rows))


@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([64, 128]),
    P=st.integers(min_value=2, max_value=8),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_property_partition_cover_balance(n, P, seed):
    """Any partition method covers all rows and respects the balance cap."""
    rng = np.random.default_rng(seed)
    layers = [random_sparse(n, n, 8, rng) for _ in range(3)]
    res = pt.partition_network(layers, P=P, method="hgp", seed=seed)
    for p in res.parts:
        assert np.all((p >= 0) & (p < P))
    assert res.imbalance(layers) < 1.6  # loose cap for tiny instances


@settings(max_examples=15, deadline=None)
@given(
    P=st.integers(min_value=2, max_value=6),
    seed=st.integers(min_value=0, max_value=10**6),
)
def test_property_duality_random_nets(P, seed):
    rng = np.random.default_rng(seed)
    layers = [random_sparse(96, 96, 6, rng) for _ in range(3)]
    res = pt.partition_network(layers, P=P, method="random", seed=seed)
    plans = build_comm_plans(layers, res)
    for lp in plans:
        sent = {(w.worker, t): r for w in lp.workers for t, r in w.send.items()}
        recvd = {(s, w.worker): r for w in lp.workers for s, r in w.recv.items()}
        assert set(sent) == set(recvd)
        for key in sent:
            np.testing.assert_array_equal(sent[key], recvd[key])
