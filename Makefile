# Single entry point for the builder and CI.
#
#   make test         tier-1 suite (ROADMAP "Tier-1 verify").  Includes the
#                     backend parity harnesses: tests/test_backends.py (SpMM
#                     compute backends), tests/test_attention_backends.py
#                     (decode-attention backends × model families × ragged
#                     cache_len edges vs the dense-ref oracle) and
#                     tests/test_sharded_decode.py (sequence-sharded split-KV
#                     decode over host-device meshes + the no-relayout jaxpr
#                     gate).  Run one harness alone with
#                       make test PYTEST_ARGS=tests/test_attention_backends.py
#   make test-chaos   only the crash-fault recovery suite (seeded chaos
#                     injection, visibility-timeout redelivery, re-invoke
#                     recovery + billing): tests/test_chaos.py plus the
#                     fabric-level visibility-timeout units in
#                     tests/test_faas_services.py
#   make test-mesh    only the forced-4-device subprocess sweeps (marked
#                     `mesh`, deselected from tier-1 by pyproject addopts);
#                     CI's host-mesh-4 matrix entry runs this explicitly
#   make bench-quick  CI-sized benchmark sweep + BENCH_fsi.json perf snapshot
#                     (spmm_roofline_* + decode_attn_* rows per backend)
#   make bench        full benchmark sweep.  PAPER_SCALE=1 adds the P=64,
#                     N=65536 GraphChallenge sharded sweep (vmap baseline +
#                     fused megakernel rows with a wall-clock budget)
#   make bench-paper  the paper-scale sweep on CI-sized surroundings
#                     (= bench-quick + --paper-scale)
#   make bench-delta  fresh quick sweep into BENCH_fsi.new.json, schema-check
#                     it, then fail on >20% billed-time regression vs the
#                     committed BENCH_fsi.json (benchmarks/bench_delta.py) —
#                     CI runs this so a harness slowdown fails the push.
#                     NOTE: BENCH_fsi.json is a COMMITTED baseline since
#                     PR 5; bench-quick/bench/bench-paper intentionally
#                     refresh it in place — commit the refreshed file (use
#                     bench-paper so the paper-scale rows stay recorded) or
#                     `git checkout` it
#   make schema-check validate BENCH_fsi.json rows (name/us_per_call) so the
#                     perf-trajectory tooling never breaks on a malformed row
#   make docs-check   verify README/ARCHITECTURE/kernels-README relative
#                     links resolve (tools/check_doc_links.py)
#   make lint         byte-compile + import-sanity over src/ (no external
#                     linter dependency baked into the image)
#
# To exercise the mesh-sharded fleet path (pallas-bsr-sharded) on real
# multi-device host meshes, widen the host platform before jax init —
# this is CI's second matrix entry:
#   XLA_FLAGS=--xla_force_host_platform_device_count=4 make test

PY ?= python
PYTEST_ARGS ?=
PAPER_SCALE ?=
BENCH_FLAGS := $(if $(PAPER_SCALE),--paper-scale,)
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test test-chaos test-mesh bench-quick bench bench-paper bench-delta \
        schema-check docs-check lint

test:
	$(PY) -m pytest -x -q $(PYTEST_ARGS)

test-chaos:
	$(PY) -m pytest -x -q tests/test_chaos.py \
		tests/test_faas_services.py::TestVisibilityTimeout $(PYTEST_ARGS)

test-mesh:
	$(PY) -m pytest -x -q -m mesh $(PYTEST_ARGS)

bench-quick:
	$(PY) -m benchmarks.run --quick $(BENCH_FLAGS) --json BENCH_fsi.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

bench:
	$(PY) -m benchmarks.run $(BENCH_FLAGS) --json BENCH_fsi.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

bench-paper:
	$(PY) -m benchmarks.run --quick --paper-scale --json BENCH_fsi.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

bench-delta:
	$(PY) -m benchmarks.run --quick --json BENCH_fsi.new.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.new.json
	$(PY) -m benchmarks.bench_delta BENCH_fsi.json BENCH_fsi.new.json

schema-check:
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

docs-check:
	$(PY) tools/check_doc_links.py

lint:
	$(PY) -m compileall -q src benchmarks tests tools
	$(PY) -c "import repro.core.backends, repro.core.fsi, repro.faas.simulator, repro.faas.payload; print('import sanity: ok')"
