# Single entry point for the builder and CI.
#
#   make test         tier-1 suite (ROADMAP "Tier-1 verify").  Includes the
#                     backend parity harnesses: tests/test_backends.py (SpMM
#                     compute backends) and tests/test_attention_backends.py
#                     (decode-attention backends × model families × ragged
#                     cache_len edges vs the dense-ref oracle).  Run one
#                     harness alone with
#                       make test PYTEST_ARGS=tests/test_attention_backends.py
#   make bench-quick  CI-sized benchmark sweep + BENCH_fsi.json perf snapshot
#                     (spmm_roofline_* + decode_attn_* rows per backend)
#   make bench        full benchmark sweep
#   make schema-check validate BENCH_fsi.json rows (name/us_per_call) so the
#                     perf-trajectory tooling never breaks on a malformed row
#   make lint         byte-compile + import-sanity over src/ (no external
#                     linter dependency baked into the image)

PY ?= python
PYTEST_ARGS ?=
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench-quick bench schema-check lint

test:
	$(PY) -m pytest -x -q $(PYTEST_ARGS)

bench-quick:
	$(PY) -m benchmarks.run --quick --json BENCH_fsi.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

bench:
	$(PY) -m benchmarks.run --json BENCH_fsi.json
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

schema-check:
	$(PY) -m benchmarks.check_schema BENCH_fsi.json

lint:
	$(PY) -m compileall -q src benchmarks tests
	$(PY) -c "import repro.core.backends, repro.core.fsi, repro.faas.simulator, repro.faas.payload; print('import sanity: ok')"
