# Single entry point for the builder and CI.
#
#   make test         tier-1 suite (ROADMAP "Tier-1 verify")
#   make bench-quick  CI-sized benchmark sweep + BENCH_fsi.json perf snapshot
#   make bench        full benchmark sweep
#   make lint         byte-compile + import-sanity over src/ (no external
#                     linter dependency baked into the image)

PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH),)

.PHONY: test bench-quick bench lint

test:
	$(PY) -m pytest -x -q

bench-quick:
	$(PY) -m benchmarks.run --quick --json BENCH_fsi.json

bench:
	$(PY) -m benchmarks.run --json BENCH_fsi.json

lint:
	$(PY) -m compileall -q src benchmarks tests
	$(PY) -c "import repro.core.backends, repro.core.fsi, repro.faas.simulator, repro.faas.payload; print('import sanity: ok')"
