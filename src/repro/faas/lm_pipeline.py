"""Pipeline-parallel LM serving over the serverless fabric.

``run_lm_pipeline`` is the LM twin of ``run_fsi``: a model's layer stack is
cut into P contiguous stages (``core.partitioner.plan_stages``), each stage
runs as one simulated FaaS worker (``faas.worker.ModelStageWorker``) with its
parameter slice and KV cache resident, and only the activation crosses a
stage boundary — prefill blocks ([B, S, d] split into payload-capped chunks)
and per-token decode activations ([B, 1, d]) travel over the *same*
``QueueFabric``/``ObjectFabric`` channels, through the *same* publish/drain
helpers, as the FSI exchange.  The sampled token loops back from the head
stage to the embedding stage over the channel as well — every byte of the
serving loop is billed.

Clock model (identical contract to ``run_fsi``): the strict-sum **phased**
clock drives every fabric interaction, so every billable count — publish
units, SQS calls, S3 puts/gets/lists, wire bytes — derives from it alone;
the per-worker **event ledger** re-times the same events on dual
compute/channel timelines.  ``overlap`` only selects which times are
reported; charge counts are bit-identical between the two by construction.

Numerics: chained stages run the monolithic model's per-layer ops in the
same order (consecutive sub-scans over contiguous slices of the stacked
blocks), and the wire ships activations as float32 — which round-trips the
bf16 activations exactly — so pipeline logits match the on-device
``ServingEngine`` within the established per-dtype tolerances.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Literal, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.cost_model import (
    AWS_PRICING,
    CostBreakdown,
    PricingConstants,
    WorkloadStats,
    activation_hop_cost,
    lambda_cost,
    object_cost,
    queue_cost,
)
from repro.core.fsi import (
    _object_drain_one,
    _object_put_targets,
    _queue_drain_one,
    _queue_publish_entries,
)
from repro.core.partitioner import StagePlan, plan_stages
from repro.faas.chaos import FaultPlan
from repro.faas.launch_tree import launch_schedule
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk, pack_rows
from repro.faas.queue_service import QueueFabric
from repro.faas.simulator import LatencyModel, charge_weight_load
from repro.faas.worker import (
    ComputeModel,
    EventLedger,
    ModelStageWorker,
    WorkerState,
)

__all__ = ["LmPipelineResult", "build_stage_executors", "run_lm_pipeline",
           "stage_layer_costs"]

Channel = Literal["queue", "object", "auto"]

_MAX_OBJECT_PART = 8 * 1024 * 1024  # matches the FSI object send path


@dataclasses.dataclass(frozen=True)
class _HopArtifact:
    """The minimal artifact surface the shared FSI drain/put helpers read.

    ``layer`` doubles as the **hop id** — a globally monotone tag, so each
    receiver's expected hop strictly increases and the drains' stale-layer
    drop retires duplicate redeliveries of completed hops for free.
    ``needed_rows`` is the identity row space (activations are dense), so
    the drain's searchsorted lands values at their own row index."""

    layer: int
    recv_expect: Dict[int, int]
    needed_rows: np.ndarray


@dataclasses.dataclass
class LmPipelineResult:
    tokens: np.ndarray            # [B, max_new] greedy-decoded token ids
    logits: np.ndarray            # [B, vocab] final decode-step logits
    channel: Channel
    P: int
    plan: StagePlan
    worker_times: np.ndarray      # per-stage finish times (selected clock)
    stats: WorkloadStats
    cost: CostBreakdown
    raw_exchange_bytes: int       # pre-compression activation volume
    wire_exchange_bytes: int      # compressed bytes on the channel
    metrics: Dict[str, float]

    @property
    def makespan(self) -> float:
        return float(self.worker_times.max())

    @property
    def n_tokens(self) -> int:
        return int(self.tokens.size)

    @property
    def per_token_ms(self) -> float:
        """Billed makespan per generated token (batch-amortized)."""
        return self.makespan / max(1, self.n_tokens) * 1e3

    @property
    def usd_per_1k_tokens(self) -> float:
        return self.cost.total / max(1, self.n_tokens) * 1e3


# ---------------------------------------------------------------------------
# stage planning + executors
# ---------------------------------------------------------------------------


def stage_layer_costs(cfg: ModelConfig) -> List[float]:
    """Per-layer *active* parameter cost — the stage planner's balance weight
    (FLOPs per token ∝ active params; MoE layers weigh their top-k + shared
    experts, not the full expert bank)."""
    D = cfg.d_model
    attn = cfg._attn_params()
    if cfg.family == "moe":
        act_ffn = 3 * D * cfg.moe_d_ff * (
            cfg.experts_per_token + cfg.n_shared_experts
        ) + D * cfg.n_experts
        dense_ffn = 3 * D * cfg.d_ff if cfg.d_ff else act_ffn
        return [
            float(attn + (dense_ffn if l < cfg.first_dense_layers else act_ffn)
                  + 2 * D)
            for l in range(cfg.n_layers)
        ]
    return [float(cfg._block_params())] * cfg.n_layers


def build_stage_executors(
    cfg: ModelConfig,
    params: Any,
    P: int,
    attn_backend=None,
) -> List[ModelStageWorker]:
    """Slice ``params`` into P stage executors with jitted stage closures.

    Executors are reusable across ``run_lm_pipeline`` calls (channels, clock
    models) — the jit caches live on the closures, and each run resets the
    resident caches."""
    import jax

    from repro.models.registry import get_stage_model

    sm = get_stage_model(cfg, attn_backend=attn_backend)
    plan = plan_stages(stage_layer_costs(cfg), P)
    costs = stage_layer_costs(cfg)
    head_extra = cfg.d_model * cfg.padded_vocab()  # unembed matmul per token
    executors: List[ModelStageWorker] = []
    for spec in plan.stages:
        sp = sm.slice_params(params, spec)
        prefill_fn = jax.jit(
            lambda p, x, max_len, extra=None, _spec=spec:
                sm.prefill(p, _spec, x, max_len, extra),
            static_argnums=(2,),
        )
        decode_fn = jax.jit(
            lambda p, x, c, _spec=spec: sm.decode_step(p, _spec, x, c)
        )
        weight_bytes = int(sum(
            leaf.nbytes for leaf in jax.tree.leaves(sp)
            if hasattr(leaf, "nbytes")
        ))
        flops = 2.0 * sum(costs[spec.start:spec.stop])
        if spec.has_head:
            flops += 2.0 * head_extra
        executors.append(ModelStageWorker(
            spec=spec, params=sp, prefill_fn=prefill_fn, decode_fn=decode_fn,
            weight_bytes=weight_bytes, flops_per_token=flops,
        ))
    return executors


def _stage_memory_mb(executors: Sequence[ModelStageWorker],
                     pricing: PricingConstants) -> int:
    """Deterministic worker sizing: 2× the largest stage's resident weights
    (activations + KV + interpreter overhead), floor 512MB."""
    need_mb = max(ex.weight_bytes for ex in executors) * 2.0 / 1e6
    return int(min(pricing.max_lambda_memory_mb, max(512, need_mb)))


# ---------------------------------------------------------------------------
# activation hops over the shared FSI channel helpers
# ---------------------------------------------------------------------------


def _send_activation(
    hop: int, values: np.ndarray, src: WorkerState, dst_rank: int,
    channel: Channel, fabric, compute: ComputeModel,
) -> None:
    """Ship one [n_rows, width] float32 activation panel to ``dst_rank``.

    Queue: pack into payload-capped chunks (the "prefill blocks"), batch
    under the SNS caps, publish over lanes — via the exact FSI publish
    helper, so pack charges, lane schedules, and ledger gating are shared.
    Object: one multipart object per hop via the FSI PUT helper."""
    rows = np.arange(values.shape[0], dtype=np.int32)
    if channel == "queue":
        chunks = pack_rows(hop, src.rank, rows, values,
                           fabric.pricing.max_publish_payload)
        raw_total = sum(c.raw_bytes for c in chunks)
        entries = [(dst_rank, c) for c in chunks]
        _queue_publish_entries(entries, src, fabric, compute, raw_total,
                               send_threads=8)
    else:
        chunks = pack_rows(hop, src.rank, rows, values, _MAX_OBJECT_PART)
        art = _HopArtifact(layer=hop, recv_expect={}, needed_rows=rows)
        _object_put_targets(art, src.rank, [(dst_rank, chunks)], src, fabric,
                            compute, 8)


def _drain_activation(
    hop: int, src_rank: int, dst: WorkerState, n_rows: int, width: int,
    channel: Channel, fabric, compute: ComputeModel,
    receipts_out: Optional[List[int]] = None,
) -> np.ndarray:
    """Receive one [n_rows, width] activation panel from ``src_rank`` —
    through the exact FSI drain loops, so (src, seq) dedupe, stale-hop drop,
    receipt deletes, and ledger receive edges are shared with the FSI path
    (and with its fault-fabric test matrix).  ``receipts_out`` defers the
    queue receipt deletes exactly as in the FSI drain — the crash-injection
    path abandons them so the hop redelivers after the visibility timeout."""
    buf = np.zeros((n_rows, width), dtype=np.float32)
    art = _HopArtifact(layer=hop, recv_expect={src_rank: 1},
                       needed_rows=np.arange(n_rows, dtype=np.int32))

    def emit(pos: np.ndarray, vals: np.ndarray) -> None:
        buf[pos] = vals

    if channel == "queue":
        _queue_drain_one(art, dst, fabric, compute, emit,
                         receipts_out=receipts_out)
    else:
        _object_drain_one(art, dst, fabric, compute, emit)
    return buf


# ---------------------------------------------------------------------------
# the pipeline run
# ---------------------------------------------------------------------------


def run_lm_pipeline(
    cfg: ModelConfig,
    prompts: np.ndarray,                  # [B, S] int32 token ids
    params: Any = None,
    *,
    max_new_tokens: int = 8,
    P: int = 2,
    channel: Channel = "queue",
    attn_backend=None,
    memory_mb: Optional[int] = None,
    latency: Optional[LatencyModel] = None,
    compute: Optional[ComputeModel] = None,
    pricing: PricingConstants = AWS_PRICING,
    branching: int = 4,
    seed: int = 0,
    overlap: bool = True,
    eager_poll: bool = True,
    extra: Optional[Dict[str, np.ndarray]] = None,
    executors: Optional[List[ModelStageWorker]] = None,
    fabric=None,
    faults: Optional[FaultPlan] = None,
) -> LmPipelineResult:
    """Serve ``max_new_tokens`` of greedy decode for ``prompts`` over a
    P-stage serverless pipeline on ``channel``.

    ``executors`` — prebuilt :func:`build_stage_executors` output to reuse
    jit caches across runs (caches are reset here).  ``fabric`` — inject a
    fabric instance (fault-model subclasses in tests); must be built for P
    workers on the matching channel (incompatible with ``channel="auto"``).
    ``overlap`` selects the reported clock exactly as in ``run_fsi``; both
    makespans are always in ``metrics``.  ``eager_poll`` re-times ledger
    receives as if each stage's long-poll / LIST loop were already parked
    when the upstream publish landed — ledger-only, billing unchanged.
    ``channel="auto"`` picks queue vs object per stage boundary (and for the
    token loopback) from ``activation_hop_cost`` over the boundary's actual
    activation bytes; the plan lands in ``metrics["chosen_channel_plan"]``.

    ``faults`` arms a seeded :class:`~repro.faas.chaos.FaultPlan`.  Fabric
    injections (API throttles, publish delays) apply to every hop; crash
    sites are keyed ``(stage, hop, "drain")`` — the stage dies after
    draining the hop but before its receipt deletes commit, so queue hops
    redeliver after the visibility timeout and object hops re-GET from the
    durable store.  Recovery re-invokes the stage (invoke + cold start +
    stage weight reload), restores its KV cache from the last durable
    checkpoint (a billed GET; numerically the host-resident cache is
    trusted — the simulator runs stages in-process), and replays any hops
    drained since that checkpoint (recoverable only on the object channel;
    queue inputs were deleted at receipt commit).  KV checkpoints are PUT
    after prefill and every ``checkpoint_every`` decode steps.  ``send`` /
    ``compute`` crash sites and the runtime limit are exercised by
    ``run_fsi``'s full phase matrix, not here.  With a zero-fault plan
    armed, every billed count on the main fabrics stays bit-identical to
    ``faults=None``.
    """
    import jax
    import jax.numpy as jnp

    latency = latency or LatencyModel()
    compute = compute or ComputeModel()
    prompts = np.asarray(prompts)
    B, S = prompts.shape
    max_len = S + max_new_tokens + (cfg.frontend_tokens or 0)

    if params is None:
        from repro.models.registry import get_model

        params = get_model(cfg, attn_backend=attn_backend).init(
            jax.random.key(seed))
    if executors is None:
        executors = build_stage_executors(cfg, params, P,
                                          attn_backend=attn_backend)
    if len(executors) != P:
        raise ValueError(f"got {len(executors)} stage executors for P={P}")
    for ex in executors:
        ex.reset()
    plan = StagePlan(P=P, n_layers=cfg.n_layers,
                     stages=tuple(ex.spec for ex in executors))
    memory_mb = memory_mb or _stage_memory_mb(executors, pricing)

    # ---------------- launch tree + stage workers ---------------------------
    ready = launch_schedule(
        P, branching=branching, invoke_latency=latency.invoke_latency,
        cold_start=latency.cold_start,
        cold_start_jitter=latency.cold_start_jitter, seed=seed,
    )
    workers: List[WorkerState] = []
    for m in range(P):
        w = WorkerState(rank=m, memory_mb=memory_mb, start_time=float(ready[m]),
                        ledger=EventLedger(t_compute=float(ready[m]),
                                           t_channel=float(ready[m]),
                                           eager_poll=eager_poll))
        # stage cold start: only this stage's layer slice is read back —
        # charge_weight_load bills ModelStageWorker.weight_bytes, never the
        # full model (and syncs both ledger timelines: nothing overlaps a
        # weight load)
        charge_weight_load(w, executors[m], latency)
        w.touch_memory(executors[m].weight_bytes)
        workers.append(w)

    # ---------------- fabric(s) ----------------------------------------------
    def _mk_fabric(ch: str):
        if ch == "queue":
            return QueueFabric(
                P, pricing=pricing,
                publish_latency=latency.sns_publish_latency,
                fanout_latency=latency.sns_fanout_latency,
                poll_rtt=latency.sqs_poll_rtt,
                long_poll_window=latency.sqs_long_poll_window,
                seed=seed,
            )
        return ObjectFabric(
            P,
            put_latency=latency.s3_put_latency,
            get_first_byte=latency.s3_get_first_byte,
            list_latency=latency.s3_list_latency,
            bandwidth=latency.s3_bandwidth,
        )

    if channel == "auto":
        if fabric is not None:
            raise ValueError("channel='auto' is incompatible with an "
                             "injected fabric")
        boundary_ch, loop_ch = _lm_autotune_plan(
            B, S, cfg.d_model, P, max_new_tokens, pricing)
        plan_str = "".join(c[0] for c in boundary_ch) + "+" + loop_ch[0]
    elif channel in ("queue", "object"):
        boundary_ch = [channel] * max(0, P - 1)
        loop_ch = channel
        plan_str = None
    else:
        raise ValueError(channel)
    if fabric is not None:
        fabrics = {channel: fabric}
    else:
        fabrics = {ch: _mk_fabric(ch)
                   for ch in dict.fromkeys(list(boundary_ch) + [loop_ch])}
    hops = itertools.count()

    # ---------------- chaos plumbing (faults=None: all of this is inert) ----
    chaos = None
    ckpt_fabric = None
    if faults is not None:
        chaos = faults.activate()
        for fab in fabrics.values():
            fab.chaos = chaos
        ckpt_fabric = ObjectFabric(
            P,
            put_latency=latency.s3_put_latency,
            get_first_byte=latency.s3_get_first_byte,
            list_latency=latency.s3_list_latency,
            bandwidth=latency.s3_bandwidth,
        )
    ckpt_ids = itertools.count()
    last_ckpt: List[Optional[int]] = [None] * P
    # hops drained since each stage's last KV checkpoint: (hop, src, ch,
    # n_tokens) — the replay work a crash at that stage would redo
    unreplayed: List[List[tuple]] = [[] for _ in range(P)]

    def _checkpoint_kv(m: int) -> None:
        """PUT stage m's resident KV cache to the durable checkpoint store.

        The upload rides a background connection: the stage clock pays only
        serialization; the PUT tariff lands on the recovery cost line."""
        w = workers[m]
        nbytes = int(sum(
            leaf.nbytes for leaf in jax.tree.leaves(executors[m].cache)
            if hasattr(leaf, "nbytes")
        ))
        s = nbytes / compute.pack_bandwidth * w.slowdown
        w.charge_seconds(s)
        if w.ledger is not None:
            w.ledger.compute(s)
        cid = next(ckpt_ids)
        ckpt_fabric.put_obj(cid, m, m, Chunk(bytes(nbytes), raw_bytes=nbytes),
                            w.abs_time)
        last_ckpt[m] = cid
        unreplayed[m].clear()

    def _recover_stage(m: int, hop_id: int) -> None:
        """Re-invoke crashed stage m: cold start + stage weight reload, KV
        restore from the last durable checkpoint, replay of any hops drained
        since it (object channel only — queue inputs are gone)."""
        w = workers[m]
        chaos.record_reinvoke(
            m, hop_id, "drain",
            "crashed after drain, before receipt delete; re-invoked")
        w.charge_seconds(latency.invoke_latency + latency.cold_start)
        if w.ledger is not None:
            w.ledger.sync(latency.invoke_latency + latency.cold_start)
        charge_weight_load(w, executors[m], latency)
        if last_ckpt[m] is not None:
            now, _ = ckpt_fabric.get_obj(last_ckpt[m], m, f"{m}_{m}.dat",
                                         w.abs_time)
            w.advance_to_abs(now)
            if w.ledger is not None:
                w.ledger.sync_to(w.abs_time)
        for h, src_rank, hch, n_tokens in unreplayed[m]:
            if hch != "object":
                raise chaos.unrecoverable(
                    m, hop_id,
                    f"replaying hop {h} needs its activation re-read, but "
                    f"the queue channel deleted it at receipt commit — "
                    f"lower checkpoint_every so every drained hop is "
                    f"covered by a KV checkpoint, or route boundaries over "
                    f"the object channel")
            now, _ = fabrics["object"].get_obj(h, m, f"{src_rank}_{m}.dat",
                                               w.abs_time)
            w.advance_to_abs(now)
            if w.ledger is not None:
                w.ledger.sync_to(w.abs_time)
            w.charge_compute(executors[m].flops_per_token * n_tokens, compute)

    def drain_hop(hop_id: int, src_rank: int, m: int, n_rows: int,
                  width_: int, ch: str) -> np.ndarray:
        """The fault-aware hop drain.  A doomed drain (armed crash site,
        peeked without consuming) defers its queue receipt deletes and
        abandons them, so the messages stay in flight and redeliver; then
        the stage recovers and drains again."""
        fab = fabrics[ch]
        w = workers[m]
        if chaos is not None and chaos.peek_crash(m, hop_id, "drain"):
            _drain_activation(hop_id, src_rank, w, n_rows, width_, ch, fab,
                              compute,
                              receipts_out=[] if ch == "queue" else None)
            chaos.should_crash(m, hop_id, "drain")  # consume the site
            _recover_stage(m, hop_id)
            buf = _drain_activation(hop_id, src_rank, w, n_rows, width_, ch,
                                    fab, compute)
        else:
            buf = _drain_activation(hop_id, src_rank, w, n_rows, width_, ch,
                                    fab, compute)
        if chaos is not None:
            unreplayed[m].append((hop_id, src_rank, ch, n_rows))
        return buf

    def f32_panel(x) -> np.ndarray:
        a = np.asarray(x)
        return np.ascontiguousarray(
            a.reshape(-1, a.shape[-1]).astype(np.float32))

    def charge_stage(m: int, n_tokens: int) -> None:
        w = workers[m]
        if w.ledger is not None:
            w.ledger.join_compute()  # the stage compute needs its drain done
        w.charge_compute(executors[m].flops_per_token * n_tokens, compute)

    # ---------------- prefill chain -----------------------------------------
    act_dtype = None
    out = None
    hop = None
    n_rows = width = 0
    for m in range(P):
        w, ex = workers[m], executors[m]
        if m == 0:
            x_in = jnp.asarray(prompts, jnp.int32)
        else:
            ch = boundary_ch[m - 1]
            buf = drain_hop(hop, m - 1, m, n_rows, width, ch)
            x_in = jnp.asarray(buf.reshape(B, -1, width)).astype(act_dtype)
        n_prefill_tokens = B * (x_in.shape[1] if m else S)
        out = ex.run_prefill(x_in, max_len, extra=extra if m == 0 else None)
        charge_stage(m, n_prefill_tokens)
        if chaos is not None:
            _checkpoint_kv(m)
        if m < P - 1:
            act_dtype = out.dtype
            panel = f32_panel(out)
            n_rows, width = panel.shape
            hop = next(hops)
            ch = boundary_ch[m]
            _send_activation(hop, panel, w, m + 1, ch, fabrics[ch], compute)

    token = jnp.argmax(out[:, -1:], axis=-1).astype(jnp.int32)

    # ---------------- decode loop -------------------------------------------
    out_tokens: List[np.ndarray] = []
    logits = out
    for step in range(max_new_tokens):
        out_tokens.append(np.asarray(token)[:, 0])
        if P > 1:
            # token loopback: head stage ships the sampled token back to the
            # embedding stage over the channel (a billed hop like any other)
            loop_hop = next(hops)
            _send_activation(
                loop_hop, np.asarray(token, np.float32), workers[P - 1], 0,
                loop_ch, fabrics[loop_ch], compute,
            )
            buf = drain_hop(loop_hop, P - 1, 0, B, 1, loop_ch)
            token = jnp.asarray(buf.astype(np.int32))
        for m in range(P):
            w, ex = workers[m], executors[m]
            if m == 0:
                x_in = token
            else:
                ch = boundary_ch[m - 1]
                buf = drain_hop(hop, m - 1, m, B, width, ch)
                x_in = jnp.asarray(buf[:, None, :]).astype(act_dtype)
            out = ex.run_decode(x_in)
            charge_stage(m, B)
            if chaos is not None and step % faults.checkpoint_every == 0:
                _checkpoint_kv(m)
            if m < P - 1:
                act_dtype = out.dtype
                panel = f32_panel(out)
                width = panel.shape[1]
                hop = next(hops)
                ch = boundary_ch[m]
                _send_activation(hop, panel, w, m + 1, ch, fabrics[ch],
                                 compute)
        logits = out
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)

    # ---------------- billing ------------------------------------------------
    phased_times = np.array([w.abs_time for w in workers])
    ledger_times = np.array([w.overlap_time for w in workers])
    times = ledger_times if overlap else phased_times
    starts = np.array([w.start_time for w in workers])
    stats = WorkloadStats(
        P=P, mean_runtime_s=float((times - starts).mean()),
        memory_mb=memory_mb,
    )
    raw, wire = 0, 0
    extra_metrics: Dict[str, float] = {}
    if "queue" in fabrics:
        qm = fabrics["queue"].metrics
        stats.publish_units = qm.publish_billed_units
        stats.bytes_sns_to_sqs = qm.bytes_sns_to_sqs
        stats.sqs_api_calls = qm.sqs_api_calls
        raw += qm.raw_bytes
        wire += qm.bytes_sns_to_sqs
        extra_metrics.update({
            "publish_api_calls": qm.publish_api_calls,
            "messages": qm.messages_delivered,
            "empty_polls": qm.empty_polls,
            "redeliveries": qm.redeliveries,
        })
    if "object" in fabrics:
        om = fabrics["object"].metrics
        stats.s3_puts = om.puts
        stats.s3_gets = om.gets
        stats.s3_lists = om.lists
        raw += om.raw_bytes
        wire += om.bytes_written
        extra_metrics["nul_files"] = om.nul_files
    # communication sums both fabrics' tariffs (each is 0 for unused stats)
    cost = CostBreakdown(
        compute=lambda_cost(stats, pricing),
        communication=(queue_cost(stats, pricing).communication
                       + object_cost(stats, pricing).communication),
    )
    if chaos is not None:
        # recovery line: re-invocation fees + durable KV-checkpoint store
        # tariffs; redelivery/replay traffic stays on communication, and the
        # recovery runtime is on compute via mean_runtime_s
        cm = ckpt_fabric.metrics
        ckpt_stats = WorkloadStats(P=P, mean_runtime_s=0.0,
                                   memory_mb=memory_mb, s3_puts=cm.puts,
                                   s3_gets=cm.gets, s3_lists=cm.lists)
        cost.recovery = (sum(chaos.reinvokes.values())
                         * pricing.lambda_invoke
                         + object_cost(ckpt_stats, pricing).communication)

    act_bytes = B * cfg.d_model * 4
    decode_ch = boundary_ch[0] if boundary_ch else loop_ch
    metrics = {
        "flops_total": float(sum(w.flops for w in workers)),
        "phased_makespan_s": float(phased_times.max()),
        "overlap_makespan_s": float(ledger_times.max()),
        "hops": float(next(hops)),
        # analytic per-hop $ (cost-model Eq. 5-7 on one decode activation) —
        # the stage planner's a-priori estimate alongside the billed truth
        "est_decode_hop_usd": activation_hop_cost(decode_ch, act_bytes,
                                                  pricing),
        **{k: float(v) for k, v in extra_metrics.items()},
    }
    if chaos is not None:
        cm = ckpt_fabric.metrics
        metrics.update({
            "recovery_usd": cost.recovery,
            "n_reinvokes": float(sum(chaos.reinvokes.values())),
            "checkpoint_puts": float(cm.puts),
            "checkpoint_bytes": float(cm.bytes_written),
            "throttle_retries": float(sum(
                fab.metrics.throttle_retries for fab in fabrics.values())),
        })
    if plan_str is not None:
        metrics["chosen_channel_plan"] = plan_str
    return LmPipelineResult(
        tokens=np.stack(out_tokens, axis=1).astype(np.int32),
        logits=np.asarray(logits[:, 0], np.float32),
        channel=channel, P=P, plan=plan, worker_times=times, stats=stats,
        cost=cost, raw_exchange_bytes=int(raw), wire_exchange_bytes=int(wire),
        metrics=metrics,
    )


def _lm_autotune_plan(
    B: int, S: int, d_model: int, P: int, max_new_tokens: int,
    pricing: PricingConstants,
):
    """Per-stage-boundary channel choice from the live cost model.

    A boundary ships one [B·S, d] prefill panel plus ``max_new_tokens``
    [B, d] decode panels per request; the planner sums
    ``activation_hop_cost`` over those payloads (chunk header + row ids +
    float32 values, the exact ``pack_rows`` framing) and picks the cheaper
    channel per boundary — ties go to queue (lower latency per hop).  The
    token loopback (head → embedding, [B, 1] per step) is chosen the same
    way.  Deterministic in the request shape, so overlap/phased twins of a
    run see one plan."""
    def hop(ch: str, n_rows: int, width: int) -> float:
        nbytes = 24 + n_rows * (4 + 4 * width)
        return activation_hop_cost(ch, nbytes, pricing)

    boundary: List[str] = []
    for _ in range(max(0, P - 1)):
        cost = {
            ch: hop(ch, B * S, d_model) + max_new_tokens * hop(ch, B, d_model)
            for ch in ("queue", "object")
        }
        boundary.append("queue" if cost["queue"] <= cost["object"]
                        else "object")
    lcost = {ch: max_new_tokens * hop(ch, B, 1) for ch in ("queue", "object")}
    loop = "queue" if lcost["queue"] <= lcost["object"] else "object"
    return boundary, loop
