"""Hierarchical worker launch (paper §III, `worker_invoke_children`).

Workers form a B-ary tree in heap numbering: worker ``m`` invokes children
``m*B + 1 + i`` for ``i < B`` (while < P).  Each worker derives its own rank
from (parent id, sibling number, branching factor), so no central registry is
needed — objective 3 of §II-B.  Spreading invocation across all internal
nodes parallelizes the cold-start cascade; the paper reports this beats both
a centralized single-loop launch and Lambada's two-level loop.

`launch_schedule` returns per-worker ready times under a latency model, and
the comparison helpers reproduce that claim as a benchmark.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

__all__ = ["TreeSpec", "children_of", "parent_of", "launch_schedule",
           "warm_pool_schedule", "central_launch_schedule",
           "two_level_launch_schedule"]


@dataclasses.dataclass(frozen=True)
class TreeSpec:
    n_workers: int
    branching: int = 4

    def children(self, m: int) -> List[int]:
        return children_of(m, self.n_workers, self.branching)

    def parent(self, m: int) -> int:
        return parent_of(m, self.branching)

    def depth(self, m: int) -> int:
        d = 0
        while m > 0:
            m = parent_of(m, self.branching)
            d += 1
        return d

    def is_leaf(self, m: int) -> bool:
        return not self.children(m)


def children_of(m: int, P: int, B: int) -> List[int]:
    return [c for c in range(m * B + 1, m * B + 1 + B) if c < P]


def parent_of(m: int, B: int) -> int:
    if m == 0:
        raise ValueError("root has no parent")
    return (m - 1) // B


def _tree_schedule(
    P: int, branching: int, invoke_latency: float, cold_start: float,
    jitter: np.ndarray,
) -> Tuple[np.ndarray, np.ndarray]:
    """(invoked_at, ready) for the hierarchical tree launch with per-worker
    cold-start jitter already drawn."""
    invoked = np.zeros(P)
    ready = np.zeros(P)
    ready[0] = cold_start + jitter[0]
    # BFS in heap order is already topological: parent < child index-wise
    for m in range(P):
        t = ready[m]
        for i, c in enumerate(children_of(m, P, branching)):
            invoked[c] = t + (i + 1) * invoke_latency
            ready[c] = invoked[c] + cold_start + jitter[c]
    return invoked, ready


def launch_schedule(
    P: int,
    branching: int = 4,
    invoke_latency: float = 0.050,
    cold_start: float = 0.250,
    cold_start_jitter: float = 0.0,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
) -> np.ndarray:
    """Ready time of every worker under the hierarchical tree launch.

    A worker becomes *ready* after its own cold start; it then issues its
    child invocations sequentially (each costs `invoke_latency` of its own
    time) before starting compute — matching the paper's design where
    invoking the sub-tree is 'a precursor to executing its compute role'.

    Jitter draws come from ``rng`` when given (``SimulatorConfig`` threads
    its launch stream here), else from a generator seeded with ``seed`` —
    either way the schedule is a pure function of its inputs.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    jitter = rng.random(P) * cold_start_jitter
    _, ready = _tree_schedule(P, branching, invoke_latency, cold_start, jitter)
    return ready


def warm_pool_schedule(
    P: int,
    branching: int = 4,
    invoke_latency: float = 0.050,
    cold_start: float = 0.250,
    cold_start_jitter: float = 0.0,
    seed: int = 0,
    rng: Optional[np.random.Generator] = None,
    weight_load_s: float | np.ndarray = 0.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """Warm-pool policy: the same tree launch cascade runs BEFORE the request
    arrives, and every worker pre-loads its weight shard; the pool is
    declared hot when the last worker finishes, and the request epoch is
    re-based to that instant.

    Returns ``(ready, provision_s)``: ``ready`` is all-zeros (every worker is
    idle-hot at the request epoch) and ``provision_s[m]`` is worker ``m``'s
    billed pre-request runtime — from its invocation (Lambda bills init
    duration) through pool-hot — the input to
    :func:`repro.core.cost_model.warm_pool_cost`.  Same jitter stream as
    :func:`launch_schedule`, so warm and on-demand runs of one seed draw
    identical cold starts.
    """
    if rng is None:
        rng = np.random.default_rng(seed)
    jitter = rng.random(P) * cold_start_jitter
    invoked, ready = _tree_schedule(P, branching, invoke_latency, cold_start,
                                    jitter)
    loaded = ready + np.broadcast_to(np.asarray(weight_load_s, float), (P,))
    pool_hot = float(loaded.max())
    provision_s = pool_hot - invoked
    return np.zeros(P), provision_s


def central_launch_schedule(
    P: int, invoke_latency: float = 0.050, cold_start: float = 0.250,
) -> np.ndarray:
    """Coordinator invokes all P workers in one loop."""
    ready = np.zeros(P)
    for m in range(P):
        ready[m] = (m + 1) * invoke_latency + cold_start
    return ready


def two_level_launch_schedule(
    P: int, fan: int | None = None,
    invoke_latency: float = 0.050, cold_start: float = 0.250,
) -> np.ndarray:
    """Lambada-style: coordinator invokes sqrt(P) lieutenants, each invokes
    its slice."""
    fan = fan or max(1, int(np.ceil(np.sqrt(P))))
    ready = np.zeros(P)
    lieutenants = list(range(0, P, fan))
    for j, m in enumerate(lieutenants):
        ready[m] = (j + 1) * invoke_latency + cold_start
        for i, c in enumerate(range(m + 1, min(m + fan, P))):
            ready[c] = ready[m] + (i + 1) * invoke_latency + cold_start
    return ready
