"""Fully-serverless execution substrate (simulated AWS data plane).

Every byte that moves between simulated Lambda workers really moves —
serialized, zlib-compressed, size-capped and billed exactly as SNS/SQS/S3
would — so the cost model validation and the Queue-vs-Object trade-off are
measured, not asserted.
"""

from repro.faas.simulator import LatencyModel, run_fsi, FsiRunResult  # noqa: F401
