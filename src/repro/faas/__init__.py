"""Fully-serverless execution substrate (simulated AWS data plane).

Every byte that moves between simulated Lambda workers really moves —
serialized, zlib-compressed, size-capped and billed exactly as SNS/SQS/S3
would — so the cost model validation and the Queue-vs-Object trade-off are
measured, not asserted.

The simulator re-exports are lazy (PEP 562): ``repro.faas.simulator`` imports
``repro.core.fsi``, which imports fabric submodules from this package — an
eager import here would make ``import repro.core.fsi`` circular.
"""

_SIMULATOR_EXPORTS = ("LatencyModel", "run_fsi", "FsiRunResult",
                      "FaultPlan", "FleetFailure")

__all__ = list(_SIMULATOR_EXPORTS)


def __getattr__(name):
    if name in _SIMULATOR_EXPORTS:
        from repro.faas import simulator

        return getattr(simulator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
