"""End-to-end FSD-Inference run orchestration (the deterministic simulator).

``run_fsi`` is the entry point: partition the network, build comm plans and
offline worker artifacts, launch the worker tree, execute the FSI algorithm
layer-by-layer on every (simulated) Lambda, then Barrier + Reduce the output
panels to worker 0.  Every byte is really serialized/compressed/capped and
billed; worker clocks advance per the latency model, so the result carries
both the *output* (validated against the dense oracle in tests) and the
*latency + $-cost* profile (validated against the paper's §VI numbers in
benchmarks).

Fault tolerance: stragglers are modeled as slowed-down workers; when
``reinvoke_stragglers`` is set, workers whose per-layer compute exceeds
``straggler_timeout`` × the fleet median are re-invoked (cold start + weight
reload penalty, then full speed), per the pre-emptive retry literature the
paper cites.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Literal, Optional, Union

import numpy as np

from repro.core.cost_model import (
    AWS_PRICING,
    CostBreakdown,
    PricingConstants,
    WorkloadStats,
    activation_hop_cost,
    lambda_cost,
    object_cost,
    queue_cost,
    serial_cost,
    warm_pool_cost,
)
from repro.core.backends import ComputeBackend, get_backend
from repro.core.fsi import (
    WorkerArtifacts,
    charge_finish,
    fsi_object_recv,
    fsi_object_recv_fleet,
    fsi_object_send_and_local,
    fsi_object_send_and_local_fleet,
    fsi_queue_recv,
    fsi_queue_recv_fleet,
    fsi_queue_send_and_local,
    fsi_queue_send_and_local_fleet,
    prepare_worker_artifacts,
    run_serial,
)
from repro.core.partitioner import PartitionResult, partition_network
from repro.core.send_recv import build_comm_plans
from repro.data.graphchallenge import GraphChallengeNet
from repro.faas.chaos import ChaosState, FaultPlan, FleetFailure
from repro.faas.collectives import reduce_to_root
from repro.faas.launch_tree import TreeSpec, launch_schedule, warm_pool_schedule
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import ComputeModel, EventLedger, WorkerState

__all__ = ["LatencyModel", "SimulatorConfig", "FsiRunResult", "run_fsi",
           "charge_weight_load", "FaultPlan", "FleetFailure"]

Channel = Literal["queue", "object", "serial", "auto"]


@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    """Run policy + seeded RNG threading for the deterministic simulator.

    Every random draw a run makes — launch-tree cold-start jitter, straggler
    assignment, short-poll visibility — flows from this one seed through
    named, non-colliding streams, so two runs with an identical config
    produce identical makespans, metrics, and bills on both clock models.
    (Previously the straggler stream was derived as ``seed + 99``, which
    collides with the *launch* stream of a run seeded ``seed + 99`` —
    supposedly independent draws were correlated across runs.)

    ``eager_poll`` — consumers park their long-poll / LIST loop for the next
    layer before the publisher finishes, so the publish→poll RTT overlaps
    the sender's pack+publish on the ledger timeline (billing unchanged).
    ``warm_pool`` — workers are pre-invoked and weights pre-loaded before
    the request arrives; the pre-request GB-seconds are billed explicitly on
    the ``CostBreakdown.warm_pool`` line.
    """

    seed: int = 0
    eager_poll: bool = True
    warm_pool: bool = False

    def launch_rng(self) -> np.random.Generator:
        """Cold-start jitter stream — pinned to the historical root stream
        (``default_rng(seed)``) so committed bench baselines stay
        comparable across this refactor."""
        return np.random.default_rng(self.seed)

    def rng(self, stream: str) -> np.random.Generator:
        """A named stream statistically independent of every other stream
        and of any other seed's streams."""
        return np.random.default_rng([self.seed,
                                      zlib.crc32(stream.encode("utf-8"))])


@dataclasses.dataclass
class LatencyModel:
    """Service latency/throughput constants (defaults: public AWS figures)."""

    invoke_latency: float = 0.050
    cold_start: float = 0.250
    cold_start_jitter: float = 0.100
    sns_publish_latency: float = 0.012
    sns_fanout_latency: float = 0.020
    sqs_poll_rtt: float = 0.008
    sqs_long_poll_window: float = 2.0
    s3_put_latency: float = 0.030
    s3_get_first_byte: float = 0.018
    s3_list_latency: float = 0.025
    s3_bandwidth: float = 90e6
    weight_load_bandwidth: float = 250e6  # S3 model-shard read at startup
    straggler_prob: float = 0.0
    straggler_slowdown: float = 4.0


@dataclasses.dataclass
class FsiRunResult:
    output: np.ndarray                    # x^L assembled at worker 0 [N, batch]
    channel: Channel
    P: int
    worker_times: np.ndarray              # T_i (seconds, incl. launch offset)
    stats: WorkloadStats
    cost: CostBreakdown
    partition: Optional[PartitionResult]
    raw_exchange_bytes: int               # pre-compression volume (Table III)
    wire_exchange_bytes: int              # compressed bytes on the channel
    metrics: Dict[str, float]

    @property
    def mean_runtime(self) -> float:
        return float(self.worker_times.mean())

    @property
    def makespan(self) -> float:
        return float(self.worker_times.max())

    def per_sample_ms(self, batch: int) -> float:
        return self.makespan / batch * 1e3


def charge_weight_load(worker: WorkerState, artifact, latency: "LatencyModel") -> None:
    """Bill a worker's model-shard read from object storage at the startup
    read bandwidth.  One definition for every call site — FSI worker init,
    straggler re-invoke, and LM-pipeline stage cold start — so the cost
    expression can't drift.

    The shard size is the artifact's ``weight_bytes`` when it carries one (an
    LM pipeline stage loads only its own layer slice — it must never be
    billed the full-model read), else the FSI convention CSR nnz × 8B.

    On the overlapped ledger this is a fleet-wide stall: nothing can compute
    or communicate without the weights, so both timelines sync."""
    nbytes = getattr(artifact, "weight_bytes", None)
    if not nbytes:
        nbytes = artifact.weight_nnz * 8
    s = nbytes / latency.weight_load_bandwidth
    worker.charge_seconds(s)
    if worker.ledger is not None:
        worker.ledger.sync(s)


def run_fsi(
    net: GraphChallengeNet,
    x0: np.ndarray,
    P: int = 8,
    channel: Channel = "queue",
    partition_method: str = "hgp",
    memory_mb: Optional[int] = None,
    latency: Optional[LatencyModel] = None,
    compute: Optional[ComputeModel] = None,
    pricing: PricingConstants = AWS_PRICING,
    branching: int = 4,
    seed: int = 0,
    exploit_sparsity: bool = True,
    reinvoke_stragglers: bool = False,
    straggler_timeout: float = 3.0,
    partition: Optional[PartitionResult] = None,
    compute_backend: Union[str, ComputeBackend, None] = None,
    mesh: Optional[object] = None,
    channel_batching: bool = True,
    overlap: bool = True,
    eager_poll: bool = True,
    warm_pool: bool = False,
    sim: Optional[SimulatorConfig] = None,
    faults: Optional[FaultPlan] = None,
) -> FsiRunResult:
    """Run distributed FSI over a simulated serverless fleet.

    ``overlap`` selects which clock model the result reports.  Both models
    are always computed side by side: the strict-sum **phased** clock drives
    every fabric interaction (publishes, polls, LISTs — hence all billable
    counts), while the **event ledger** re-times the same events with
    per-worker compute/channel timelines merged only at dependency edges
    (layer k's drain overlaps layer k's publish lanes and local MVP).  With
    ``overlap=True`` (the default) worker times and billed durations come
    from the ledger; ``overlap=False`` reports the phased clock and serves
    as the differential oracle — charge counts are bit-identical between the
    two by construction.  Both makespans are always exposed in ``metrics``.

    ``eager_poll`` (default on) re-times ledger receives as if each consumer
    had its next-layer long-poll / LIST already parked when the publish
    landed — ledger-only, so no billable count moves.  ``warm_pool`` (default
    off: it adds a cost line) pre-invokes the fleet and pre-loads weights
    before the request epoch; the pre-request GB-seconds surface as
    ``CostBreakdown.warm_pool`` / ``metrics["warm_pool_usd"]``.
    ``channel="auto"`` picks queue vs object per layer boundary (and for the
    output gather) from ``activation_hop_cost`` over the comm plan's payload
    bytes; the plan string lands in ``metrics["chosen_channel_plan"]``.
    ``sim`` bundles seed + policy; when given it overrides ``seed`` /
    ``eager_poll`` / ``warm_pool``.

    ``faults`` injects a seeded :class:`~repro.faas.chaos.FaultPlan`:
    workers killed at chosen (layer, phase) sites are re-invoked (cold
    start + weight reload — or a warm-pool spare — on real cost lines),
    restore their input panel from a durable checkpoint written every
    ``checkpoint_every`` layers, and replay the layer handler; undeleted
    queue messages redeliver after the visibility timeout and durable
    objects are re-GET.  The output stays bitwise equal to the fault-free
    run while every recovery action bills (``CostBreakdown.recovery`` for
    re-invocations + the checkpoint store; redelivery/replay traffic on
    ``communication``; recovery runtime on ``compute``).  An unrecoverable
    plan raises :class:`~repro.faas.chaos.FleetFailure` with per-worker
    diagnostics.  With ``faults=None`` nothing changes — every billable
    counter stays bit-identical to the fault-free baseline.  Fault
    injection drives the per-worker host path (no fleet batching).
    """
    latency = latency or LatencyModel()
    compute = compute or ComputeModel()
    if sim is None:
        sim = SimulatorConfig(seed=seed, eager_poll=eager_poll,
                              warm_pool=warm_pool)
    seed = sim.seed
    backend = get_backend(compute_backend)
    # Mesh threading for device-sharded fleet backends (pallas-bsr-sharded):
    # the mesh rides on the backend instance, so everything downstream —
    # prepare_worker_artifacts, fleet_prepare_all, fleet_apply — sees one
    # consistent worker-axis layout without new plumbing.
    if mesh is not None:
        if not hasattr(backend, "with_mesh"):
            raise ValueError(
                f"compute backend {backend.name!r} does not take a mesh; "
                f"use 'pallas-bsr-sharded'"
            )
        backend = backend.with_mesh(mesh)
    batch = x0.shape[1]

    # ---------------- Serial short-circuit ---------------------------------
    if channel == "serial" or P == 1:
        memory_mb = memory_mb or pricing.max_lambda_memory_mb
        out, w = run_serial(net, x0, memory_mb=memory_mb, compute=compute,
                            backend=backend)
        w.charge_seconds(net.model_bytes / latency.weight_load_bandwidth)
        times = np.array([w.clock + latency.cold_start])
        stats = WorkloadStats(P=1, mean_runtime_s=float(times.mean()), memory_mb=memory_mb)
        return FsiRunResult(
            output=out, channel="serial", P=1, worker_times=times, stats=stats,
            cost=serial_cost(stats, pricing), partition=None,
            raw_exchange_bytes=0, wire_exchange_bytes=0,
            metrics={"flops": w.flops},
        )

    # ---------------- offline partitioning + plans --------------------------
    if partition is None:
        partition = partition_network(net.layers, P, method=partition_method, seed=seed)
    plans = build_comm_plans(net.layers, partition)
    artifacts = prepare_worker_artifacts(net.layers, partition, plans,
                                         backend=backend)
    # Fleet batching: pallas-bsr stacks each layer's per-worker operands so
    # one device dispatch serves all P workers; pallas-bsr-sharded lays that
    # stack over a `worker` mesh axis (shard_map, blocked P/D per device);
    # numpy backends return None and finish per worker.
    fleet_states = backend.fleet_prepare_all(
        [[artifacts[m].layers[k].state_for(backend) for m in range(P)]
         for k in range(net.n_layers)]
    )

    memory_mb = memory_mb or _default_memory_mb(net.neurons)
    for a in artifacts:
        need = a.memory_bytes(batch)
        if need > memory_mb * 1024 * 1024:
            raise MemoryError(
                f"worker {a.rank} shard needs ~{need/1e6:.0f}MB > {memory_mb}MB; "
                f"increase P or memory"
            )

    # ---------------- launch tree -------------------------------------------
    provision_s: Optional[np.ndarray] = None
    if sim.warm_pool:
        # the same cascade + weight loads run before the request epoch; the
        # per-worker pre-request runtime is billed on its own cost line
        weight_load_s = np.array([
            (getattr(artifacts[m], "weight_bytes", None)
             or artifacts[m].weight_nnz * 8) / latency.weight_load_bandwidth
            for m in range(P)
        ])
        ready, provision_s = warm_pool_schedule(
            P, branching=branching, invoke_latency=latency.invoke_latency,
            cold_start=latency.cold_start,
            cold_start_jitter=latency.cold_start_jitter,
            rng=sim.launch_rng(), weight_load_s=weight_load_s,
        )
    else:
        ready = launch_schedule(
            P, branching=branching, invoke_latency=latency.invoke_latency,
            cold_start=latency.cold_start,
            cold_start_jitter=latency.cold_start_jitter,
            rng=sim.launch_rng(),
        )
    rng = sim.rng("straggler")
    workers: List[WorkerState] = []
    for m in range(P):
        w = WorkerState(rank=m, memory_mb=memory_mb, start_time=float(ready[m]),
                        ledger=EventLedger(t_compute=float(ready[m]),
                                           t_channel=float(ready[m]),
                                           eager_poll=sim.eager_poll))
        if latency.straggler_prob > 0 and rng.random() < latency.straggler_prob:
            w.slowdown = latency.straggler_slowdown
        if not sim.warm_pool:
            # weight shard load from object storage (paper: workers reload
            # per request); warm pools pre-loaded during provisioning
            charge_weight_load(w, artifacts[m], latency)
        workers.append(w)

    # ---------------- fabric(s) ----------------------------------------------
    def _mk_fabric(ch: str):
        if ch == "queue":
            return QueueFabric(
                P, pricing=pricing,
                publish_latency=latency.sns_publish_latency,
                fanout_latency=latency.sns_fanout_latency,
                poll_rtt=latency.sqs_poll_rtt,
                long_poll_window=latency.sqs_long_poll_window,
                seed=seed,
            )
        return ObjectFabric(
            P,
            put_latency=latency.s3_put_latency,
            get_first_byte=latency.s3_get_first_byte,
            list_latency=latency.s3_list_latency,
            bandwidth=latency.s3_bandwidth,
        )

    if channel == "auto":
        plan_channels, gather_ch = _autotune_plan(
            artifacts, batch, net.n_layers, P, branching, pricing)
        plan_str = "".join(c[0] for c in plan_channels) + "+" + gather_ch[0]
    elif channel in ("queue", "object"):
        plan_channels = [channel] * net.n_layers
        gather_ch = channel
        plan_str = None
    else:
        raise ValueError(channel)
    fabrics = {ch: _mk_fabric(ch)
               for ch in dict.fromkeys(list(plan_channels) + [gather_ch])}

    # ---------------- chaos / recovery plumbing ------------------------------
    chaos: Optional[ChaosState] = None
    ckpt_fabric: Optional[ObjectFabric] = None
    # warm-pool spares drawn on re-invoke (stragglers or crash recovery);
    # their pre-provisioning seconds fold into the warm-pool cost line
    spare_provision_s: List[float] = []
    runtime_start = [w.clock for w in workers]
    if faults is not None:
        chaos = faults.activate()
        for fab in fabrics.values():
            fab.chaos = chaos
        # The panel-checkpoint store: durable, on its own prefix space, and
        # billed on the *recovery* cost line rather than communication.
        ckpt_fabric = ObjectFabric(
            P,
            put_latency=latency.s3_put_latency,
            get_first_byte=latency.s3_get_first_byte,
            list_latency=latency.s3_list_latency,
            bandwidth=latency.s3_bandwidth,
        )

    # ---------------- layer loop --------------------------------------------
    x_panels: List[np.ndarray] = [
        x0[artifacts[m].x0_rows].astype(np.float32) for m in range(P)
    ]
    for k in range(net.n_layers):
        t_before = [w.clock for w in workers]
        arts_k = [artifacts[m].layers[k] for m in range(P)]
        ch_k = plan_channels[k]
        fabric = fabrics[ch_k]
        if chaos is not None:
            # Crash-fault path: per-worker handlers with kill sites, panel
            # checkpoints, and re-invoke recovery (see _chaos_run_layer).
            x_panels = _chaos_run_layer(
                k, net, artifacts, x_panels, workers, fabrics, plan_channels,
                backend, compute, latency, chaos, ckpt_fabric, sim.warm_pool,
                spare_provision_s, runtime_start, exploit_sparsity,
            )
            _check_stragglers(
                reinvoke_stragglers, workers, t_before, straggler_timeout,
                artifacts, latency, sim.warm_pool, spare_provision_s)
            continue
        # Phases 1+2 — publish + overlapped local MVP, then drain the channel.
        # ``channel_batching`` (the default) runs the fleet-batched host path:
        # one pack pass and one vectorized drain scatter per layer instead of
        # O(P) Python-level passes.  Billed charges are bit-identical either
        # way (the fleet variants share the publish/drain helpers — asserted
        # in tests/test_fleet_channels.py).
        bufs: List[np.ndarray]
        if channel_batching:
            if ch_k == "queue":
                fleet_bufs = fsi_queue_send_and_local_fleet(
                    arts_k, x_panels, workers, fabric, compute,
                    exploit_sparsity=exploit_sparsity,
                )
                bufs = fsi_queue_recv_fleet(arts_k, fleet_bufs, workers,
                                            fabric, compute)
            else:
                fleet_bufs = fsi_object_send_and_local_fleet(
                    arts_k, x_panels, workers, fabric, compute,
                    exploit_sparsity=exploit_sparsity,
                )
                bufs = fsi_object_recv_fleet(arts_k, fleet_bufs, workers,
                                             fabric, compute)
        else:
            bufs = []
            for m in range(P):
                art = arts_k[m]
                if ch_k == "queue":
                    bufs.append(fsi_queue_send_and_local(
                        art, x_panels[m], workers[m], fabric, compute,
                        exploit_sparsity=exploit_sparsity,
                    ))
                else:
                    bufs.append(fsi_object_send_and_local(
                        art, x_panels[m], workers[m], fabric, compute,
                        exploit_sparsity=exploit_sparsity,
                    ))
            for m in range(P):
                art = arts_k[m]
                if ch_k == "queue":
                    bufs[m] = fsi_queue_recv(art, bufs[m], workers[m], fabric, compute)
                else:
                    bufs[m] = fsi_object_recv(art, bufs[m], workers[m], fabric, compute)
        if fleet_states is not None:
            outs = backend.fleet_apply(fleet_states[k], bufs, net.bias)
        else:
            outs = [
                backend.apply(
                    artifacts[m].layers[k].state_for(backend), bufs[m], net.bias
                )
                for m in range(P)
            ]
        for m in range(P):
            x_panels[m] = charge_finish(
                artifacts[m].layers[k], bufs[m], outs[m], workers[m], compute
            )
        # Straggler slowdown applies to *active* work (compute, pack/unpack)
        # via WorkerState.slowdown at the charge sites — never to channel
        # waits, which would compound across the fleet.
        _check_stragglers(
            reinvoke_stragglers, workers, t_before, straggler_timeout,
            artifacts, latency, sim.warm_pool, spare_provision_s)

    if chaos is not None:
        # Mailbox sweep: a worker recovered at the *last* layer re-published
        # duplicates its peers had already drained past — they must be
        # polled and deleted (billed) before the queues host the reduce.
        for fab in fabrics.values():
            if not isinstance(fab, QueueFabric):
                continue
            for m, w in enumerate(workers):
                receipts: List[int] = []
                while fab.pending(m):
                    now, ds = fab.poll(m, w.abs_time)
                    w.advance_to_abs(now)
                    receipts.extend(d.receipt for d in ds)
                if receipts:
                    w.advance_to_abs(
                        fab.delete_batch(m, receipts, w.abs_time))

    # ---------------- fused sync + reduce (Algorithm lines 19-20) ------------
    # FMI-style collective fusion: the output reduce's up-sweep payload
    # doubles as the barrier token (``sync=True``), so the separate barrier
    # up/down sweeps — two full tree traversals of token messages — vanish
    # from both clock models and from the bill.
    tree = TreeSpec(n_workers=P, branching=branching)
    panels = [x_panels[m] for m in range(P)]
    gathered = reduce_to_root(workers, fabrics[gather_ch], tree, panels,
                              op="concat_rows", sync=True)
    order = np.argsort(np.concatenate([artifacts[m].layers[-1].out_rows for m in range(P)]))
    output = gathered[order]

    # ---------------- billing -------------------------------------------------
    phased_times = np.array([w.abs_time for w in workers])
    ledger_times = np.array([w.overlap_time for w in workers])
    times = ledger_times if overlap else phased_times
    starts = np.array([w.start_time for w in workers])
    stats = WorkloadStats(
        P=P, mean_runtime_s=float((times - starts).mean()),
        memory_mb=memory_mb,
    )
    raw, wire = 0, 0
    extra: Dict[str, float] = {}
    if "queue" in fabrics:
        qm = fabrics["queue"].metrics
        stats.publish_units = qm.publish_billed_units
        stats.bytes_sns_to_sqs = qm.bytes_sns_to_sqs
        stats.sqs_api_calls = qm.sqs_api_calls
        raw += qm.raw_bytes
        wire += qm.bytes_sns_to_sqs
        extra.update({
            "publish_api_calls": qm.publish_api_calls,
            "messages": qm.messages_delivered,
            "empty_polls": qm.empty_polls,
            "redeliveries": qm.redeliveries,
        })
    if "object" in fabrics:
        om = fabrics["object"].metrics
        stats.s3_puts = om.puts
        stats.s3_gets = om.gets
        stats.s3_lists = om.lists
        raw += om.raw_bytes
        wire += om.bytes_written
        extra["nul_files"] = om.nul_files
    # communication sums both fabrics' tariffs (each is 0 for unused stats)
    cost = CostBreakdown(
        compute=lambda_cost(stats, pricing),
        communication=(queue_cost(stats, pricing).communication
                       + object_cost(stats, pricing).communication),
    )
    if provision_s is not None:
        cost.warm_pool = warm_pool_cost(
            list(provision_s) + spare_provision_s, memory_mb, pricing)
    if chaos is not None:
        # recovery line: re-invocation fees + the checkpoint store's request
        # tariffs.  Redelivery / replay traffic on the main fabrics already
        # landed on ``communication`` (where the provider bills it) and
        # recovery runtime on ``compute`` via mean_runtime.
        n_reinvokes = sum(chaos.reinvokes.values())
        cm = ckpt_fabric.metrics
        ckpt_stats = WorkloadStats(
            P=P, mean_runtime_s=0.0, memory_mb=memory_mb,
            s3_puts=cm.puts, s3_gets=cm.gets, s3_lists=cm.lists,
        )
        cost.recovery = (n_reinvokes * pricing.lambda_invoke
                         + object_cost(ckpt_stats, pricing).communication)

    metrics = {
        "flops_total": float(sum(w.flops for w in workers)),
        "imbalance": partition.imbalance(net.layers),
        # both clock models are always computed; the flag only selects which
        # one ``worker_times``/``stats`` report
        "phased_makespan_s": float(phased_times.max()),
        "overlap_makespan_s": float(ledger_times.max()),
        **{k: float(v) for k, v in extra.items()},
    }
    if plan_str is not None:
        metrics["chosen_channel_plan"] = plan_str
    if provision_s is not None:
        metrics["warm_pool_usd"] = cost.warm_pool
        metrics["warm_pool_provision_s"] = float(
            np.sum(provision_s) + sum(spare_provision_s))
        metrics["warm_pool_spares"] = float(len(spare_provision_s))
    if chaos is not None:
        metrics["recovery_usd"] = cost.recovery
        metrics["n_reinvokes"] = float(sum(chaos.reinvokes.values()))
        metrics["checkpoint_puts"] = float(ckpt_fabric.metrics.puts)
        metrics["checkpoint_bytes"] = float(ckpt_fabric.metrics.bytes_written)
        metrics["throttle_retries"] = float(
            sum(f.metrics.throttle_retries for f in fabrics.values()))
    return FsiRunResult(
        output=output, channel=channel, P=P, worker_times=times, stats=stats,
        cost=cost, partition=partition,
        raw_exchange_bytes=int(raw), wire_exchange_bytes=int(wire),
        metrics=metrics,
    )


def _check_stragglers(
    reinvoke_stragglers: bool,
    workers: List[WorkerState],
    t_before: List[float],
    straggler_timeout: float,
    artifacts: List[WorkerArtifacts],
    latency: "LatencyModel",
    warm_pool: bool,
    spare_provision_s: List[float],
) -> None:
    """Pre-emptive straggler re-invocation after one layer (paper's cited
    retry mitigation): workers whose layer cost exceeds ``straggler_timeout``
    × the fleet median are replaced with a fresh container.

    On demand that bills a cold start + weight reload on the worker clock;
    under ``warm_pool=True`` the replacement is drawn from the
    pre-provisioned pool instead — the spare already paid its cold start +
    weight load *before* the request, so the clock pays only the invoke
    routing and the spare's provisioning seconds fold into the
    ``CostBreakdown.warm_pool`` line (via ``spare_provision_s``)."""
    if not reinvoke_stragglers:
        return
    layer_cost = np.array([w.clock - t0 for w, t0 in zip(workers, t_before)])
    med = float(np.median(layer_cost))
    for m, w in enumerate(workers):
        if med > 0 and layer_cost[m] > straggler_timeout * med and w.slowdown > 1:
            w.slowdown = 1.0
            if warm_pool:
                w.charge_seconds(latency.invoke_latency)
                if w.ledger is not None:
                    w.ledger.sync(latency.invoke_latency)
                nbytes = (getattr(artifacts[m], "weight_bytes", None)
                          or artifacts[m].weight_nnz * 8)
                spare_provision_s.append(
                    latency.cold_start + nbytes / latency.weight_load_bandwidth)
            else:
                # re-invoke: fresh container (cold start + weight reload),
                # then it runs at full speed
                w.charge_seconds(latency.cold_start)
                if w.ledger is not None:
                    w.ledger.sync(latency.cold_start)
                charge_weight_load(w, artifacts[m], latency)


def _bill_reinvoke(
    w: WorkerState,
    artifact: WorkerArtifacts,
    latency: "LatencyModel",
    warm_pool: bool,
    spare_provision_s: List[float],
) -> None:
    """Bill one crash-recovery re-invocation on the worker's clock models.

    On demand: invoke routing + cold start + weight reload (a fleet-wide
    stall on the ledger — nothing overlaps a dead worker).  Under a warm
    pool the replacement container is already hot: the clock pays only the
    invoke routing, and the spare's pre-request provisioning seconds land on
    the warm-pool cost line."""
    w.charge_seconds(latency.invoke_latency)
    if w.ledger is not None:
        w.ledger.sync(latency.invoke_latency)
    if warm_pool:
        nbytes = (getattr(artifact, "weight_bytes", None)
                  or artifact.weight_nnz * 8)
        spare_provision_s.append(
            latency.cold_start + nbytes / latency.weight_load_bandwidth)
    else:
        w.charge_seconds(latency.cold_start)
        if w.ledger is not None:
            w.ledger.sync(latency.cold_start)
        charge_weight_load(w, artifact, latency)


def _checkpoint_panel(
    ckpt_fabric: ObjectFabric,
    k: int,
    m: int,
    panel: np.ndarray,
    w: WorkerState,
    compute: ComputeModel,
) -> None:
    """PUT worker ``m``'s layer-``k`` input panel to the durable checkpoint
    store.  The upload rides a background connection (async PUT issued
    alongside the layer's sends), so the worker clock pays only the panel
    serialization; the store's request tariffs land on the *recovery* cost
    line at billing time.  This is what keeps the zero-fault overhead of an
    armed FaultPlan at ~0 on both clock models."""
    blob = Chunk(panel.tobytes(), raw_bytes=panel.nbytes)
    s = panel.nbytes / compute.pack_bandwidth * w.slowdown
    w.charge_seconds(s)
    if w.ledger is not None:
        w.ledger.compute(s)
    ckpt_fabric.put_obj(k, m, m, blob, w.abs_time)


def _restore_panel(
    m: int,
    k: int,
    batch: int,
    chaos: ChaosState,
    ckpt_fabric: ObjectFabric,
    artifacts: List[WorkerArtifacts],
    workers: List[WorkerState],
    fabrics: Dict[str, object],
    plan_channels: List[str],
    backend: ComputeBackend,
    compute: ComputeModel,
    net: GraphChallengeNet,
) -> np.ndarray:
    """Reconstruct worker ``m``'s layer-``k`` input panel after a crash.

    The re-invoked container GETs the newest checkpoint at or below ``k``
    (real bytes round-trip — the restored panel is ``np.frombuffer`` of what
    was PUT) and replays the intermediate layers forward.  Replay re-reads
    each layer's remote inputs, which only works where they are still
    readable: durable objects survive their drain, but queue messages were
    deleted when the layer committed — a replayed *queue* layer is
    unrecoverable and raises :class:`FleetFailure` (the checkpoint-cadence
    trade-off: on the queue channel, C=1 is the only fully-recoverable
    cadence).  Replayed layers do not re-publish — the restart driver hands
    the worker its last acknowledged send layer, so only the crashed layer's
    sends go out again."""
    plan = chaos.plan
    k0 = (k // plan.checkpoint_every) * plan.checkpoint_every
    w = workers[m]
    now, blob = ckpt_fabric.get_obj(k0, m, f"{m}_{m}.dat", w.abs_time)
    w.advance_to_abs(now)
    if w.ledger is not None:
        w.ledger.sync_to(w.abs_time)
    panel = np.frombuffer(bytes(blob), dtype=np.float32).reshape(-1, batch).copy()
    for j in range(k0, k):
        if plan_channels[j] != "object":
            raise chaos.unrecoverable(
                m, k,
                f"replaying layer {j} needs its inputs re-read, but the queue "
                f"channel deleted them at commit — lower checkpoint_every "
                f"(C={plan.checkpoint_every}) so a checkpoint lands on layer {k}",
            )
        art = artifacts[m].layers[j]
        buf = np.zeros((len(art.needed_rows), batch), dtype=np.float32)
        buf[art.owned_positions] = panel[art.owned_source_positions]
        w.charge_compute(art.local_flops * batch, compute)
        buf = fsi_object_recv(art, buf, w, fabrics["object"], compute)
        out = backend.apply(art.state_for(backend), buf, net.bias)
        panel = charge_finish(art, buf, out, w, compute)
    return panel


def _chaos_run_layer(
    k: int,
    net: GraphChallengeNet,
    artifacts: List[WorkerArtifacts],
    x_panels: List[np.ndarray],
    workers: List[WorkerState],
    fabrics: Dict[str, object],
    plan_channels: List[str],
    backend: ComputeBackend,
    compute: ComputeModel,
    latency: "LatencyModel",
    chaos: ChaosState,
    ckpt_fabric: ObjectFabric,
    warm_pool: bool,
    spare_provision_s: List[float],
    runtime_start: List[float],
    exploit_sparsity: bool,
) -> List[np.ndarray]:
    """One layer of the crash-fault executor (per-worker host path).

    Kill sites per :data:`~repro.faas.chaos.CRASH_PHASES`:

    * ``send``    — dies before publishing; recovery re-invokes, restores the
      panel, then publishes for the first time;
    * ``compute`` — dies after publishing; the replayed handler publishes
      duplicates, which peers retire via the (src, seq) dedupe;
    * ``drain``   — dies after the drain but before the receipt deletes
      commit; the in-flight messages redeliver after the visibility timeout
      and the re-drain pays the empty polls + redelivery bills for real.

    A ``runtime_limit_s`` overrun is detected at the layer boundary and
    handled as a ``send``-phase kill.  Every recovery recomputes from real
    restored bytes, so the layer's output panels are bitwise identical to
    the fault-free run while every extra publish, poll, GET, and GB-second
    is billed.
    """
    P = len(workers)
    batch = x_panels[0].shape[1]
    plan = chaos.plan
    ch_k = plan_channels[k]
    fabric = fabrics[ch_k]

    if k % plan.checkpoint_every == 0:
        for m in range(P):
            _checkpoint_panel(ckpt_fabric, k, m, x_panels[m], workers[m],
                              compute)

    def send_local(m: int) -> np.ndarray:
        art = artifacts[m].layers[k]
        if ch_k == "queue":
            return fsi_queue_send_and_local(
                art, x_panels[m], workers[m], fabric, compute,
                exploit_sparsity=exploit_sparsity)
        return fsi_object_send_and_local(
            art, x_panels[m], workers[m], fabric, compute,
            exploit_sparsity=exploit_sparsity)

    def recover(m: int, phase: str, reason: str) -> None:
        chaos.record_reinvoke(m, k, phase, reason)
        _bill_reinvoke(workers[m], artifacts[m], latency, warm_pool,
                       spare_provision_s)
        runtime_start[m] = workers[m].clock
        x_panels[m] = _restore_panel(
            m, k, batch, chaos, ckpt_fabric, artifacts, workers, fabrics,
            plan_channels, backend, compute, net)

    bufs: List[Optional[np.ndarray]] = [None] * P
    for m in range(P):
        if (plan.runtime_limit_s is not None
                and workers[m].clock - runtime_start[m] > plan.runtime_limit_s):
            recover(m, "send", "per-function runtime limit exceeded")
        elif chaos.should_crash(m, k, "send"):
            recover(m, "send", "killed before publish")
        bufs[m] = send_local(m)
        if chaos.should_crash(m, k, "compute"):
            recover(m, "compute", "killed after publish, before drain")
            bufs[m] = send_local(m)  # handler replay: duplicate publishes
    for m in range(P):
        art = artifacts[m].layers[k]

        def drain(m: int, doomed: Optional[List[int]] = None) -> np.ndarray:
            if ch_k == "queue":
                return fsi_queue_recv(art, bufs[m], workers[m], fabric,
                                      compute, receipts_out=doomed)
            return fsi_object_recv(art, bufs[m], workers[m], fabric, compute)

        if chaos.peek_crash(m, k, "drain"):
            # A doomed drain defers its deletes: the receipts below are
            # abandoned when the worker dies, stay in flight, and redeliver
            # after the visibility timeout — which the re-drain pays for
            # (empty polls while invisible, then re-billed deliveries).
            bufs[m] = drain(m, doomed=[])
            chaos.should_crash(m, k, "drain")  # consume the site
            recover(m, "drain", "killed before the receipt deletes committed")
            bufs[m] = send_local(m)  # handler replay: duplicate publishes
            bufs[m] = drain(m)
        else:
            bufs[m] = drain(m)
    outs = [
        backend.apply(artifacts[m].layers[k].state_for(backend), bufs[m],
                      net.bias)
        for m in range(P)
    ]
    return [
        charge_finish(artifacts[m].layers[k], bufs[m], outs[m], workers[m],
                      compute)
        for m in range(P)
    ]


def _autotune_plan(
    artifacts: List[WorkerArtifacts], batch: int, n_layers: int, P: int,
    branching: int, pricing: PricingConstants,
):
    """Per-layer-boundary channel choice from the live cost model.

    For every layer the planner sums ``activation_hop_cost`` over the comm
    plan's (src → target) payloads — ``len(rows)`` activation rows of
    ``batch`` float32 each plus the chunk header — and picks the cheaper
    channel; ties go to queue (lower latency per hop).  The output gather is
    chosen the same way over the reduce tree's subtree panel sizes (shipped
    raw, so no compression discount).  Deterministic: the plan depends only
    on the partition, so overlap/phased twins of a run see one plan.
    """
    plan: List[str] = []
    for k in range(n_layers):
        cost = {"queue": 0.0, "object": 0.0}
        for m in range(P):
            for rows in artifacts[m].layers[k].send_global.values():
                nbytes = 24 + len(rows) * (4 + 4 * batch)
                for ch in cost:
                    cost[ch] += activation_hop_cost(ch, nbytes, pricing)
        plan.append("queue" if cost["queue"] <= cost["object"] else "object")
    tree = TreeSpec(n_workers=P, branching=branching)
    sub = [len(a.layers[-1].out_rows) for a in artifacts]
    for m in reversed(range(1, P)):
        sub[tree.parent(m)] += sub[m]
    gcost = {"queue": 0.0, "object": 0.0}
    for m in range(1, P):
        nbytes = sub[m] * batch * 4
        for ch in gcost:
            gcost[ch] += activation_hop_cost(ch, nbytes, pricing,
                                             est_compression_ratio=1.0)
    gather = "queue" if gcost["queue"] <= gcost["object"] else "object"
    return plan, gather


def _default_memory_mb(neurons: int) -> int:
    """Paper §VI-A1 worker sizing: 1000/1500/2000/4000MB for N=1k..64k."""
    return {1024: 1000, 4096: 1500, 16384: 2000, 65536: 4000}.get(neurons, 2000)
