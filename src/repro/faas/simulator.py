"""End-to-end FSD-Inference run orchestration (the deterministic simulator).

``run_fsi`` is the entry point: partition the network, build comm plans and
offline worker artifacts, launch the worker tree, execute the FSI algorithm
layer-by-layer on every (simulated) Lambda, then Barrier + Reduce the output
panels to worker 0.  Every byte is really serialized/compressed/capped and
billed; worker clocks advance per the latency model, so the result carries
both the *output* (validated against the dense oracle in tests) and the
*latency + $-cost* profile (validated against the paper's §VI numbers in
benchmarks).

Fault tolerance: stragglers are modeled as slowed-down workers; when
``reinvoke_stragglers`` is set, workers whose per-layer compute exceeds
``straggler_timeout`` × the fleet median are re-invoked (cold start + weight
reload penalty, then full speed), per the pre-emptive retry literature the
paper cites.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Literal, Optional, Union

import numpy as np

from repro.core.cost_model import (
    AWS_PRICING,
    CostBreakdown,
    PricingConstants,
    WorkloadStats,
    object_cost,
    queue_cost,
    serial_cost,
)
from repro.core.backends import ComputeBackend, get_backend
from repro.core.fsi import (
    WorkerArtifacts,
    charge_finish,
    fsi_object_recv,
    fsi_object_recv_fleet,
    fsi_object_send_and_local,
    fsi_object_send_and_local_fleet,
    fsi_queue_recv,
    fsi_queue_recv_fleet,
    fsi_queue_send_and_local,
    fsi_queue_send_and_local_fleet,
    prepare_worker_artifacts,
    run_serial,
)
from repro.core.partitioner import PartitionResult, partition_network
from repro.core.send_recv import build_comm_plans
from repro.data.graphchallenge import GraphChallengeNet
from repro.faas.collectives import reduce_to_root
from repro.faas.launch_tree import TreeSpec, launch_schedule
from repro.faas.object_service import ObjectFabric
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import ComputeModel, EventLedger, WorkerState

__all__ = ["LatencyModel", "FsiRunResult", "run_fsi", "charge_weight_load"]

Channel = Literal["queue", "object", "serial"]


@dataclasses.dataclass
class LatencyModel:
    """Service latency/throughput constants (defaults: public AWS figures)."""

    invoke_latency: float = 0.050
    cold_start: float = 0.250
    cold_start_jitter: float = 0.100
    sns_publish_latency: float = 0.012
    sns_fanout_latency: float = 0.020
    sqs_poll_rtt: float = 0.008
    sqs_long_poll_window: float = 2.0
    s3_put_latency: float = 0.030
    s3_get_first_byte: float = 0.018
    s3_list_latency: float = 0.025
    s3_bandwidth: float = 90e6
    weight_load_bandwidth: float = 250e6  # S3 model-shard read at startup
    straggler_prob: float = 0.0
    straggler_slowdown: float = 4.0


@dataclasses.dataclass
class FsiRunResult:
    output: np.ndarray                    # x^L assembled at worker 0 [N, batch]
    channel: Channel
    P: int
    worker_times: np.ndarray              # T_i (seconds, incl. launch offset)
    stats: WorkloadStats
    cost: CostBreakdown
    partition: Optional[PartitionResult]
    raw_exchange_bytes: int               # pre-compression volume (Table III)
    wire_exchange_bytes: int              # compressed bytes on the channel
    metrics: Dict[str, float]

    @property
    def mean_runtime(self) -> float:
        return float(self.worker_times.mean())

    @property
    def makespan(self) -> float:
        return float(self.worker_times.max())

    def per_sample_ms(self, batch: int) -> float:
        return self.makespan / batch * 1e3


def charge_weight_load(worker: WorkerState, artifact, latency: "LatencyModel") -> None:
    """Bill a worker's model-shard read from object storage at the startup
    read bandwidth.  One definition for every call site — FSI worker init,
    straggler re-invoke, and LM-pipeline stage cold start — so the cost
    expression can't drift.

    The shard size is the artifact's ``weight_bytes`` when it carries one (an
    LM pipeline stage loads only its own layer slice — it must never be
    billed the full-model read), else the FSI convention CSR nnz × 8B.

    On the overlapped ledger this is a fleet-wide stall: nothing can compute
    or communicate without the weights, so both timelines sync."""
    nbytes = getattr(artifact, "weight_bytes", None)
    if not nbytes:
        nbytes = artifact.weight_nnz * 8
    s = nbytes / latency.weight_load_bandwidth
    worker.charge_seconds(s)
    if worker.ledger is not None:
        worker.ledger.sync(s)


def run_fsi(
    net: GraphChallengeNet,
    x0: np.ndarray,
    P: int = 8,
    channel: Channel = "queue",
    partition_method: str = "hgp",
    memory_mb: Optional[int] = None,
    latency: Optional[LatencyModel] = None,
    compute: Optional[ComputeModel] = None,
    pricing: PricingConstants = AWS_PRICING,
    branching: int = 4,
    seed: int = 0,
    exploit_sparsity: bool = True,
    reinvoke_stragglers: bool = False,
    straggler_timeout: float = 3.0,
    partition: Optional[PartitionResult] = None,
    compute_backend: Union[str, ComputeBackend, None] = None,
    mesh: Optional[object] = None,
    channel_batching: bool = True,
    overlap: bool = True,
) -> FsiRunResult:
    """Run distributed FSI over a simulated serverless fleet.

    ``overlap`` selects which clock model the result reports.  Both models
    are always computed side by side: the strict-sum **phased** clock drives
    every fabric interaction (publishes, polls, LISTs — hence all billable
    counts), while the **event ledger** re-times the same events with
    per-worker compute/channel timelines merged only at dependency edges
    (layer k's drain overlaps layer k's publish lanes and local MVP).  With
    ``overlap=True`` (the default) worker times and billed durations come
    from the ledger; ``overlap=False`` reports the phased clock and serves
    as the differential oracle — charge counts are bit-identical between the
    two by construction.  Both makespans are always exposed in ``metrics``.
    """
    latency = latency or LatencyModel()
    compute = compute or ComputeModel()
    backend = get_backend(compute_backend)
    # Mesh threading for device-sharded fleet backends (pallas-bsr-sharded):
    # the mesh rides on the backend instance, so everything downstream —
    # prepare_worker_artifacts, fleet_prepare_all, fleet_apply — sees one
    # consistent worker-axis layout without new plumbing.
    if mesh is not None:
        if not hasattr(backend, "with_mesh"):
            raise ValueError(
                f"compute backend {backend.name!r} does not take a mesh; "
                f"use 'pallas-bsr-sharded'"
            )
        backend = backend.with_mesh(mesh)
    batch = x0.shape[1]

    # ---------------- Serial short-circuit ---------------------------------
    if channel == "serial" or P == 1:
        memory_mb = memory_mb or pricing.max_lambda_memory_mb
        out, w = run_serial(net, x0, memory_mb=memory_mb, compute=compute,
                            backend=backend)
        w.charge_seconds(net.model_bytes / latency.weight_load_bandwidth)
        times = np.array([w.clock + latency.cold_start])
        stats = WorkloadStats(P=1, mean_runtime_s=float(times.mean()), memory_mb=memory_mb)
        return FsiRunResult(
            output=out, channel="serial", P=1, worker_times=times, stats=stats,
            cost=serial_cost(stats, pricing), partition=None,
            raw_exchange_bytes=0, wire_exchange_bytes=0,
            metrics={"flops": w.flops},
        )

    # ---------------- offline partitioning + plans --------------------------
    if partition is None:
        partition = partition_network(net.layers, P, method=partition_method, seed=seed)
    plans = build_comm_plans(net.layers, partition)
    artifacts = prepare_worker_artifacts(net.layers, partition, plans,
                                         backend=backend)
    # Fleet batching: pallas-bsr stacks each layer's per-worker operands so
    # one device dispatch serves all P workers; pallas-bsr-sharded lays that
    # stack over a `worker` mesh axis (shard_map, blocked P/D per device);
    # numpy backends return None and finish per worker.
    fleet_states = backend.fleet_prepare_all(
        [[artifacts[m].layers[k].state_for(backend) for m in range(P)]
         for k in range(net.n_layers)]
    )

    memory_mb = memory_mb or _default_memory_mb(net.neurons)
    for a in artifacts:
        need = a.memory_bytes(batch)
        if need > memory_mb * 1024 * 1024:
            raise MemoryError(
                f"worker {a.rank} shard needs ~{need/1e6:.0f}MB > {memory_mb}MB; "
                f"increase P or memory"
            )

    # ---------------- launch tree -------------------------------------------
    ready = launch_schedule(
        P, branching=branching, invoke_latency=latency.invoke_latency,
        cold_start=latency.cold_start, cold_start_jitter=latency.cold_start_jitter,
        seed=seed,
    )
    rng = np.random.default_rng(seed + 99)
    workers: List[WorkerState] = []
    for m in range(P):
        w = WorkerState(rank=m, memory_mb=memory_mb, start_time=float(ready[m]),
                        ledger=EventLedger(t_compute=float(ready[m]),
                                           t_channel=float(ready[m])))
        if latency.straggler_prob > 0 and rng.random() < latency.straggler_prob:
            w.slowdown = latency.straggler_slowdown
        # weight shard load from object storage (paper: workers reload per request)
        charge_weight_load(w, artifacts[m], latency)
        workers.append(w)

    # ---------------- fabric -------------------------------------------------
    if channel == "queue":
        fabric = QueueFabric(
            P, pricing=pricing,
            publish_latency=latency.sns_publish_latency,
            fanout_latency=latency.sns_fanout_latency,
            poll_rtt=latency.sqs_poll_rtt,
            long_poll_window=latency.sqs_long_poll_window,
            seed=seed,
        )
    elif channel == "object":
        fabric = ObjectFabric(
            P,
            put_latency=latency.s3_put_latency,
            get_first_byte=latency.s3_get_first_byte,
            list_latency=latency.s3_list_latency,
            bandwidth=latency.s3_bandwidth,
        )
    else:
        raise ValueError(channel)

    # ---------------- layer loop --------------------------------------------
    x_panels: List[np.ndarray] = [
        x0[artifacts[m].x0_rows].astype(np.float32) for m in range(P)
    ]
    for k in range(net.n_layers):
        t_before = [w.clock for w in workers]
        arts_k = [artifacts[m].layers[k] for m in range(P)]
        # Phases 1+2 — publish + overlapped local MVP, then drain the channel.
        # ``channel_batching`` (the default) runs the fleet-batched host path:
        # one pack pass and one vectorized drain scatter per layer instead of
        # O(P) Python-level passes.  Billed charges are bit-identical either
        # way (the fleet variants share the publish/drain helpers — asserted
        # in tests/test_fleet_channels.py).
        bufs: List[np.ndarray]
        if channel_batching:
            if channel == "queue":
                fleet_bufs = fsi_queue_send_and_local_fleet(
                    arts_k, x_panels, workers, fabric, compute,
                    exploit_sparsity=exploit_sparsity,
                )
                bufs = fsi_queue_recv_fleet(arts_k, fleet_bufs, workers,
                                            fabric, compute)
            else:
                fleet_bufs = fsi_object_send_and_local_fleet(
                    arts_k, x_panels, workers, fabric, compute,
                    exploit_sparsity=exploit_sparsity,
                )
                bufs = fsi_object_recv_fleet(arts_k, fleet_bufs, workers,
                                             fabric, compute)
        else:
            bufs = []
            for m in range(P):
                art = arts_k[m]
                if channel == "queue":
                    bufs.append(fsi_queue_send_and_local(
                        art, x_panels[m], workers[m], fabric, compute,
                        exploit_sparsity=exploit_sparsity,
                    ))
                else:
                    bufs.append(fsi_object_send_and_local(
                        art, x_panels[m], workers[m], fabric, compute,
                        exploit_sparsity=exploit_sparsity,
                    ))
            for m in range(P):
                art = arts_k[m]
                if channel == "queue":
                    bufs[m] = fsi_queue_recv(art, bufs[m], workers[m], fabric, compute)
                else:
                    bufs[m] = fsi_object_recv(art, bufs[m], workers[m], fabric, compute)
        if fleet_states is not None:
            outs = backend.fleet_apply(fleet_states[k], bufs, net.bias)
        else:
            outs = [
                backend.apply(
                    artifacts[m].layers[k].state_for(backend), bufs[m], net.bias
                )
                for m in range(P)
            ]
        for m in range(P):
            x_panels[m] = charge_finish(
                artifacts[m].layers[k], bufs[m], outs[m], workers[m], compute
            )
        # Straggler slowdown applies to *active* work (compute, pack/unpack)
        # via WorkerState.slowdown at the charge sites — never to channel
        # waits, which would compound across the fleet.
        if reinvoke_stragglers:
            layer_cost = np.array([w.clock - t0 for w, t0 in zip(workers, t_before)])
            med = float(np.median(layer_cost))
            for m, w in enumerate(workers):
                if med > 0 and layer_cost[m] > straggler_timeout * med and w.slowdown > 1:
                    # re-invoke: fresh container (cold start + weight reload),
                    # then it runs at full speed — the paper's cited
                    # pre-emptive retry mitigation
                    w.slowdown = 1.0
                    w.charge_seconds(latency.cold_start)
                    if w.ledger is not None:
                        w.ledger.sync(latency.cold_start)
                    charge_weight_load(w, artifacts[m], latency)

    # ---------------- fused sync + reduce (Algorithm lines 19-20) ------------
    # FMI-style collective fusion: the output reduce's up-sweep payload
    # doubles as the barrier token (``sync=True``), so the separate barrier
    # up/down sweeps — two full tree traversals of token messages — vanish
    # from both clock models and from the bill.
    tree = TreeSpec(n_workers=P, branching=branching)
    panels = [x_panels[m] for m in range(P)]
    gathered = reduce_to_root(workers, fabric, tree, panels, op="concat_rows",
                              sync=True)
    order = np.argsort(np.concatenate([artifacts[m].layers[-1].out_rows for m in range(P)]))
    output = gathered[order]

    # ---------------- billing -------------------------------------------------
    phased_times = np.array([w.abs_time for w in workers])
    ledger_times = np.array([w.overlap_time for w in workers])
    times = ledger_times if overlap else phased_times
    starts = np.array([w.start_time for w in workers])
    stats = WorkloadStats(
        P=P, mean_runtime_s=float((times - starts).mean()),
        memory_mb=memory_mb,
    )
    if channel == "queue":
        qm = fabric.metrics
        stats.publish_units = qm.publish_billed_units
        stats.bytes_sns_to_sqs = qm.bytes_sns_to_sqs
        stats.sqs_api_calls = qm.sqs_api_calls
        cost = queue_cost(stats, pricing)
        raw, wire = qm.raw_bytes, qm.bytes_sns_to_sqs
        extra = {
            "publish_api_calls": qm.publish_api_calls,
            "messages": qm.messages_delivered,
            "empty_polls": qm.empty_polls,
        }
    else:
        om = fabric.metrics
        stats.s3_puts = om.puts
        stats.s3_gets = om.gets
        stats.s3_lists = om.lists
        cost = object_cost(stats, pricing)
        raw, wire = om.raw_bytes, om.bytes_written
        extra = {"nul_files": om.nul_files}

    metrics = {
        "flops_total": float(sum(w.flops for w in workers)),
        "imbalance": partition.imbalance(net.layers),
        # both clock models are always computed; the flag only selects which
        # one ``worker_times``/``stats`` report
        "phased_makespan_s": float(phased_times.max()),
        "overlap_makespan_s": float(ledger_times.max()),
        **{k: float(v) for k, v in extra.items()},
    }
    return FsiRunResult(
        output=output, channel=channel, P=P, worker_times=times, stats=stats,
        cost=cost, partition=partition,
        raw_exchange_bytes=int(raw), wire_exchange_bytes=int(wire),
        metrics=metrics,
    )


def _default_memory_mb(neurons: int) -> int:
    """Paper §VI-A1 worker sizing: 1000/1500/2000/4000MB for N=1k..64k."""
    return {1024: 1000, 4096: 1500, 16384: 2000, 65536: 4000}.get(neurons, 2000)
