"""MPI-style collectives over serverless channels (paper §II-B objective 6).

The root worker coordinates Barrier / Reduce / Broadcast / AllReduce through
the same pub-sub or object fabric used for point-to-point exchange, routed
along the launch tree (partial aggregation at internal nodes keeps the root's
queue shallow).  Timing is computed analytically over the tree — equivalent
to simulating the token messages one by one — while API calls and bytes are
billed on the fabric's meters.

Billing comes in two flavours:

* ``aggregate=True`` (default) — FMI-style message aggregation: all of a
  node's per-peer small messages in one sweep step are packed into the
  fewest publish batches the SNS caps allow (≤10 messages / ≤256KB), and a
  receiving node drains its whole step with batched polls + one batched
  delete (object fabric: one LIST per node instead of one per edge).  Per
  sweep step a node issues O(1) API calls instead of O(degree);
* ``aggregate=False`` — the per-edge reference (one publish/PUT + one
  poll/LIST per tree edge), kept so fabric-metrics tests can pin the
  reduction.

``reduce_to_root(..., sync=True)`` additionally fuses the final barrier into
the reduce: the up-sweep payload doubles as the sync token, so no separate
barrier sweeps run — this is what ``run_fsi`` uses for the output gather.
"""

from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.faas.launch_tree import TreeSpec
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import WorkerState

__all__ = ["barrier", "reduce_to_root", "broadcast", "all_reduce"]

_TOKEN_BYTES = 64


def _edge_cost(fabric, eager: bool = False) -> float:
    """One-hop message time over the fabric.

    ``eager=True`` gives the ledger-side hop under eager polling: the
    receiver's long-poll / LIST is already parked when the send starts, so
    only the one-way publish half-trip and the push half of the poll RTT
    serialize (queue), or the one-way PUT half-trip before the in-flight
    LIST can observe the object (object).  Phased timing and billing always
    use the blocked-reader cost."""
    if isinstance(fabric, QueueFabric):
        if eager:
            return (fabric.publish_latency / 2 + fabric.fanout_latency
                    + fabric.poll_rtt / 2)
        return fabric.publish_latency + fabric.fanout_latency + fabric.poll_rtt
    if eager:
        return (fabric.put_latency / 2 + fabric.list_latency
                + fabric.get_first_byte)
    return fabric.put_latency + fabric.list_latency + fabric.get_first_byte


def _ledger_edge_cost(fabric, workers: Sequence[WorkerState]) -> float:
    """Edge cost on the ledger timelines: eager iff every ledger-carrying
    worker polls eagerly (the fleet shares one polling policy)."""
    eager = any(w.ledger is not None for w in workers) and all(
        w.ledger.eager_poll for w in workers if w.ledger is not None)
    return _edge_cost(fabric, eager=eager)


def _chunks(data: bytes, cap: int) -> List[Chunk]:
    return [Chunk(data[lo: lo + cap], raw_bytes=len(data[lo: lo + cap]))
            for lo in range(0, len(data), cap)]


def _bill_edge(fabric, layer: int, src: int, dst: int, payload: bytes | None):
    """Per-edge reference billing (``aggregate=False``): one publish per
    chunk per edge, one poll/LIST + delete per edge."""
    data = payload or b"\0" * _TOKEN_BYTES
    if isinstance(fabric, QueueFabric):
        cap = fabric.pricing.max_publish_payload
        for lo in range(0, len(data), cap):
            blob = Chunk(data[lo: lo + cap], raw_bytes=len(data[lo: lo + cap]))
            fabric.publish_batch(src % fabric.n_topics, [(dst, blob)], 0.0)
        n_msgs = -(-len(data) // cap)
        fabric.poll(dst, 1e9, long_poll=True)  # drain for billing
        fabric.delete_batch(dst, list(range(n_msgs)), 0.0)
    else:
        blob = Chunk(data, raw_bytes=len(data))
        fabric.put_obj(layer, src, dst, blob, 0.0)
        now, handles = fabric.list_files(layer, dst, 1e9)
        for h in handles:
            if not h.is_nul:
                fabric.get_obj(layer, dst, h.key, now)
        fabric._store.pop(fabric._prefix(layer, dst), None)


def _bill_sends(fabric, layer: int,
                edges: Sequence[Tuple[int, int, bytes | None]]) -> None:
    """Aggregated sender-side billing for a sweep step: every ``(src, dst,
    payload)`` edge's chunks are packed into the fewest publish batches the
    SNS caps allow, per source (object fabric: one PUT per edge — objects
    are keyed per target, but readers still aggregate on the drain side)."""
    if isinstance(fabric, QueueFabric):
        cap = fabric.pricing.max_publish_payload
        per_msg = fabric.pricing.max_messages_per_publish
        by_src: Dict[int, List[Tuple[int, Chunk]]] = {}
        for src, dst, payload in edges:
            data = payload or b"\0" * _TOKEN_BYTES
            for c in _chunks(data, cap):
                by_src.setdefault(src, []).append((dst, c))
        for src, entries in by_src.items():
            cur: List[Tuple[int, Chunk]] = []
            cur_bytes = 0
            for dst, c in entries:
                if cur and (len(cur) >= per_msg or cur_bytes + len(c) > cap):
                    fabric.publish_batch(src % fabric.n_topics, cur, 0.0)
                    cur, cur_bytes = [], 0
                cur.append((dst, c))
                cur_bytes += len(c)
            if cur:
                fabric.publish_batch(src % fabric.n_topics, cur, 0.0)
    else:
        for src, dst, payload in edges:
            data = payload or b"\0" * _TOKEN_BYTES
            fabric.put_obj(layer, src, dst, Chunk(data, raw_bytes=len(data)), 0.0)


def _bill_drain(fabric, layer: int, dst: int) -> None:
    """Aggregated receiver-side billing: drain everything pending for ``dst``
    with ≤10-message polls and ONE batched delete (queue), or one LIST + the
    GETs (object) — O(1)-ish API calls per node per sweep step."""
    if isinstance(fabric, QueueFabric):
        receipts: List[int] = []
        while fabric.pending(dst):
            _, deliveries = fabric.poll(dst, 1e9, long_poll=True)
            receipts.extend(d.receipt for d in deliveries)
        if receipts:
            fabric.delete_batch(dst, receipts, 0.0)
    else:
        now, handles = fabric.list_files(layer, dst, 1e9)
        for h in handles:
            if not h.is_nul:
                fabric.get_obj(layer, dst, h.key, now)
        fabric._store.pop(fabric._prefix(layer, dst), None)


def barrier(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec,
    layer_tag: int = 1 << 20, *, aggregate: bool = True,
) -> float:
    """Tree up-sweep + down-sweep; on return every worker clock is aligned."""
    P = len(workers)
    edge = _edge_cost(fabric)
    edge_led = _ledger_edge_cost(fabric, workers)
    # up-sweep: completion time at each node (phased and ledger timelines)
    up = [0.0] * P
    up_led = [0.0] * P
    for m in reversed(range(P)):
        t = workers[m].abs_time
        tl = workers[m].overlap_time
        kids = tree.children(m)
        for c in kids:
            t = max(t, up[c] + edge)
            tl = max(tl, up_led[c] + edge_led)
        if kids:
            if aggregate:
                _bill_sends(fabric, layer_tag, [(c, m, None) for c in kids])
                _bill_drain(fabric, layer_tag, m)
            else:
                for c in kids:
                    _bill_edge(fabric, layer_tag, c, m, None)
        up[m] = t
        up_led[m] = tl
    # down-sweep: release times
    release = [0.0] * P
    release_led = [0.0] * P
    release[0] = up[0]
    release_led[0] = up_led[0]
    for m in range(P):
        kids = tree.children(m)
        if kids:
            if aggregate:
                _bill_sends(fabric, layer_tag, [(m, c, None) for c in kids])
                for c in kids:
                    _bill_drain(fabric, layer_tag, c)
            else:
                for c in kids:
                    _bill_edge(fabric, layer_tag, m, c, None)
        for c in kids:
            release[c] = release[m] + edge
            release_led[c] = release_led[m] + edge_led
    for m, w in enumerate(workers):
        w.advance_to_abs(release[m])
        if w.ledger is not None:
            w.ledger.sync_to(release_led[m])
    return max(release)


def reduce_to_root(
    workers: Sequence[WorkerState],
    fabric,
    tree: TreeSpec,
    payloads: List[np.ndarray],
    op: str = "concat_rows",
    layer_tag: int = 1 << 21,
    *,
    aggregate: bool = True,
    sync: bool = False,
) -> np.ndarray:
    """Reduce(P_0, ·): partial aggregation at internal nodes (paper line 20/25).

    ``op='concat_rows'`` stacks row panels **in worker-rank order** (the FSI
    output gather — callers unpermute against rank-ordered row ids, so the
    root re-sorts the panels it aggregated in tree-traversal order; with
    branching b, ranks ≥ b+2 otherwise arrive interleaved under their parent
    subtree and the gather would be silently misassembled);
    ``op='sum'`` adds equal-shaped arrays (classic MPI_Reduce).

    With ``sync=True`` the reduce doubles as the final barrier (FMI-style
    collective fusion): the up-sweep payload IS the sync token, every worker
    is advanced to the time its aggregated subtree panel is handed to its
    parent, and no separate barrier sweeps run.
    """
    P = len(workers)
    edge = _edge_cost(fabric)
    edge_led = _ledger_edge_cost(fabric, workers)
    bw = _bandwidth(fabric)
    # accumulate (rank, panel) pairs so the root can restore rank order no
    # matter how the tree interleaved the subtrees
    acc: List[List[tuple]] = [[(m, payloads[m])] for m in range(P)]
    done = [0.0] * P
    done_led = [0.0] * P
    for m in reversed(range(P)):
        t = workers[m].abs_time
        tl = workers[m].overlap_time
        step_edges: List[Tuple[int, int, bytes | None]] = []
        for c in tree.children(m):
            blob = b"".join(np.ascontiguousarray(a).tobytes()
                            for _, a in acc[c])
            t = max(t, done[c] + edge + len(blob) / bw)
            tl = max(tl, done_led[c] + edge_led + len(blob) / bw)
            step_edges.append((c, m, blob))
            acc[m].extend(acc[c])
        if step_edges:
            if aggregate:
                _bill_sends(fabric, layer_tag, step_edges)
                _bill_drain(fabric, layer_tag, m)
            else:
                for c, _, blob in step_edges:
                    _bill_edge(fabric, layer_tag, c, m, blob)
        done[m] = t
        done_led[m] = tl
    if sync:
        # a non-root worker finishes once its panel is handed up the tree
        for m, w in enumerate(workers):
            hop = edge if m != 0 else 0.0
            hop_led = edge_led if m != 0 else 0.0
            w.advance_to_abs(done[m] + hop)
            if w.ledger is not None:
                w.ledger.sync_to(done_led[m] + hop_led)
    else:
        workers[0].advance_to_abs(done[0])
        if workers[0].ledger is not None:
            workers[0].ledger.sync_to(done_led[0])
    if op == "sum":
        out = acc[0][0][1].copy()
        for _, a in acc[0][1:]:
            out = out + a
        return out
    return np.concatenate(
        [a for _, a in sorted(acc[0], key=lambda pair: pair[0])], axis=0
    )


def broadcast(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec, payload: np.ndarray,
    layer_tag: int = 1 << 22, *, aggregate: bool = True,
) -> None:
    P = len(workers)
    edge = _edge_cost(fabric)
    edge_led = _ledger_edge_cost(fabric, workers)
    blob = np.ascontiguousarray(payload).tobytes()
    t = [0.0] * P
    t_led = [0.0] * P
    t[0] = workers[0].abs_time
    t_led[0] = workers[0].overlap_time
    for m in range(P):
        kids = tree.children(m)
        if kids:
            if aggregate:
                _bill_sends(fabric, layer_tag, [(m, c, blob) for c in kids])
                for c in kids:
                    _bill_drain(fabric, layer_tag, c)
            else:
                for c in kids:
                    _bill_edge(fabric, layer_tag, m, c, blob)
        for c in kids:
            t[c] = t[m] + edge + len(blob) / _bandwidth(fabric)
            t_led[c] = t_led[m] + edge_led + len(blob) / _bandwidth(fabric)
    for m, w in enumerate(workers):
        w.advance_to_abs(t[m])
        if w.ledger is not None:
            w.ledger.sync_to(t_led[m])


def all_reduce(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec, payloads: List[np.ndarray]
) -> np.ndarray:
    out = reduce_to_root(workers, fabric, tree, payloads, op="sum")
    broadcast(workers, fabric, tree, out)
    return out


def _bandwidth(fabric) -> float:
    if isinstance(fabric, ObjectFabric):
        return fabric.bandwidth
    return 60e6  # effective SNS/SQS per-connection throughput
