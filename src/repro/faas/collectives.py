"""MPI-style collectives over serverless channels (paper §II-B objective 6).

The root worker coordinates Barrier / Reduce / Broadcast / AllReduce through
the same pub-sub or object fabric used for point-to-point exchange, routed
along the launch tree (partial aggregation at internal nodes keeps the root's
queue shallow).  Timing is computed analytically over the tree — equivalent
to simulating the token messages one by one — while API calls and bytes are
billed on the fabric's meters.
"""

from __future__ import annotations

from typing import List, Sequence

import numpy as np

from repro.faas.launch_tree import TreeSpec
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import WorkerState

__all__ = ["barrier", "reduce_to_root", "broadcast", "all_reduce"]

_TOKEN_BYTES = 64


def _edge_cost(fabric) -> float:
    """One-hop message time over the fabric."""
    if isinstance(fabric, QueueFabric):
        return fabric.publish_latency + fabric.fanout_latency + fabric.poll_rtt
    return fabric.put_latency + fabric.list_latency + fabric.get_first_byte


def _bill_edge(fabric, layer: int, src: int, dst: int, payload: bytes | None):
    data = payload or b"\0" * _TOKEN_BYTES
    if isinstance(fabric, QueueFabric):
        cap = fabric.pricing.max_publish_payload
        for lo in range(0, len(data), cap):
            blob = Chunk(data[lo : lo + cap], raw_bytes=len(data[lo : lo + cap]))
            fabric.publish_batch(src % fabric.n_topics, [(dst, blob)], 0.0)
        n_msgs = -(-len(data) // cap)
        fabric.poll(dst, 1e9, long_poll=True)  # drain for billing
        fabric.delete_batch(dst, list(range(n_msgs)), 0.0)
    else:
        blob = Chunk(data, raw_bytes=len(data))
        fabric.put_obj(layer, src, dst, blob, 0.0)
        now, handles = fabric.list_files(layer, dst, 1e9)
        for h in handles:
            if not h.is_nul:
                fabric.get_obj(layer, dst, h.key, now)
        fabric._store.pop(fabric._prefix(layer, dst), None)


def barrier(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec, layer_tag: int = 1 << 20
) -> float:
    """Tree up-sweep + down-sweep; on return every worker clock is aligned."""
    P = len(workers)
    edge = _edge_cost(fabric)
    # up-sweep: completion time at each node
    up = [0.0] * P
    for m in reversed(range(P)):
        t = workers[m].abs_time
        for c in tree.children(m):
            t = max(t, up[c] + edge)
            _bill_edge(fabric, layer_tag, c, m, None)
        up[m] = t
    # down-sweep: release times
    release = [0.0] * P
    release[0] = up[0]
    for m in range(P):
        for c in tree.children(m):
            _bill_edge(fabric, layer_tag, m, c, None)
            release[c] = release[m] + edge
    for m, w in enumerate(workers):
        w.advance_to_abs(release[m])
    return max(release)


def reduce_to_root(
    workers: Sequence[WorkerState],
    fabric,
    tree: TreeSpec,
    payloads: List[np.ndarray],
    op: str = "concat_rows",
    layer_tag: int = 1 << 21,
) -> np.ndarray:
    """Reduce(P_0, ·): partial aggregation at internal nodes (paper line 20/25).

    ``op='concat_rows'`` stacks row panels **in worker-rank order** (the FSI
    output gather — callers unpermute against rank-ordered row ids, so the
    root re-sorts the panels it aggregated in tree-traversal order; with
    branching b, ranks ≥ b+2 otherwise arrive interleaved under their parent
    subtree and the gather would be silently misassembled);
    ``op='sum'`` adds equal-shaped arrays (classic MPI_Reduce).
    """
    P = len(workers)
    edge = _edge_cost(fabric)
    # accumulate (rank, panel) pairs so the root can restore rank order no
    # matter how the tree interleaved the subtrees
    acc: List[List[tuple]] = [[(m, payloads[m])] for m in range(P)]
    done = [0.0] * P
    for m in reversed(range(P)):
        t = workers[m].abs_time
        for c in tree.children(m):
            blob = b"".join(np.ascontiguousarray(a).tobytes()
                            for _, a in acc[c])
            t = max(t, done[c] + edge + len(blob) / _bandwidth(fabric))
            _bill_edge(fabric, layer_tag, c, m, blob)
            acc[m].extend(acc[c])
        done[m] = t
    workers[0].advance_to_abs(done[0])
    if op == "sum":
        out = acc[0][0][1].copy()
        for _, a in acc[0][1:]:
            out = out + a
        return out
    return np.concatenate(
        [a for _, a in sorted(acc[0], key=lambda pair: pair[0])], axis=0
    )


def broadcast(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec, payload: np.ndarray,
    layer_tag: int = 1 << 22,
) -> None:
    P = len(workers)
    edge = _edge_cost(fabric)
    blob = np.ascontiguousarray(payload).tobytes()
    t = [0.0] * P
    t[0] = workers[0].abs_time
    for m in range(P):
        for c in tree.children(m):
            _bill_edge(fabric, layer_tag, m, c, blob)
            t[c] = t[m] + edge + len(blob) / _bandwidth(fabric)
    for m, w in enumerate(workers):
        w.advance_to_abs(t[m])


def all_reduce(
    workers: Sequence[WorkerState], fabric, tree: TreeSpec, payloads: List[np.ndarray]
) -> np.ndarray:
    out = reduce_to_root(workers, fabric, tree, payloads, op="sum")
    broadcast(workers, fabric, tree, out)
    return out


def _bandwidth(fabric) -> float:
    if isinstance(fabric, ObjectFabric):
        return fabric.bandwidth
    return 60e6  # effective SNS/SQS per-connection throughput
