"""Seeded chaos injection + crash-fault recovery primitives (ISSUE 10).

FSD-Inference's correctness story leans on the FaaS platform's
fault-tolerance primitives: SQS at-least-once delivery with
visibility-timeout redelivery, durable object storage as recovery state,
and function re-invocation on failure.  This module is the *injection*
side: a frozen, seeded :class:`FaultPlan` describes which workers die at
which (layer, phase), how often publishes are delayed inside the provider,
how often API calls are throttled (429), and the per-function runtime
limit.  The *recovery* side lives in the executors
(``run_fsi`` / ``run_lm_pipeline``), which re-invoke crashed workers,
restore their panels from durable checkpoints, and replay the layer
handler — every extra invocation, redelivery, GET, and GB-second landing
on auditable ``CostBreakdown`` lines.

Determinism: every random draw flows from ``FaultPlan.seed`` through
named, stream-separated RNGs (the ``SimulatorConfig.rng`` convention),
and crash draws are *event-keyed* — seeded by ``(worker, layer, phase)``
rather than drawn in call order — so a recovery replay can never shift
which faults fire.  Each fault event fires at most once: a re-invoked
worker does not re-crash at the site it just recovered from (the chaos
driver is modeled as injecting each fault a single time).
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, Optional, Tuple

import numpy as np

__all__ = ["FaultPlan", "FleetFailure", "ChaosState", "CRASH_PHASES"]

#: Phases of one layer handler a worker can be killed in.
#: ``send``    — before the worker publishes its layer-k chunks;
#: ``compute`` — after publishing, before draining (local MVP in flight);
#: ``drain``   — after the drain completed but before the receipt deletes
#:               commit, so the drained messages redeliver after the
#:               visibility timeout.
CRASH_PHASES = ("send", "compute", "drain")


class FleetFailure(RuntimeError):
    """Raised when a fault is not recoverable within the plan's budget.

    Carries structured per-worker diagnostics so callers (and the chaos
    test-suite's exactness assertions) can see *why* the fleet died:
    ``diagnostics[worker] = {"layer", "phase", "reinvokes", "reason"}``.
    """

    def __init__(self, message: str, diagnostics: Dict[int, dict]):
        super().__init__(message)
        self.diagnostics = diagnostics


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, immutable chaos schedule for one run.

    ``kills`` lists explicit ``(worker, layer, phase)`` crash sites (phase
    from :data:`CRASH_PHASES`); ``crash_prob`` additionally arms every
    (worker, layer, phase) site with an independent event-keyed draw.
    ``publish_delay_prob`` models lost publishes as provider-internal
    retries (the message is delivered ``publish_delay_s`` late — lost
    forever is not a thing SNS→SQS promises, so neither do we).
    ``throttle_prob`` injects 429s on fabric API calls, retried with
    capped exponential backoff + full jitter.  ``runtime_limit_s`` kills
    any worker whose billed runtime since (re-)invocation exceeds the
    limit, at the next layer boundary.  ``max_reinvokes`` is the
    per-worker re-invocation budget; exceeding it raises
    :class:`FleetFailure`.  ``checkpoint_every`` is the panel-checkpoint
    cadence C (a checkpoint PUT of each worker's input panel every C
    layers) — crashes above the last checkpoint replay forward from it,
    which needs the intermediate layers' inputs to still be readable
    (durable object channel); see docs/ARCHITECTURE.md for the trade-off.
    """

    seed: int = 0
    kills: Tuple[Tuple[int, int, str], ...] = ()
    crash_prob: float = 0.0
    publish_delay_prob: float = 0.0
    publish_delay_s: float = 0.25
    throttle_prob: float = 0.0
    throttle_max_retries: int = 16
    runtime_limit_s: Optional[float] = None
    max_reinvokes: int = 3
    backoff_base_s: float = 0.05
    backoff_cap_s: float = 2.0
    checkpoint_every: int = 1

    def __post_init__(self):
        for worker, layer, phase in self.kills:
            if phase not in CRASH_PHASES:
                raise ValueError(f"unknown crash phase {phase!r}")
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")

    def activate(self) -> "ChaosState":
        return ChaosState(self)


class ChaosState:
    """Mutable per-run state of an activated :class:`FaultPlan`.

    One instance is shared by every fabric of a run (``fabric.chaos``) and
    by the executor's crash checks, so the stream-separated RNGs stay
    coherent across the queue/object/checkpoint fabrics.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._kills = frozenset(plan.kills)
        self._fired: set = set()
        self._rngs: Dict[str, np.random.Generator] = {}
        self.reinvokes: Dict[int, int] = {}
        self.diagnostics: Dict[int, dict] = {}

    # -- stream-separated RNGs (the SimulatorConfig.rng convention) ---------

    def rng(self, stream: str) -> np.random.Generator:
        r = self._rngs.get(stream)
        if r is None:
            r = np.random.default_rng(
                [self.plan.seed, zlib.crc32(stream.encode("utf-8"))]
            )
            self._rngs[stream] = r
        return r

    # -- crash schedule ------------------------------------------------------

    def _armed(self, worker: int, layer: int, phase: str) -> bool:
        key = (worker, layer, phase)
        if key in self._fired:
            return False
        if key in self._kills:
            return True
        if self.plan.crash_prob > 0.0:
            r = np.random.default_rng(
                [self.plan.seed, zlib.crc32(b"crash"), worker, layer,
                 CRASH_PHASES.index(phase)]
            )
            return bool(r.random() < self.plan.crash_prob)
        return False

    def peek_crash(self, worker: int, layer: int, phase: str) -> bool:
        """Whether the site is armed, without consuming it.  The executor
        peeks the ``drain`` site before draining so a doomed drain defers
        its receipt deletes (they must stay in flight to redeliver) while a
        healthy drain keeps the production per-iteration delete schedule —
        a zero-fault plan's billed counts stay bit-identical to no plan."""
        return self._armed(worker, layer, phase)

    def should_crash(self, worker: int, layer: int, phase: str) -> bool:
        """True exactly once per armed (worker, layer, phase) site.

        The probabilistic arm is event-keyed (seeded by the site, not drawn
        in call order) so recovery replays cannot shift later draws.
        """
        hit = self._armed(worker, layer, phase)
        if hit:
            self._fired.add((worker, layer, phase))
        return hit

    def record_reinvoke(self, worker: int, layer: int, phase: str,
                        reason: str) -> None:
        """Count one re-invocation against ``worker``'s budget; raise
        :class:`FleetFailure` when the budget is exhausted."""
        n = self.reinvokes.get(worker, 0) + 1
        self.reinvokes[worker] = n
        self.diagnostics[worker] = {
            "layer": layer, "phase": phase, "reinvokes": n, "reason": reason,
        }
        if n > self.plan.max_reinvokes:
            raise FleetFailure(
                f"worker {worker} exhausted its re-invoke budget "
                f"({n} > {self.plan.max_reinvokes}) at layer {layer} "
                f"({phase}): {reason}",
                dict(self.diagnostics),
            )

    def unrecoverable(self, worker: int, layer: int, reason: str
                      ) -> FleetFailure:
        """Build the structured failure for a crash no replay can fix."""
        self.diagnostics[worker] = {
            "layer": layer, "phase": "recover",
            "reinvokes": self.reinvokes.get(worker, 0), "reason": reason,
        }
        return FleetFailure(
            f"worker {worker} unrecoverable at layer {layer}: {reason}",
            dict(self.diagnostics),
        )

    # -- fabric-side injections ---------------------------------------------

    def throttle(self, stream: str, at_time: float) -> Tuple[float, int]:
        """Model 429s on one API call: each throttled attempt is retried
        after capped exponential backoff with *full jitter* (sleep drawn
        uniformly from [0, min(cap, base·2^attempt)]).  Returns the delayed
        start time and the number of retries taken."""
        p = self.plan.throttle_prob
        if p <= 0.0:
            return at_time, 0
        rng = self.rng("throttle:" + stream)
        n = 0
        while rng.random() < p:
            n += 1
            if n > self.plan.throttle_max_retries:
                raise FleetFailure(
                    f"{stream}: throttled {n} consecutive times — retry "
                    f"budget exhausted",
                    {-1: {"layer": -1, "phase": stream, "reinvokes": 0,
                          "reason": "throttle retry budget exhausted"}},
                )
            cap = min(self.plan.backoff_cap_s,
                      self.plan.backoff_base_s * (2.0 ** (n - 1)))
            at_time += float(rng.random()) * cap
        return at_time, n

    def publish_delay(self) -> float:
        """Extra provider-side delivery delay for one publish call (a
        dropped publish surfacing as an SNS-internal retry)."""
        p = self.plan.publish_delay_prob
        if p <= 0.0:
            return 0.0
        rng = self.rng("publish_delay")
        if rng.random() < p:
            return self.plan.publish_delay_s
        return 0.0
