"""Simulated SNS (pub-sub) + SQS (queues) fabric — FSD-Inf-Queue (§III-A).

Topology per the paper (Fig. 2):

* ``n_topics`` parallel SNS topics (``topic-{m%10}``) to spread publish load
  and avoid single-resource I/O bottlenecks;
* one *dedicated* SQS queue per worker, subscribed to every topic with a
  service-side **filter policy** on the ``target`` message attribute — the
  fan-out and filtering run in the provider's backend, not on the
  resource-constrained workers;
* publishes are batched (≤10 messages, ≤256KB total) and billed in 64KB
  increments; SQS is billed per API call (receive / delete batches);
* 'long' polling (W>0) visits all queue servers and waits up to W seconds,
  returning as soon as messages exist — 'short' polling (W=0) samples a
  subset of servers and may miss messages (modeled as a per-message visibility
  probability), which is why the paper finds long polling strictly better.

Latency accounting lives with the fabric so both FSI algorithms and the
MPI-style collectives bill through one place.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import PricingConstants, AWS_PRICING
from repro.faas.payload import Chunk

__all__ = ["QueueFabric", "QueueMetrics", "Delivery"]


@dataclasses.dataclass
class Delivery:
    deliver_at: float        # service-side availability time (seconds)
    target: int
    blob: Chunk
    attributes: Dict[str, int]
    receipt: int = -1
    # Availability under the overlapped-pipeline ledger (sender's channel
    # timeline + fan-out).  None when the sender carried no ledger; drains
    # then fall back to ``deliver_at``.
    ledger_at: Optional[float] = None
    # Availability under an *eager* long-poll: the consumer's ReceiveMessage
    # is already parked on the queue before the sender publishes, so the
    # message reaches the reader after the one-way publish half-trip, the
    # fan-out, and the push half of the poll RTT — the request half was
    # spent while the sender was still packing.  Ledger-only; billing and
    # the phased ``deliver_at`` schedule never read this.
    ledger_eager_at: Optional[float] = None


@dataclasses.dataclass
class QueueMetrics:
    publish_api_calls: int = 0
    publish_billed_units: int = 0       # S in Eq. 5
    bytes_sns_to_sqs: int = 0           # Z in Eq. 5
    sqs_api_calls: int = 0              # Q in Eq. 6
    messages_delivered: int = 0
    empty_polls: int = 0
    raw_bytes: int = 0                  # pre-compression volume (Table III)
    redeliveries: int = 0               # visibility-timeout expiries requeued
    throttle_retries: int = 0           # chaos-injected 429 retries


class QueueFabric:
    """The SNS topics + per-worker SQS queues, with billing counters."""

    def __init__(
        self,
        n_workers: int,
        n_topics: int = 10,
        pricing: PricingConstants = AWS_PRICING,
        publish_latency: float = 0.012,
        fanout_latency: float = 0.020,
        poll_rtt: float = 0.008,
        long_poll_window: float = 2.0,
        short_poll_miss_prob: float = 0.35,
        seed: int = 0,
        visibility_timeout: float = 30.0,
    ):
        self.n_workers = n_workers
        self.n_topics = max(1, min(n_topics, n_workers))
        self.pricing = pricing
        self.publish_latency = publish_latency
        self.fanout_latency = fanout_latency
        self.poll_rtt = poll_rtt
        self.long_poll_window = long_poll_window
        self.short_poll_miss_prob = short_poll_miss_prob
        self.visibility_timeout = visibility_timeout
        self.metrics = QueueMetrics()
        self._queues: List[List[Delivery]] = [[] for _ in range(n_workers)]
        # At-least-once delivery: polled messages move here keyed by receipt
        # until DeleteMessageBatch retires them; past ``visible_again_at`` an
        # undeleted message is requeued (with a fresh receipt) and re-billed
        # on the next poll that reaches it.
        self._inflight: List[Dict[int, Tuple[float, "_OrderedDelivery"]]] = [
            {} for _ in range(n_workers)
        ]
        self._rng = np.random.default_rng(seed)
        self._receipt = 0
        # Optional chaos hook (repro.faas.chaos.ChaosState); when set, publish
        # and poll consult it for 429 throttles and SNS-internal redelivery
        # delays.  None in production runs — zero overhead, zero billing drift.
        self.chaos = None

    # -- producer side ------------------------------------------------------

    def publish_batch(
        self, topic: int, entries: List[Tuple[int, Chunk]], at_time: float,
        *, ledger_at: Optional[float] = None,
    ) -> float:
        """Publish ≤10 (target, blob) entries; returns completion time.

        Billing: one publish request per 64KB increment of the total payload
        (a 256KB batch = 4 billed units).  Data transfer SNS→SQS is billed
        per byte (Z).

        ``ledger_at`` is the send start on the overlapped-pipeline timeline;
        it only stamps each delivery's ``ledger_at`` availability and never
        affects billing or the phased delivery schedule.
        """
        if not (1 <= len(entries) <= self.pricing.max_messages_per_publish):
            raise ValueError("publish batch must contain 1..10 messages")
        payload = sum(len(b) for _, b in entries)
        if payload > self.pricing.max_publish_payload:
            raise ValueError(
                f"publish payload {payload}B exceeds "
                f"{self.pricing.max_publish_payload}B cap"
            )
        extra_fanout = 0.0
        if self.chaos is not None:
            at_time, n_retries = self.chaos.throttle("sns_publish", at_time)
            self.metrics.throttle_retries += n_retries
            extra_fanout = self.chaos.publish_delay()
        self.metrics.publish_api_calls += 1
        self.metrics.publish_billed_units += max(
            1, -(-payload // self.pricing.publish_billing_unit)
        )
        self.metrics.bytes_sns_to_sqs += payload
        self.metrics.raw_bytes += sum(b.raw_bytes for _, b in entries)
        done = at_time + self.publish_latency
        led_avail = (None if ledger_at is None
                     else ledger_at + self.publish_latency + self.fanout_latency
                     + extra_fanout)
        # Eager long-poll availability: the reader's poll is already open, so
        # only the one-way publish half-trip (the ack half overlaps fan-out),
        # the fan-out, and the push half of the poll RTT precede delivery.
        # The sender's lane still occupies the full publish_latency.
        led_eager = (None if ledger_at is None
                     else ledger_at + self.publish_latency / 2
                     + self.fanout_latency + extra_fanout + self.poll_rtt / 2)
        for target, blob in entries:
            if not (0 <= target < self.n_workers):
                raise ValueError(f"bad filter target {target}")
            heapq.heappush(
                self._queues[target],
                # heap keyed by delivery time; receipt id breaks ties
                _OrderedDelivery(
                    done + self.fanout_latency + extra_fanout,
                    self._next_receipt(), target,
                    blob, ledger_at=led_avail, ledger_eager_at=led_eager,
                ),
            )
        return done

    def publish_batches(
        self, topic: int, batches: List[List[Tuple[int, Chunk]]],
        at_time: float, lanes: int = 8,
        *, ledger_at: Optional[float] = None,
    ):
        """Publish a sequence of batches round-robin over ``lanes`` concurrent
        connections starting at ``at_time``; returns the per-lane completion
        times.  Billing is exactly ``len(batches)`` ``publish_batch`` calls —
        this is the one-call entry point the fleet send path uses so a layer's
        whole publish schedule is a single fabric interaction.

        With ``ledger_at`` set, the same lane schedule is mirrored on the
        overlapped timeline starting at ``ledger_at`` (identical assignment
        ``i % lanes``), and the return is ``(lane_time, ledger_lane_time)``.
        """
        lane_time = [at_time] * max(1, lanes)
        led_lanes = None if ledger_at is None else [ledger_at] * len(lane_time)
        for i, batch in enumerate(batches):
            lane = i % len(lane_time)
            if led_lanes is None:
                lane_time[lane] = self.publish_batch(topic, batch, lane_time[lane])
            else:
                lane_time[lane] = self.publish_batch(
                    topic, batch, lane_time[lane], ledger_at=led_lanes[lane]
                )
                led_lanes[lane] += self.publish_latency
        if ledger_at is None:
            return lane_time
        return lane_time, led_lanes

    def _next_receipt(self) -> int:
        self._receipt += 1
        return self._receipt

    # -- consumer side ------------------------------------------------------

    def poll(
        self, worker: int, at_time: float, long_poll: bool = True, max_messages: int = 10
    ) -> Tuple[float, List[Delivery]]:
        """ReceiveMessage.  Returns (time_after_poll, deliveries).

        Long polling: if nothing is available now, block until the earliest
        delivery or the window expiry, whichever first (no extra API cost
        while waiting).  Short polling: returns immediately, and each
        available message is missed with ``short_poll_miss_prob`` (not all
        SQS servers are visited).

        Boundary semantics (pinned): a long poll waits over the half-open
        window ``[now, now + long_poll_window)``.  A message whose
        ``deliver_at`` lands exactly on the window deadline is NOT returned —
        the empty response is already on the wire at that instant — so the
        call bills one empty poll and the next call collects the message.
        Every call counts exactly one of {delivered, empty}, never both.

        At-least-once semantics: returned messages are NOT removed — they
        move to an in-flight set with a ``visibility_timeout`` deadline and
        only ``delete_batch`` retires them.  An undeleted message reappears
        (fresh receipt, re-billed on redelivery) once the deadline passes.
        """
        if self.chaos is not None:
            at_time, n_retries = self.chaos.throttle("sqs_receive", at_time)
            self.metrics.throttle_retries += n_retries
        self.metrics.sqs_api_calls += 1
        q = self._queues[worker]
        now = at_time + self.poll_rtt
        self._requeue_expired(worker, now)
        inflight = self._inflight[worker]

        def available(t: float) -> List[_OrderedDelivery]:
            out = []
            while q and q[0].deliver_at <= t and len(out) < max_messages:
                out.append(heapq.heappop(q))
            return out

        if long_poll:
            got = available(now)
            if not got:
                deadline = now + self.long_poll_window
                # The earliest thing that can show up inside the window is
                # either a scheduled delivery or an in-flight message whose
                # visibility deadline expires (a redelivery).
                wake = q[0].deliver_at if q else float("inf")
                if inflight:
                    wake = min(wake, min(t for t, _ in inflight.values()))
                if wake < deadline:
                    now = max(now, wake)
                    self._requeue_expired(worker, now)
                    got = available(now)
                else:
                    now = deadline
        else:
            got = []
            for d in available(now):
                if self._rng.random() < self.short_poll_miss_prob:
                    heapq.heappush(q, d)  # not seen this poll
                else:
                    got.append(d)
        if got:
            self.metrics.messages_delivered += len(got)
            for d in got:
                inflight[d.receipt] = (now + self.visibility_timeout, d)
        else:
            self.metrics.empty_polls += 1
        return now, [d.as_delivery() for d in got]

    def _requeue_expired(self, worker: int, t: float) -> None:
        """Requeue in-flight messages whose visibility deadline has passed.

        Redelivered messages get a fresh receipt (as SQS receipt handles do),
        so a late delete of the old receipt is a harmless no-op; ledger
        stamps are cleared so drains time the redelivery off ``deliver_at``.
        """
        inflight = self._inflight[worker]
        expired = [r for r, (vis, _) in inflight.items() if vis <= t]
        for r in expired:
            vis, d = inflight.pop(r)
            self.metrics.redeliveries += 1
            heapq.heappush(
                self._queues[worker],
                _OrderedDelivery(vis, self._next_receipt(), d.target, d.blob),
            )

    def delete_batch(self, worker: int, receipts: List[int], at_time: float) -> float:
        """DeleteMessageBatch — one API call per ≤10 receipts.

        An empty receipt list is a no-op: no API call is made (and none
        billed), and no RTT is paid.  Unknown / already-requeued receipts
        within a non-empty batch are ignored, matching SQS's per-entry
        failure semantics.
        """
        if not receipts:
            return at_time
        n_calls = -(-len(receipts) // 10)
        self.metrics.sqs_api_calls += n_calls
        inflight = self._inflight[worker]
        for r in receipts:
            inflight.pop(r, None)
        return at_time + self.poll_rtt

    def pending(self, worker: int) -> int:
        return len(self._queues[worker])


@dataclasses.dataclass(order=True)
class _OrderedDelivery:
    deliver_at: float
    receipt: int
    target: int = dataclasses.field(compare=False)
    blob: Chunk = dataclasses.field(compare=False)
    ledger_at: Optional[float] = dataclasses.field(compare=False, default=None)
    ledger_eager_at: Optional[float] = dataclasses.field(compare=False,
                                                         default=None)

    def as_delivery(self) -> Delivery:
        return Delivery(
            deliver_at=self.deliver_at,
            target=self.target,
            blob=self.blob,
            attributes={},
            receipt=self.receipt,
            ledger_at=self.ledger_at,
            ledger_eager_at=self.ledger_eager_at,
        )
