"""Per-worker state for the simulated Lambda fleet."""

from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

__all__ = ["WorkerState", "ComputeModel", "estimate_worker_memory_bytes"]


@dataclasses.dataclass
class ComputeModel:
    """Maps work to seconds on a Lambda instance.

    AWS allocates ~1 vCPU per 1769MB of configured memory (capped at 6);
    effective numpy SpMM throughput per vCPU is taken from public Lambda
    measurements (~1.8 GFLOP/s for scipy-like sparse kernels).
    """

    flops_per_vcpu: float = 1.8e9
    pack_bandwidth: float = 400e6    # zlib level-1 compress, B/s
    unpack_bandwidth: float = 900e6  # zlib decompress, B/s
    max_vcpus: float = 6.0
    vcpu_per_mb: float = 1.0 / 1769.0

    def vcpus(self, memory_mb: int) -> float:
        return min(self.max_vcpus, max(0.07, memory_mb * self.vcpu_per_mb))

    def flops_seconds(self, flops: float, memory_mb: int) -> float:
        return flops / (self.flops_per_vcpu * self.vcpus(memory_mb))


@dataclasses.dataclass
class WorkerState:
    rank: int
    memory_mb: int
    clock: float = 0.0               # seconds since its own invocation epoch
    start_time: float = 0.0          # absolute ready time from the launch tree
    slowdown: float = 1.0            # straggler factor on compute
    flops: float = 0.0
    bytes_sent: int = 0
    bytes_received: int = 0
    messages_sent: int = 0
    messages_received: int = 0
    mem_high_water: int = 0

    @property
    def abs_time(self) -> float:
        return self.start_time + self.clock

    def advance_to_abs(self, t_abs: float) -> None:
        self.clock = max(self.clock, t_abs - self.start_time)

    def charge_compute(self, flops: float, model: ComputeModel) -> None:
        self.flops += flops
        self.clock += model.flops_seconds(flops, self.memory_mb) * self.slowdown

    def charge_seconds(self, s: float) -> None:
        self.clock += s

    def touch_memory(self, n_bytes: int) -> None:
        self.mem_high_water = max(self.mem_high_water, n_bytes)


PY_OVERHEAD = 1.4  # interpreter + allocator overhead on top of raw buffers


def estimate_worker_memory_bytes(
    weight_nnz: int, max_needed_rows: int, max_out_rows: int, batch: int,
    bytes_per_nnz: int = 8, act_bytes: int = 4,
) -> int:
    """Peak resident bytes: CSR weights + input/output activation panels
    (double-buffered across the layer boundary) + one in-flight message."""
    weights = weight_nnz * bytes_per_nnz
    acts = (max_needed_rows + max_out_rows) * batch * act_bytes
    return int((weights + acts) * PY_OVERHEAD)
