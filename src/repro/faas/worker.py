"""Per-worker state for the simulated Lambda fleet.

Two clock models live here:

* the **phased clock** (``WorkerState.clock``) — the strict-sum model every
  fabric interaction is driven by: each layer's pack → publish → local MVP →
  drain → finish charges accumulate serially.  This clock decides *when*
  messages are published and polled, so every billable count (publish units,
  SQS calls, S3 requests, wire bytes) derives from it alone;
* the **event ledger** (``EventLedger``) — the overlapped-pipeline model:
  separate compute and channel timelines per worker, merged only at true
  dependency edges (a publish needs its payload packed; a layer finish needs
  the drain complete).  The ledger never touches the fabric — it re-times
  the exact events the phased clock executed — so switching the reported
  timeline between the two models cannot change a single charge count.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import numpy as np

__all__ = ["WorkerState", "EventLedger", "ComputeModel",
           "ModelStageWorker", "estimate_worker_memory_bytes"]


@dataclasses.dataclass
class EventLedger:
    """Dual-timeline event ledger for the overlapped layer pipeline.

    ``t_compute`` carries pack, SpMM, and epilogue work; ``t_channel``
    carries publish lane occupancy and the receiver thread's unpack work.
    Both are *absolute* seconds (same epoch as ``WorkerState.abs_time``) and
    monotone by construction — every mutator takes ``max`` with the current
    value before adding, so a dependency edge can only delay an event, never
    rewind a timeline.
    """

    t_compute: float = 0.0
    t_channel: float = 0.0
    # Eager polling: the receiver thread parks its long-poll / LIST loop for
    # layer l+1 while the layer-l publisher is still packing, so a chunk's
    # availability is its *eager* stamp (one-way publish half-trip + fan-out
    # + push half of the poll RTT) instead of the blocked-reader stamp.
    # Pure re-timing: the phased clock still drives every fabric call, so no
    # billable count can move.
    eager_poll: bool = False

    @property
    def done(self) -> float:
        """The worker is finished when both timelines drain."""
        return max(self.t_compute, self.t_channel)

    def recv_available(self, lazy_at: float,
                       eager_at: Optional[float]) -> float:
        """Availability stamp a drain should gate ``receive`` on: the eager
        stamp when this ledger polls eagerly and the sender recorded one,
        else the blocked-reader stamp."""
        if self.eager_poll and eager_at is not None:
            return eager_at
        return lazy_at

    def compute(self, seconds: float) -> None:
        self.t_compute += seconds

    def channel_busy_from(self, ready: float, seconds: float) -> float:
        """Occupy the channel timeline with a send that cannot start before
        ``ready`` (its payload's pack completion); returns the finish time."""
        self.t_channel = max(self.t_channel, ready) + seconds
        return self.t_channel

    def receive(self, available_at: float, seconds: float) -> None:
        """Receiver-thread work on a chunk that became available (service
        side) at ``available_at``: the thread is blocked in a long poll /
        LIST loop, so the data is in hand at availability and only the
        deserialize/stream cost occupies the channel timeline."""
        self.t_channel = max(self.t_channel, available_at) + seconds

    def join_compute(self) -> None:
        """Dependency edge channel → compute (e.g. a layer finish needs the
        drain complete): compute may not proceed past the channel timeline."""
        self.t_compute = max(self.t_compute, self.t_channel)

    def sync(self, seconds: float) -> None:
        """A fleet-wide stall that occupies the whole worker (cold start,
        weight reload on re-invoke): both timelines meet, then advance."""
        t = self.done + seconds
        self.t_compute = t
        self.t_channel = t

    def sync_to(self, t_abs: float) -> None:
        """Advance both timelines to an absolute release time (collectives)."""
        self.t_compute = max(self.t_compute, t_abs)
        self.t_channel = max(self.t_channel, t_abs)


@dataclasses.dataclass
class ComputeModel:
    """Maps work to seconds on a Lambda instance.

    AWS allocates ~1 vCPU per 1769MB of configured memory (capped at 6);
    effective numpy SpMM throughput per vCPU is taken from public Lambda
    measurements (~1.8 GFLOP/s for scipy-like sparse kernels).
    """

    flops_per_vcpu: float = 1.8e9
    pack_bandwidth: float = 400e6    # zlib level-1 compress, B/s
    unpack_bandwidth: float = 900e6  # zlib decompress, B/s
    max_vcpus: float = 6.0
    vcpu_per_mb: float = 1.0 / 1769.0

    def vcpus(self, memory_mb: int) -> float:
        return min(self.max_vcpus, max(0.07, memory_mb * self.vcpu_per_mb))

    def flops_seconds(self, flops: float, memory_mb: int) -> float:
        return flops / (self.flops_per_vcpu * self.vcpus(memory_mb))


@dataclasses.dataclass
class WorkerState:
    rank: int
    memory_mb: int
    clock: float = 0.0               # seconds since its own invocation epoch
    start_time: float = 0.0          # absolute ready time from the launch tree
    slowdown: float = 1.0            # straggler factor on compute
    flops: float = 0.0
    bytes_sent: int = 0
    bytes_received: int = 0
    messages_sent: int = 0
    messages_received: int = 0
    mem_high_water: int = 0
    # Overlapped-pipeline timelines; None outside run_fsi (unit tests that
    # drive helpers directly get the phased clock only).
    ledger: Optional[EventLedger] = None

    @property
    def abs_time(self) -> float:
        return self.start_time + self.clock

    @property
    def overlap_time(self) -> float:
        """Absolute finish time under the overlapped model (falls back to the
        phased clock when no ledger is attached)."""
        return self.ledger.done if self.ledger is not None else self.abs_time

    def advance_to_abs(self, t_abs: float) -> None:
        self.clock = max(self.clock, t_abs - self.start_time)

    def charge_compute(self, flops: float, model: ComputeModel) -> None:
        self.flops += flops
        s = model.flops_seconds(flops, self.memory_mb) * self.slowdown
        self.clock += s
        if self.ledger is not None:
            self.ledger.compute(s)

    def charge_seconds(self, s: float) -> None:
        self.clock += s

    def touch_memory(self, n_bytes: int) -> None:
        self.mem_high_water = max(self.mem_high_water, n_bytes)


# ---------------------------------------------------------------------------
# Model-stage executor — the LM-pipeline sibling of the FSI worker
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ModelStageWorker:
    """One pipeline stage of an LM, resident on one FaaS worker.

    Holds the stage's sliced parameter subtree and its KV cache between
    decode steps (KV residency: the cache never crosses a stage boundary —
    only the [B, S, d] / [B, 1, d] activation does).  The compute functions
    are injected (jitted closures over the family's stage fns), so this
    module stays framework-free.

    ``weight_bytes`` is the stage slice's actual parameter footprint — the
    quantity ``charge_weight_load`` bills at worker startup, so a stage is
    never billed the full-model load.  ``flops_per_token`` is the stage's
    active-parameter FLOPs for one token (prefill multiplies by the prompt
    length).
    """

    spec: Any                              # core.partitioner.StageSpec
    params: Any                            # sliced stage parameter pytree
    prefill_fn: Callable[..., Any]         # (params, x_in, max_len) -> (out, cache)
    decode_fn: Callable[..., Any]          # (params, x_in, cache) -> (out, cache)
    weight_bytes: int = 0
    flops_per_token: float = 0.0
    cache: Any = None                      # worker-resident KV cache

    def reset(self) -> None:
        self.cache = None

    def run_prefill(self, x_in, max_len: int, extra=None):
        if extra is not None:
            out, self.cache = self.prefill_fn(self.params, x_in, max_len, extra)
        else:
            out, self.cache = self.prefill_fn(self.params, x_in, max_len)
        return out

    def run_decode(self, x_in):
        if self.cache is None:
            raise RuntimeError(
                f"stage {self.spec} decode before prefill: no resident cache")
        out, self.cache = self.decode_fn(self.params, x_in, self.cache)
        return out


PY_OVERHEAD = 1.4  # interpreter + allocator overhead on top of raw buffers


def estimate_worker_memory_bytes(
    weight_nnz: int, max_needed_rows: int, max_out_rows: int, batch: int,
    bytes_per_nnz: int = 8, act_bytes: int = 4,
) -> int:
    """Peak resident bytes: CSR weights + input/output activation panels
    (double-buffered across the layer boundary) + one in-flight message."""
    weights = weight_nnz * bytes_per_nnz
    acts = (max_needed_rows + max_out_rows) * batch * act_bytes
    return int((weights + acts) * PY_OVERHEAD)
