"""Message payload encoding for the FSI channels (paper §III-C1).

Intermediate results ``x̄_mn^{k-1}`` (selected rows of the activation matrix)
are serialized as::

    header: layer(u32) | src(u32) | n_rows(u32) | batch(u32) | seq(u32) | total(u32)
    body:   row_ids int32[n_rows] | values float32[n_rows, batch]

then zlib-compressed (paper §IV-B: "Both FSD-Inf-Queue and FSD-Inf-Object
utilize ZLIB compression to reduce the communication volume").

``pack_rows`` splits a row set into byte strings that each stay under the
pub-sub payload cap, using the paper's NNZ heuristic to estimate how many
rows fit per message before compressing (grouping and compressing rows only
once per message).
"""

from __future__ import annotations

import struct
import zlib
from typing import List, Tuple

import numpy as np

__all__ = ["encode_chunk", "decode_chunk", "pack_rows", "Chunk"]

_HEADER = struct.Struct("<6I")


def _buffer(arr: np.ndarray, dtype) -> object:
    """Zero-copy buffer view when the array is already contiguous+typed
    (the pack_rows fast path); otherwise one conversion copy."""
    if arr.dtype != dtype or not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr, dtype=dtype)
    return arr.data


def encode_chunk(
    layer: int, src: int, row_ids: np.ndarray, values: np.ndarray,
    seq: int, total: int, compress: bool = True,
) -> bytes:
    assert values.shape[0] == row_ids.shape[0]
    header = _HEADER.pack(layer, src, len(row_ids), values.shape[1], seq, total)
    ids_buf = _buffer(row_ids, np.int32)
    val_buf = _buffer(values, np.float32)
    if not compress:
        return header + bytes(ids_buf) + bytes(val_buf)
    # stream the pieces through one compressobj: no concatenated body temp
    co = zlib.compressobj(1)
    return b"".join(
        (co.compress(header), co.compress(ids_buf), co.compress(val_buf),
         co.flush())
    )


def decode_chunk(blob: bytes, compressed: bool = True) -> Tuple[int, int, np.ndarray, np.ndarray, int, int]:
    body = zlib.decompress(blob) if compressed else blob
    layer, src, n_rows, batch, seq, total = _HEADER.unpack_from(body, 0)
    off = _HEADER.size
    row_ids = np.frombuffer(body, dtype=np.int32, count=n_rows, offset=off)
    off += 4 * n_rows
    values = np.frombuffer(body, dtype=np.float32, count=n_rows * batch, offset=off)
    return layer, src, row_ids.copy(), values.reshape(n_rows, batch).copy(), seq, total


class Chunk(bytes):
    """A byte-string message; subclass only to carry the uncompressed size."""

    raw_bytes: int

    def __new__(cls, data: bytes, raw_bytes: int):
        obj = super().__new__(cls, data)
        obj.raw_bytes = raw_bytes
        return obj


def pack_rows(
    layer: int,
    src: int,
    row_ids: np.ndarray,
    values: np.ndarray,
    max_payload: int,
    compress: bool = True,
    est_compression_ratio: float = 0.45,
) -> List[Chunk]:
    """Split (row_ids, values) into ≤max_payload byte strings.

    The NNZ-count heuristic sizes the first split; if a compressed chunk still
    exceeds the cap (adversarial entropy) it is split again recursively.
    """
    n_rows, batch = values.shape
    if n_rows == 0:
        return []
    # normalize dtype/layout ONCE so every emitted slice is a zero-copy
    # contiguous view inside encode_chunk (no per-chunk ascontiguousarray)
    row_ids = np.ascontiguousarray(row_ids, dtype=np.int32)
    values = np.ascontiguousarray(values, dtype=np.float32)
    bytes_per_row = 4 + 4 * batch
    est = bytes_per_row * (est_compression_ratio if compress else 1.0)
    rows_per_msg = max(1, int(max_payload / max(est, 1e-9)))
    chunks: List[Tuple[np.ndarray, np.ndarray]] = []

    def emit(ids: np.ndarray, vals: np.ndarray):
        blob = encode_chunk(layer, src, ids, vals, 0, 0, compress)
        if len(blob) > max_payload and len(ids) > 1:
            mid = len(ids) // 2
            emit(ids[:mid], vals[:mid])
            emit(ids[mid:], vals[mid:])
        else:
            chunks.append((ids, vals))

    for lo in range(0, n_rows, rows_per_msg):
        emit(row_ids[lo : lo + rows_per_msg], values[lo : lo + rows_per_msg])

    total = len(chunks)
    out: List[Chunk] = []
    for seq, (ids, vals) in enumerate(chunks):
        blob = encode_chunk(layer, src, ids, vals, seq, total, compress)
        out.append(Chunk(blob, raw_bytes=_HEADER.size + len(ids) * bytes_per_row))
    return out
