"""Message payload encoding for the FSI channels (paper §III-C1).

Intermediate results ``x̄_mn^{k-1}`` (selected rows of the activation matrix)
are serialized as::

    header: layer(u32) | src(u32) | n_rows(u32) | batch(u32) | seq(u32) | total(u32)
    body:   row_ids int32[n_rows] | values float32[n_rows, batch]

then zlib-compressed (paper §IV-B: "Both FSD-Inf-Queue and FSD-Inf-Object
utilize ZLIB compression to reduce the communication volume").

``pack_rows`` splits a row set into byte strings that each stay under the
pub-sub payload cap, using the paper's NNZ heuristic to estimate how many
rows fit per message before compressing (grouping and compressing rows only
once per message).  ``pack_rows_fleet`` is the batched entry point: it packs
every worker's outgoing row-sets for one layer in a single call, sharing one
deflate-state pool across all chunks — the byte streams are identical to P
independent ``pack_rows`` calls (billing invariance), only the Python-level
per-chunk setup cost is amortized.

``decode_chunk`` is zero-copy: the returned ``row_ids``/``values`` are
read-only views into the decompressed body.  The single place the FSI recv
paths materialize a copy is the scatter into the destination buffer.
"""

from __future__ import annotations

import struct
import zlib
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["encode_chunk", "decode_chunk", "pack_rows", "pack_rows_fleet",
           "Chunk"]

_HEADER = struct.Struct("<6I")
_ZLIB_LEVEL = 1


def _buffer(arr: np.ndarray, dtype) -> object:
    """Zero-copy buffer view when the array is already contiguous+typed
    (the pack_rows fast path); otherwise one conversion copy."""
    if arr.dtype != dtype or not arr.flags.c_contiguous:
        arr = np.ascontiguousarray(arr, dtype=dtype)
    return arr.data


class _CompressPool:
    """Deflate-state provider shared by every chunk of one batched pack.

    Centralizing the level here keeps every chunk's stream byte-identical
    whichever entry point packed it — the wire volume (and everything billed
    over it) cannot drift between the per-worker and fleet-batched send
    paths.  States are provisioned fresh per chunk: ``compressobj(1)`` is
    ~3µs while ``compressobj.copy()`` duplicates the full deflate window
    (~500µs measured), so cloning a template would be a pessimization.
    """

    def __init__(self, level: int = _ZLIB_LEVEL):
        self._level = level

    def fresh(self):
        return zlib.compressobj(self._level)


def encode_chunk(
    layer: int, src: int, row_ids: np.ndarray, values: np.ndarray,
    seq: int, total: int, compress: bool = True,
    _pool: Optional[_CompressPool] = None,
) -> bytes:
    assert values.shape[0] == row_ids.shape[0]
    header = _HEADER.pack(layer, src, len(row_ids), values.shape[1], seq, total)
    ids_buf = _buffer(row_ids, np.int32)
    val_buf = _buffer(values, np.float32)
    if not compress:
        return header + bytes(ids_buf) + bytes(val_buf)
    # stream the pieces through one compressobj: no concatenated body temp
    co = _pool.fresh() if _pool is not None else zlib.compressobj(_ZLIB_LEVEL)
    return b"".join(
        (co.compress(header), co.compress(ids_buf), co.compress(val_buf),
         co.flush())
    )


def decode_chunk(blob: bytes, compressed: bool = True) -> Tuple[int, int, np.ndarray, np.ndarray, int, int]:
    """Decode one chunk; ``row_ids``/``values`` are zero-copy read-only views
    into the (decompressed) body — they stay valid as long as the caller
    holds them, and any mutation must copy first (the recv scatter is the
    one site that materializes them, into the destination buffer)."""
    body = zlib.decompress(blob) if compressed else blob
    layer, src, n_rows, batch, seq, total = _HEADER.unpack_from(body, 0)
    off = _HEADER.size
    row_ids = np.frombuffer(body, dtype=np.int32, count=n_rows, offset=off)
    off += 4 * n_rows
    values = np.frombuffer(body, dtype=np.float32, count=n_rows * batch, offset=off)
    row_ids.flags.writeable = False   # bytes-backed already; bytearray too
    values.flags.writeable = False
    return layer, src, row_ids, values.reshape(n_rows, batch), seq, total


class Chunk(bytes):
    """A byte-string message; subclass only to carry the uncompressed size."""

    raw_bytes: int

    def __new__(cls, data: bytes, raw_bytes: int):
        obj = super().__new__(cls, data)
        obj.raw_bytes = raw_bytes
        return obj


def _pack_rows_one(
    layer: int,
    src: int,
    row_ids: np.ndarray,
    values: np.ndarray,
    max_payload: int,
    compress: bool,
    est_compression_ratio: float,
    pool: Optional[_CompressPool],
) -> List[Chunk]:
    """The pack core shared by ``pack_rows`` and ``pack_rows_fleet``."""
    n_rows, batch = values.shape
    if n_rows == 0:
        return []
    # normalize dtype/layout ONCE so every emitted slice is a zero-copy
    # contiguous view inside encode_chunk (no per-chunk ascontiguousarray)
    row_ids = np.ascontiguousarray(row_ids, dtype=np.int32)
    values = np.ascontiguousarray(values, dtype=np.float32)
    bytes_per_row = 4 + 4 * batch
    est = bytes_per_row * (est_compression_ratio if compress else 1.0)
    rows_per_msg = max(1, int(max_payload / max(est, 1e-9)))
    if n_rows <= rows_per_msg:
        # Single-message fast path (the overwhelmingly common case at high
        # P, where per-target payloads are small): encode once with the
        # final (seq=0, total=1) framing and keep it if it fits — the split
        # machinery below would compress the same rows twice.
        blob = encode_chunk(layer, src, row_ids, values, 0, 1, compress,
                            _pool=pool)
        if len(blob) <= max_payload or n_rows == 1:
            return [Chunk(blob, raw_bytes=_HEADER.size + n_rows * bytes_per_row)]
    chunks: List[Tuple[np.ndarray, np.ndarray]] = []

    # Oversized trial encodes (adversarial entropy beats the NNZ estimate)
    # are re-split on an explicit work stack — LIFO with the right half
    # pushed first keeps row order, and the depth is bounded by the stack,
    # not the Python recursion limit.
    work: List[Tuple[np.ndarray, np.ndarray]] = [
        (row_ids[lo: lo + rows_per_msg], values[lo: lo + rows_per_msg])
        for lo in reversed(range(0, n_rows, rows_per_msg))
    ]
    while work:
        ids, vals = work.pop()
        blob = encode_chunk(layer, src, ids, vals, 0, 0, compress, _pool=pool)
        if len(blob) > max_payload and len(ids) > 1:
            mid = len(ids) // 2
            work.append((ids[mid:], vals[mid:]))
            work.append((ids[:mid], vals[:mid]))
        else:
            chunks.append((ids, vals))

    total = len(chunks)
    out: List[Chunk] = []
    for seq, (ids, vals) in enumerate(chunks):
        blob = encode_chunk(layer, src, ids, vals, seq, total, compress,
                            _pool=pool)
        out.append(Chunk(blob, raw_bytes=_HEADER.size + len(ids) * bytes_per_row))
    return out


def pack_rows(
    layer: int,
    src: int,
    row_ids: np.ndarray,
    values: np.ndarray,
    max_payload: int,
    compress: bool = True,
    est_compression_ratio: float = 0.45,
) -> List[Chunk]:
    """Split (row_ids, values) into ≤max_payload byte strings.

    The NNZ-count heuristic sizes the first split; if a compressed chunk
    still exceeds the cap (adversarial entropy) it is halved again on the
    work stack until it fits or is a single row.
    """
    return _pack_rows_one(layer, src, row_ids, values, max_payload, compress,
                          est_compression_ratio, pool=None)


def pack_rows_fleet(
    jobs: Sequence[Tuple[int, int, np.ndarray, np.ndarray]],
    max_payload: int,
    compress: bool = True,
    est_compression_ratio: float = 0.45,
) -> Iterator[List[Chunk]]:
    """Batched ``pack_rows``: pack every (layer, src, row_ids, values) job of
    one fleet layer in a single call.

    One deflate-state pool serves every chunk of every job, and the jobs are
    packed lazily in order — the produced byte strings are identical to
    ``[pack_rows(*job, max_payload, ...) for job in jobs]`` (asserted in
    ``tests/test_faas_services.py``), so message counts, wire bytes, and all
    billing quantized over them are invariant to which entry point packed
    the layer.
    """
    pool = _CompressPool() if compress else None
    for layer, src, row_ids, values in jobs:
        yield _pack_rows_one(layer, src, row_ids, values, max_payload,
                             compress, est_compression_ratio, pool=pool)
