"""Simulated S3 object storage fabric — FSD-Inf-Object (paper §III-B).

Per the paper (Fig. 3):

* ``n_buckets`` containers (``bucket-{n%10}``) so the per-prefix API request
  quota scales k-fold [Lambada];
* worker ``m`` sending to worker ``n`` in layer ``k`` writes
  ``bucket-{n%b}/{k}/{n}/{m}_{n}.dat`` — or a zero-byte ``.nul`` marker when
  it has nothing to send, so readers never GET empty files;
* readers repeatedly LIST their own single prefix ``bucket-{m%b}/{k}/{m}/``
  and GET only ``.dat`` handles still present in their recv map;
* PUT/GET/LIST are billed per request, *independent of object size*, and
  data transfer S3↔Lambda is free in-region — which is exactly why Object
  wins at very large payloads and loses at high parallelism (§IV-C).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.faas.payload import Chunk

__all__ = ["ObjectFabric", "ObjectMetrics", "ObjectHandle"]


@dataclasses.dataclass
class ObjectMetrics:
    puts: int = 0       # V in Eq. 7
    gets: int = 0       # R in Eq. 7
    lists: int = 0      # L in Eq. 7
    bytes_written: int = 0
    raw_bytes: int = 0
    nul_files: int = 0
    throttle_retries: int = 0   # chaos-injected 503/429 retries


@dataclasses.dataclass
class ObjectHandle:
    key: str
    size: int
    visible_at: float
    is_nul: bool
    src: int
    # Visibility under the overlapped-pipeline ledger (sender's channel
    # timeline + PUT latency + streaming).  None when the writer carried no
    # ledger; drains then fall back to ``visible_at``.
    ledger_visible_at: Optional[float] = None
    # Visibility under an *eager* reader: its LIST loop is already running
    # when the PUT lands, so the object becomes actionable after the one-way
    # PUT half-trip plus streaming — the PUT ack half overlaps the reader's
    # in-flight LIST.  The reader still pays its own LIST + GET latencies on
    # receive.  Ledger-only; billing and phased visibility never read this.
    ledger_eager_visible_at: Optional[float] = None


class ObjectFabric:
    def __init__(
        self,
        n_workers: int,
        n_buckets: int = 10,
        put_latency: float = 0.030,
        get_first_byte: float = 0.018,
        list_latency: float = 0.025,
        bandwidth: float = 90e6,  # per-connection S3 streaming throughput
    ):
        self.n_workers = n_workers
        self.n_buckets = max(1, min(n_buckets, n_workers))
        self.put_latency = put_latency
        self.get_first_byte = get_first_byte
        self.list_latency = list_latency
        self.bandwidth = bandwidth
        self.metrics = ObjectMetrics()
        # prefix "(bucket, layer, target)" → {key: (handle, blob)}
        self._store: Dict[Tuple[int, int, int], Dict[str, Tuple[ObjectHandle, Chunk]]] = {}
        # Optional chaos hook (repro.faas.chaos.ChaosState); when set, PUT /
        # GET / LIST consult it for throttles (SlowDown / 429).  None in
        # production runs — zero overhead, zero billing drift.
        self.chaos = None

    def _maybe_throttle(self, stream: str, at_time: float) -> float:
        if self.chaos is not None:
            at_time, n = self.chaos.throttle(stream, at_time)
            self.metrics.throttle_retries += n
        return at_time

    def _prefix(self, layer: int, target: int) -> Tuple[int, int, int]:
        return (target % self.n_buckets, layer, target)

    def put_obj(
        self, layer: int, src: int, target: int, blob: Chunk | None, at_time: float,
        *, ledger_at: Optional[float] = None,
    ) -> float:
        """PUT one object (or the 0-byte .nul marker); returns completion time.

        ``ledger_at`` is the PUT start on the overlapped-pipeline timeline; it
        only stamps the handle's ``ledger_visible_at`` and never affects
        billing or the phased visibility schedule."""
        at_time = self._maybe_throttle("s3_put", at_time)
        self.metrics.puts += 1
        is_nul = blob is None or len(blob) == 0
        size = 0 if is_nul else len(blob)
        done = at_time + self.put_latency + size / self.bandwidth
        led_done = (None if ledger_at is None
                    else ledger_at + self.put_latency + size / self.bandwidth)
        led_eager = (None if ledger_at is None
                     else ledger_at + self.put_latency / 2
                     + size / self.bandwidth)
        ext = "nul" if is_nul else "dat"
        key = f"{src}_{target}.{ext}"
        handle = ObjectHandle(key=key, size=size, visible_at=done, is_nul=is_nul,
                              src=src, ledger_visible_at=led_done,
                              ledger_eager_visible_at=led_eager)
        self._store.setdefault(self._prefix(layer, target), {})[key] = (
            handle,
            blob if blob is not None else Chunk(b"", 0),
        )
        if is_nul:
            self.metrics.nul_files += 1
        else:
            self.metrics.bytes_written += size
            self.metrics.raw_bytes += blob.raw_bytes
        return done

    def put_multipart(
        self, layer: int, src: int, target: int, blobs: List[Chunk], at_time: float,
        *, ledger_at: Optional[float] = None,
    ) -> float:
        """Large sends: object storage allows effectively unlimited object
        size, so multiple chunks to one target become one object (paper:
        'each FaaS instance only needs to write a single object for each of
        its targets in a given layer')."""
        if not blobs:
            if ledger_at is None:
                return self.put_obj(layer, src, target, None, at_time)
            return self.put_obj(layer, src, target, None, at_time,
                                ledger_at=ledger_at)
        joined = b"".join(
            len(b).to_bytes(8, "little") + bytes(b) for b in blobs
        )
        chunk = Chunk(joined, raw_bytes=sum(b.raw_bytes for b in blobs))
        if ledger_at is None:
            return self.put_obj(layer, src, target, chunk, at_time)
        return self.put_obj(layer, src, target, chunk, at_time,
                            ledger_at=ledger_at)

    def put_multiparts(
        self, layer: int, src: int,
        target_blobs: List[Tuple[int, List[Chunk]]], at_time: float,
        lanes: int = 8,
        *, ledger_at: Optional[float] = None,
    ):
        """PUT one multipart object (or ``.nul``) per (target, chunks) pair,
        round-robin over ``lanes`` concurrent connections starting at
        ``at_time``; returns the per-lane completion times.  Billing is
        exactly one ``put_multipart`` per target — the one-call entry point
        the fleet send path uses for a layer's whole PUT schedule.

        With ``ledger_at`` set, the same lane schedule is mirrored on the
        overlapped timeline (identical ``i % lanes`` assignment) and the
        return is ``(lane_time, ledger_lane_time)``."""
        lane_time = [at_time] * max(1, lanes)
        led_lanes = None if ledger_at is None else [ledger_at] * len(lane_time)
        for i, (target, blobs) in enumerate(target_blobs):
            lane = i % len(lane_time)
            if led_lanes is None:
                lane_time[lane] = self.put_multipart(
                    layer, src, target, blobs, lane_time[lane]
                )
            else:
                lane_time[lane] = self.put_multipart(
                    layer, src, target, blobs, lane_time[lane],
                    ledger_at=led_lanes[lane],
                )
                # mirror put_obj's duration arithmetic (length-prefixed join)
                size = sum(len(b) + 8 for b in blobs) if blobs else 0
                led_lanes[lane] += self.put_latency + size / self.bandwidth
        if ledger_at is None:
            return lane_time
        return lane_time, led_lanes

    @staticmethod
    def split_multipart(blob: bytes) -> List[bytes]:
        out, off = [], 0
        while off < len(blob):
            n = int.from_bytes(blob[off : off + 8], "little")
            off += 8
            out.append(blob[off : off + n])
            off += n
        return out

    def list_files(self, layer: int, worker: int, at_time: float) -> Tuple[float, List[ObjectHandle]]:
        """LIST the worker's own prefix; only handles already visible show up."""
        at_time = self._maybe_throttle("s3_list", at_time)
        self.metrics.lists += 1
        now = at_time + self.list_latency
        entries = self._store.get(self._prefix(layer, worker), {})
        visible = [h for h, _ in entries.values() if h.visible_at <= now]
        return now, sorted(visible, key=lambda h: h.key)

    def get_obj(self, layer: int, worker: int, key: str, at_time: float) -> Tuple[float, Chunk]:
        at_time = self._maybe_throttle("s3_get", at_time)
        self.metrics.gets += 1
        handle, blob = self._store[self._prefix(layer, worker)][key]
        now = at_time + self.get_first_byte + handle.size / self.bandwidth
        return now, blob
