"""Oracle for the flash attention kernel (reuses the model-side reference)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import full_attention


def flash_attention_ref(q, k, v, causal: bool = True):
    """q [B,H,Sq,D], k/v [B,KV,Sk,D] → o [B,H,Sq,D] (naive softmax)."""
    # model-side reference uses [B, S, H, D] layout
    o = full_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=causal,
    )
    return o.transpose(0, 2, 1, 3)
