"""Flash attention (prefill) Pallas kernel — TPU BlockSpec pattern.

Grid: (batch·heads, n_q_blocks, n_kv_blocks).  TPU grids execute the last
dimension sequentially per core, so the (m, l, acc) running-softmax state
lives in VMEM scratch and persists across the kv-block sweep; the output is
normalized and written on the final kv block.  Causal masking is applied
per tile; fully-masked tiles still execute (masked) — skipping them is a
documented hillclimb (§Perf).

Block shapes are MXU-aligned (bq, bk multiples of 128; D = head_dim is 64 or
128 for every assigned arch).  GQA folds into the k/v index_map (h → h//G).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, n_k: int, causal: bool, scale: float):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)
    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == n_k - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,       # [B, H, Sq, D]
    k: jnp.ndarray,       # [B, KV, Sk, D]
    v: jnp.ndarray,       # [B, KV, Sk, D]
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    n_q, n_k = Sq // bq, Sk // bk
    grid = (B * H, n_q, n_k)
    scale = 1.0 / (D ** 0.5)

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, n_k=n_k, causal=causal,
                          scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D),
                         lambda bh, qi, kj: (bh // H, bh % H, qi, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, qi, kj: (bh // H, (bh % H) // G, kj, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda bh, qi, kj: (bh // H, (bh % H) // G, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D),
                               lambda bh, qi, kj: (bh // H, bh % H, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # running sum
            pltpu.VMEM((bq, D), jnp.float32),   # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
