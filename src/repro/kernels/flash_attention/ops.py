"""Jitted wrapper for the flash attention kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention

__all__ = ["mha"]


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def mha(q, k, v, *, causal: bool = True, block_q: int = 128,
        block_k: int = 128, interpret: bool = True):
    return flash_attention(q, k, v, causal=causal, block_q=block_q,
                           block_k=block_k, interpret=interpret)
