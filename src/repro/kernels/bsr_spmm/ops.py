"""Jitted public wrapper around the BSR SpMM Pallas kernel."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparse import BSRMatrix, bsr_from_csr
from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_fused

__all__ = ["sparse_layer_apply", "prepare_bsr_operands", "bsr_spmm"]


def prepare_bsr_operands(bsr: BSRMatrix):
    """Padded (blocks, cols) device arrays from an offline BSR matrix."""
    blocks, cols, _ = bsr.padded()
    return jnp.asarray(blocks, jnp.float32), jnp.asarray(cols, jnp.int32)


@partial(jax.jit, static_argnames=("bias", "clip", "interpret"))
def bsr_spmm(blocks, cols, x, *, bias: float, clip: float = 32.0,
             interpret: bool = True):
    return bsr_spmm_fused(blocks, cols, x, bias=bias, clip=clip,
                          interpret=interpret)


def sparse_layer_apply(bsr: BSRMatrix, x, bias: float, clip: float = 32.0,
                       interpret: bool = True):
    """One GraphChallenge layer: y = clip(relu(W·x + b), 0, clip)."""
    blocks, cols = prepare_bsr_operands(bsr)
    return bsr_spmm(blocks, cols, jnp.asarray(x, jnp.float32),
                    bias=bias, clip=clip, interpret=interpret)
