"""Jitted public wrappers around the BSR SpMM Pallas kernel.

``bsr_spmm``       — one worker-layer dispatch (jit-cached per shape/bias).
``bsr_spmm_fleet`` — the whole simulated fleet in one device dispatch: a
                     vmap over a leading worker axis of stacked padded-BSR
                     operands (see ``core.backends.PallasBsrBackend``).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.sparse import BSRMatrix
from repro.kernels.bsr_spmm.bsr_spmm import bsr_spmm_fused

__all__ = ["sparse_layer_apply", "prepare_bsr_operands", "bsr_spmm",
           "bsr_spmm_fleet"]


def prepare_bsr_operands(bsr: BSRMatrix):
    """Padded (blocks, cols) device arrays from an offline BSR matrix."""
    blocks, cols, _ = bsr.padded()
    return jnp.asarray(blocks, jnp.float32), jnp.asarray(cols, jnp.int32)


@partial(jax.jit, static_argnames=("bias", "clip", "batch_block", "interpret"))
def bsr_spmm(blocks, cols, x, *, bias: float, clip: float = 32.0,
             batch_block: int = 128, interpret: bool = True):
    return bsr_spmm_fused(blocks, cols, x, bias=bias, clip=clip,
                          batch_block=batch_block, interpret=interpret)


@partial(jax.jit, static_argnames=("bias", "clip", "batch_block", "interpret"))
def bsr_spmm_fleet(blocks, cols, x, *, bias: float, clip: float = 32.0,
                   batch_block: int = 128, interpret: bool = True):
    """Batched dispatch: blocks [P, NBR, K, bm, bn], cols [P, NBR, K],
    x [P, N, B] → y [P, NBR*bm, B].  One compile serves every layer when the
    operands are padded to fleet-global maxima."""
    return jax.vmap(
        lambda b, c, xx: bsr_spmm_fused(
            b, c, xx, bias=bias, clip=clip, batch_block=batch_block,
            interpret=interpret,
        )
    )(blocks, cols, x)


def sparse_layer_apply(bsr: BSRMatrix, x, bias: float, clip: float = 32.0,
                       interpret: bool = True):
    """One GraphChallenge layer: y = clip(relu(W·x + b), 0, clip)."""
    blocks, cols = prepare_bsr_operands(bsr)
    return bsr_spmm(blocks, cols, jnp.asarray(x, jnp.float32),
                    bias=bias, clip=clip, interpret=interpret)
