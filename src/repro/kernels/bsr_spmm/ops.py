"""Jitted public wrappers around the BSR SpMM Pallas kernel.

``bsr_spmm``       — one worker-layer dispatch (jit-cached per shape/bias).
``bsr_spmm_fleet`` — the whole simulated fleet in one device dispatch: a
                     vmap over a leading worker axis of stacked padded-BSR
                     operands (see ``core.backends.PallasBsrBackend``).
``bsr_spmm_fleet_sharded`` — the same fleet panel laid out over a device
                     mesh: ``shard_map`` splits the worker axis across the
                     mesh's ``worker`` axis and each device runs a vmap of
                     the Pallas BSR body over its block of P/D workers (the
                     PR 3 dispatch, kept as the ``dispatch="vmap"`` fallback
                     and perf baseline).
``bsr_spmm_fleet_fused``   — the fleet megakernel on one device: ONE
                     ``pallas_call`` whose grid walks every worker's row
                     blocks (worker index folded into the grid), with the
                     per-panel block counts bounding the K loop.
``bsr_spmm_fleet_fused_sharded`` — the megakernel per mesh device: shard_map
                     splits the worker axis and each device runs a single
                     fused grid over its P/D worker panels — no vmap, no XLA
                     re-entry between workers
                     (``core.backends.PallasBsrShardedBackend`` default).
"""

from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp

from repro.core.sparse import BSRMatrix
from repro.kernels.bsr_spmm.bsr_spmm import (
    bsr_spmm_fleet_megakernel,
    bsr_spmm_fused,
)

__all__ = ["sparse_layer_apply", "prepare_bsr_operands", "bsr_spmm",
           "bsr_spmm_fleet", "bsr_spmm_fleet_sharded",
           "bsr_spmm_fleet_fused", "bsr_spmm_fleet_fused_sharded"]


def prepare_bsr_operands(bsr: BSRMatrix):
    """Padded (blocks, cols) device arrays from an offline BSR matrix."""
    blocks, cols, _ = bsr.padded()
    return jnp.asarray(blocks, jnp.float32), jnp.asarray(cols, jnp.int32)


@partial(jax.jit, static_argnames=("bias", "clip", "batch_block", "interpret"))
def bsr_spmm(blocks, cols, x, *, bias: float, clip: float = 32.0,
             batch_block: int = 128, interpret: bool = True):
    return bsr_spmm_fused(blocks, cols, x, bias=bias, clip=clip,
                          batch_block=batch_block, interpret=interpret)


@partial(jax.jit, static_argnames=("bias", "clip", "batch_block", "interpret"))
def bsr_spmm_fleet(blocks, cols, x, *, bias: float, clip: float = 32.0,
                   batch_block: int = 128, interpret: bool = True):
    """Batched dispatch: blocks [P, NBR, K, bm, bn], cols [P, NBR, K],
    x [P, N, B] → y [P, NBR*bm, B].  One compile serves every layer when the
    operands are padded to fleet-global maxima."""
    return jax.vmap(
        lambda b, c, xx: bsr_spmm_fused(
            b, c, xx, bias=bias, clip=clip, batch_block=batch_block,
            interpret=interpret,
        )
    )(blocks, cols, x)


@lru_cache(maxsize=None)
def _fleet_sharded_fn(mesh, axis_name: str, bias: float, clip: float,
                      batch_block: int, interpret: bool):
    """Jit-cached shard_map dispatch for one (mesh, scalars) configuration.

    The mesh and every static knob are part of the cache key, so a fixed
    fleet layout compiles once and every layer's dispatch is a cache hit
    (the operands are padded to fleet-global maxima upstream).
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    def local(blocks, cols, x):
        # Per-device body: this device's block of P/D workers, each worker a
        # full Pallas BSR SpMM + fused epilogue.  No cross-device collectives
        # — workers are independent, exactly the paper's isolation model.
        return jax.vmap(
            lambda b, c, xx: bsr_spmm_fused(
                b, c, xx, bias=bias, clip=clip, batch_block=batch_block,
                interpret=interpret,
            )
        )(blocks, cols, x)

    spec = P(axis_name)  # shard the leading worker axis; trailing dims whole
    return jax.jit(
        shard_map_compat(local, mesh, in_specs=(spec, spec, spec),
                         out_specs=spec)
    )


def bsr_spmm_fleet_sharded(blocks, cols, x, *, mesh, axis_name: str = "worker",
                           bias: float, clip: float = 32.0,
                           batch_block: int = 128, interpret: bool = True):
    """Mesh-sharded fleet dispatch: blocks [P, NBR, K, bm, bn], cols
    [P, NBR, K], x [P, N, B] → y [P, NBR*bm, B], with P divisible by the
    mesh's ``axis_name`` size (pad with zero workers upstream otherwise).
    Each device executes the Pallas BSR body for its contiguous block of
    workers; there is no cross-device communication inside a layer."""
    fn = _fleet_sharded_fn(mesh, axis_name, float(bias), float(clip),
                           int(batch_block), bool(interpret))
    return fn(blocks, cols, x)


@partial(jax.jit, static_argnames=("bias", "clip", "batch_block", "interpret"))
def bsr_spmm_fleet_fused(blocks, cols, counts, x, *, bias: float,
                         clip: float = 32.0, batch_block: int = 128,
                         interpret: bool = True):
    """Fused fleet dispatch on one device: blocks [P, NBR, K, bm, bn], cols
    [P, NBR, K], counts i32[P, NBR], x [P, N, B] → y [P, NBR*bm, B] through a
    single ``pallas_call`` (grid = worker panels × batch panels)."""
    return bsr_spmm_fleet_megakernel(
        blocks, cols, counts, x, bias=bias, clip=clip,
        batch_block=batch_block, interpret=interpret,
    )


@lru_cache(maxsize=None)
def _fleet_fused_sharded_fn(mesh, axis_name: str, bias: float, clip: float,
                            batch_block: int, interpret: bool):
    """Jit-cached shard_map dispatch of the fleet megakernel: one fused
    Pallas grid per device instead of a vmap over that device's workers."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import shard_map_compat

    def local(blocks, cols, counts, x):
        # Per-device body: ONE pallas_call streaming this device's block of
        # P/D worker panels (worker index = leading grid dimension).  No
        # cross-device collectives — workers are independent, exactly the
        # paper's isolation model.
        return bsr_spmm_fleet_megakernel(
            blocks, cols, counts, x, bias=bias, clip=clip,
            batch_block=batch_block, interpret=interpret,
        )

    spec = P(axis_name)  # shard the leading worker axis; trailing dims whole
    return jax.jit(
        shard_map_compat(local, mesh,
                         in_specs=(spec, spec, spec, spec), out_specs=spec)
    )


def bsr_spmm_fleet_fused_sharded(blocks, cols, counts, x, *, mesh,
                                 axis_name: str = "worker", bias: float,
                                 clip: float = 32.0, batch_block: int = 128,
                                 interpret: bool = True):
    """Mesh-sharded megakernel dispatch: same operand contract as
    ``bsr_spmm_fleet_fused`` with P divisible by the mesh's ``axis_name``
    size (pad with zero workers upstream otherwise — their ``counts`` are 0
    so the K loop never touches them).  Each device executes one fused
    Pallas grid over its contiguous block of workers."""
    fn = _fleet_fused_sharded_fn(mesh, axis_name, float(bias), float(clip),
                                 int(batch_block), bool(interpret))
    return fn(blocks, cols, counts, x)


def sparse_layer_apply(bsr: BSRMatrix, x, bias: float, clip: float = 32.0,
                       interpret: bool = True):
    """One GraphChallenge layer: y = clip(relu(W·x + b), 0, clip)."""
    blocks, cols = prepare_bsr_operands(bsr)
    return bsr_spmm(blocks, cols, jnp.asarray(x, jnp.float32),
                    bias=bias, clip=clip, interpret=interpret)
