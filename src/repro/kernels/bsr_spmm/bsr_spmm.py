"""Fused BSR SpMM + bias + ReLU + clip — the GraphChallenge layer op.

Hardware adaptation (DESIGN.md §3/§7): the paper's Lambda workers run
scalar-granular CSR SpMM on CPUs; the MXU wants dense tiles, so the weight
sparsity pattern is snapped to an (bm × bn) block grid offline
(``core.sparse.bsr_from_csr``) and the kernel multiplies only the nonzero
blocks.  GraphChallenge RadiX-Net butterflies are 32-wide digit windows, so
blocks capture the structure with near-zero fill-in when bn ≤ 32·stride.

Layout (padded BSR, built offline):
  blocks  f32[NBR, K, bm, bn]   dense nonzero blocks, zero-padded to K/row
  cols    i32[NBR, K]           block-column ids (0 for padding — safe)
  x       f32[N, B]             dense activations (batch panel)
  y       f32[M, B]             y = clip(relu(Wx + bias), 0, clip)

Grid: (row-blocks, batch-panels).  The K nonzero blocks of one row-block are
staged into VMEM via the BlockSpec; x panels are sliced dynamically by block
column id (pl.ds) from the full-x VMEM block — N·bb·4B must fit VMEM, which
holds for every GraphChallenge size at bb = 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cols_ref, blocks_ref, x_ref, y_ref, *, bn: int, k_max: int,
            bias: float, clip: float):
    bm = blocks_ref.shape[2]
    bb = y_ref.shape[1]
    acc0 = jnp.zeros((bm, bb), jnp.float32)

    def body(i, acc):
        c = cols_ref[0, i]
        xb = x_ref[pl.ds(c * bn, bn), :]
        wb = blocks_ref[0, i]
        return acc + jnp.dot(wb, xb, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, k_max, body, acc0)
    y_ref[...] = jnp.clip(acc + bias, 0.0, clip)


def _fleet_kernel(counts_ref, cols_ref, blocks_ref, x_ref, y_ref, *, bn: int,
                  k_max: int, bias: float, clip: float, count_bounded: bool):
    """One (worker panel, batch-panel) cell of the fleet megakernel.

    The cell computes its worker's ENTIRE layer panel — all NBR row blocks.
    Two lowerings of the same math:

    * ``count_bounded`` (compiled TPU dispatch): nested ``fori_loop`` over
      row blocks and each row's REAL block count (``counts_ref``, the BSR
      indptr diff) with ``pl.ds`` x-panel slices — the scalar-core loops
      skip the fleet-global K padding entirely.
    * interpreter (CPU hosts): one fancy-index gather of the referenced x
      block rows plus K batched [NBR, bm, bn] × [NBR, bn, bb] matmuls
      accumulated in ascending-k order — a tiny constant-size trace that
      executes as vectorized host ops instead of thousands of per-cell
      interpreter steps, with the SAME per-block contraction and k-sum
      order as the sequential lowerings (bitwise-parity asserted against
      the vmap dispatch in ``tests/test_sharded_fleet.py``).

    Padding blocks are all-zero, so the count bound only drops exact +0.0
    terms — the two lowerings agree bitwise.
    """
    nbr, _, bm = blocks_ref.shape[1:4]
    bb = y_ref.shape[2]
    if count_bounded:
        def row(r, _):
            def body(i, acc):
                c = cols_ref[0, r, i]
                xb = x_ref[0, pl.ds(c * bn, bn), :]
                wb = blocks_ref[0, r, i]
                return acc + jnp.dot(wb, xb,
                                     preferred_element_type=jnp.float32)

            acc = jax.lax.fori_loop(0, counts_ref[0, r], body,
                                    jnp.zeros((bm, bb), jnp.float32))
            y_ref[0, pl.ds(r * bm, bm), :] = jnp.clip(acc + bias, 0.0, clip)
            return 0

        jax.lax.fori_loop(0, nbr, row, 0)
    else:
        offs = jax.lax.broadcasted_iota(jnp.int32, (nbr, k_max, bn), 2)
        idx = (cols_ref[0] * bn)[:, :, None] + offs        # [NBR, K, bn]
        xg = x_ref[0][idx.reshape(nbr, k_max * bn), :]     # [NBR·K·bn, bb]
        xg = xg.reshape(nbr, k_max, bn, bb)
        acc = jnp.zeros((nbr, bm, bb), jnp.float32)
        for i in range(k_max):  # ascending k, same accumulation order as
            acc = acc + jax.lax.dot_general(   # the sequential lowerings
                blocks_ref[0, :, i], xg[:, i],
                (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.float32)
        y_ref[0] = jnp.clip(acc.reshape(nbr * bm, bb) + bias, 0.0, clip)


def _fleet_host_lowering(blocks, cols, x, bias: float, clip: float):
    """The megakernel math for the whole device shard as straight XLA ops.

    Identical to ``_fleet_kernel``'s interpreter branch with the worker axis
    vectorized in: one fancy gather of every referenced x block row plus K
    batched matmuls accumulated in ascending-k order.  The Pallas
    interpreter pays ~1ms of staging per grid cell on CPU hosts, so the
    backends route ``interpret=True`` dispatch here; bitwise parity with
    the interpreted Pallas grid is asserted in ``tests/test_kernels.py``.
    """
    p, nbr, k_max, bm, bn = blocks.shape
    b = x.shape[2]
    offs = jax.lax.broadcasted_iota(jnp.int32, (p, nbr, k_max, bn), 3)
    idx = (cols * bn)[..., None] + offs                    # [P, NBR, K, bn]
    xg = x[jnp.arange(p)[:, None], idx.reshape(p, -1)]     # [P, NBR·K·bn, B]
    xg = xg.reshape(p, nbr, k_max, bn, b)
    acc = jnp.zeros((p, nbr, bm, b), jnp.float32)
    for i in range(k_max):  # ascending k — the sequential accumulation order
        acc = acc + jax.lax.dot_general(
            blocks[:, :, i], xg[:, :, i],
            (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)
    return jnp.clip(acc.reshape(p, nbr * bm, b) + bias, 0.0, clip)


def bsr_spmm_fleet_megakernel(
    blocks: jnp.ndarray,   # [P, NBR, K, bm, bn] — stacked worker panels
    cols: jnp.ndarray,     # [P, NBR, K] int32
    counts: jnp.ndarray,   # [P, NBR] int32 — real blocks per panel row
    x: jnp.ndarray,        # [P, N, B]
    bias: float,
    clip: float = 32.0,
    batch_block: int = 128,
    interpret: bool = True,
    force_grid: bool = False,
) -> jnp.ndarray:
    """The whole worker fleet (or one device's shard of it) in ONE
    ``pallas_call``: the grid iterates the device's blocked worker panels
    (leading grid dimension = worker index) × batch panels, and each cell
    streams its worker's full row-block set — so every panel flows through
    the kernel without re-entering XLA (or a vmap batching rule) between
    workers.

    The per-worker BSR structure arrives device-local from
    ``fleet_prepare_all``: padded ``blocks``/``cols`` panels concatenated
    along the worker axis plus ``counts`` (the per-row true block count, the
    BSR indptr diff) which bounds the compiled K loops — see
    ``_fleet_kernel`` for the two lowerings.

    ``interpret=True`` (CPU hosts) routes through
    :func:`_fleet_host_lowering` — the same math as vectorized XLA ops —
    because the Pallas interpreter's per-grid-cell staging dominates at
    fleet grid sizes; pass ``force_grid=True`` to run the interpreted
    Pallas grid itself (the parity tests do).  Returns ``y [P, NBR*bm, B]``.
    """
    p, nbr, k_max, bm, bn = blocks.shape
    p2, n, b = x.shape
    assert p == p2, (p, p2)
    if interpret and not force_grid:
        return _fleet_host_lowering(blocks, cols, x, bias, clip)
    bb = min(batch_block, b)
    assert b % bb == 0, "batch_block (clamped to batch) must divide batch"
    grid = (p, b // bb)
    return pl.pallas_call(
        functools.partial(_fleet_kernel, bn=bn, k_max=k_max, bias=bias,
                          clip=clip, count_bounded=not interpret),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, nbr), lambda w, j: (w, 0)),              # counts
            pl.BlockSpec((1, nbr, k_max), lambda w, j: (w, 0, 0)),    # cols
            pl.BlockSpec((1, nbr, k_max, bm, bn),
                         lambda w, j: (w, 0, 0, 0, 0)),               # blocks
            pl.BlockSpec((1, n, bb), lambda w, j: (w, 0, j)),         # x panel
        ],
        out_specs=pl.BlockSpec((1, nbr * bm, bb), lambda w, j: (w, 0, j)),
        out_shape=jax.ShapeDtypeStruct((p, nbr * bm, b), jnp.float32),
        interpret=interpret,
    )(counts, cols, blocks, x)


def bsr_spmm_fused(
    blocks: jnp.ndarray,   # [NBR, K, bm, bn]
    cols: jnp.ndarray,     # [NBR, K] int32
    x: jnp.ndarray,        # [N, B]
    bias: float,
    clip: float = 32.0,
    batch_block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    nbr, k_max, bm, bn = blocks.shape
    n, b = x.shape
    bb = min(batch_block, b)
    assert b % bb == 0, "batch_block (clamped to batch) must divide batch"
    grid = (nbr, b // bb)
    return pl.pallas_call(
        functools.partial(_kernel, bn=bn, k_max=k_max, bias=bias, clip=clip),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_max), lambda i, j: (i, 0)),            # cols
            pl.BlockSpec((1, k_max, bm, bn), lambda i, j: (i, 0, 0, 0)),  # blocks
            pl.BlockSpec((n, bb), lambda i, j: (0, j)),               # x panel
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nbr * bm, b), jnp.float32),
        interpret=interpret,
    )(cols, blocks, x)
