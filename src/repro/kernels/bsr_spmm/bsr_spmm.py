"""Fused BSR SpMM + bias + ReLU + clip — the GraphChallenge layer op.

Hardware adaptation (DESIGN.md §3/§7): the paper's Lambda workers run
scalar-granular CSR SpMM on CPUs; the MXU wants dense tiles, so the weight
sparsity pattern is snapped to an (bm × bn) block grid offline
(``core.sparse.bsr_from_csr``) and the kernel multiplies only the nonzero
blocks.  GraphChallenge RadiX-Net butterflies are 32-wide digit windows, so
blocks capture the structure with near-zero fill-in when bn ≤ 32·stride.

Layout (padded BSR, built offline):
  blocks  f32[NBR, K, bm, bn]   dense nonzero blocks, zero-padded to K/row
  cols    i32[NBR, K]           block-column ids (0 for padding — safe)
  x       f32[N, B]             dense activations (batch panel)
  y       f32[M, B]             y = clip(relu(Wx + bias), 0, clip)

Grid: (row-blocks, batch-panels).  The K nonzero blocks of one row-block are
staged into VMEM via the BlockSpec; x panels are sliced dynamically by block
column id (pl.ds) from the full-x VMEM block — N·bb·4B must fit VMEM, which
holds for every GraphChallenge size at bb = 128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(cols_ref, blocks_ref, x_ref, y_ref, *, bn: int, k_max: int,
            bias: float, clip: float):
    bm = blocks_ref.shape[2]
    bb = y_ref.shape[1]
    acc0 = jnp.zeros((bm, bb), jnp.float32)

    def body(i, acc):
        c = cols_ref[0, i]
        xb = x_ref[pl.ds(c * bn, bn), :]
        wb = blocks_ref[0, i]
        return acc + jnp.dot(wb, xb, preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(0, k_max, body, acc0)
    y_ref[...] = jnp.clip(acc + bias, 0.0, clip)


def bsr_spmm_fused(
    blocks: jnp.ndarray,   # [NBR, K, bm, bn]
    cols: jnp.ndarray,     # [NBR, K] int32
    x: jnp.ndarray,        # [N, B]
    bias: float,
    clip: float = 32.0,
    batch_block: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    nbr, k_max, bm, bn = blocks.shape
    n, b = x.shape
    bb = min(batch_block, b)
    assert b % bb == 0, "batch must divide batch_block"
    grid = (nbr, b // bb)
    return pl.pallas_call(
        functools.partial(_kernel, bn=bn, k_max=k_max, bias=bias, clip=clip),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k_max), lambda i, j: (i, 0)),            # cols
            pl.BlockSpec((1, k_max, bm, bn), lambda i, j: (i, 0, 0, 0)),  # blocks
            pl.BlockSpec((n, bb), lambda i, j: (0, j)),               # x panel
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nbr * bm, b), jnp.float32),
        interpret=interpret,
    )(cols, blocks, x)
