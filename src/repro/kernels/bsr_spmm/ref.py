"""Pure-jnp oracle for the fused BSR SpMM kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def bsr_to_dense(blocks: np.ndarray, cols: np.ndarray, n_cols_blocks: int) -> np.ndarray:
    """Padded BSR → dense weight matrix (numpy, test-side)."""
    nbr, k, bm, bn = blocks.shape
    out = np.zeros((nbr * bm, n_cols_blocks * bn), dtype=blocks.dtype)
    for br in range(nbr):
        for i in range(k):
            c = int(cols[br, i])
            # zero padding blocks contribute nothing regardless of c
            out[br * bm:(br + 1) * bm, c * bn:(c + 1) * bn] += blocks[br, i]
    return out


def bsr_spmm_fused_ref(blocks, cols, x, bias: float, clip: float = 32.0):
    """y = clip(relu(W @ x + bias), 0, clip) via dense reconstruction."""
    n = x.shape[0]
    bn = blocks.shape[-1]
    dense = bsr_to_dense(np.asarray(blocks, np.float32), np.asarray(cols), n // bn)
    y = jnp.asarray(dense) @ jnp.asarray(x, jnp.float32) + bias
    return jnp.clip(y, 0.0, clip)
