"""Jitted wrapper for the split-KV decode kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention

__all__ = ["decode_mha"]


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_mha(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
               interpret: bool = True):
    return decode_attention(q, k_cache, v_cache, cache_len,
                            block_k=block_k, interpret=interpret)
