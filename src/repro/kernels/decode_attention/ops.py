"""Jitted wrapper for the split-KV decode kernel.

``interpret=None`` (the default) resolves from the platform: compiled MXU
dispatch on TPU, the Pallas interpreter everywhere else.  Benchmarks and the
``pallas-splitk`` attention backend inherit the right mode instead of the old
``interpret=True`` leaking interpreter dispatch onto real hardware.

The jitted inner function is keyed on (shapes, block_k, interpret) only —
``cache_len`` is a traced operand — so a decode loop over a fixed-capacity
cache compiles once and is cache-hit on every subsequent step
(``decode_mha_cache_size`` exposes the trace count for tests).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from repro.kernels.decode_attention.decode_attention import decode_attention

__all__ = ["decode_mha", "decode_mha_cache_size", "default_interpret"]


def default_interpret() -> bool:
    """Pallas interpreter only off-TPU (compiled dispatch on real hardware)."""
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("block_k", "interpret"))
def _decode_mha_jit(q, k_cache, v_cache, cache_len, *, block_k: int,
                    interpret: bool):
    return decode_attention(q, k_cache, v_cache, cache_len,
                            block_k=block_k, interpret=interpret)


def decode_mha(q, k_cache, v_cache, cache_len, *, block_k: int = 512,
               interpret: Optional[bool] = None):
    if interpret is None:
        interpret = default_interpret()
    return _decode_mha_jit(q, k_cache, v_cache, cache_len,
                           block_k=block_k, interpret=interpret)


def decode_mha_cache_size() -> int:
    """Number of traced entries in the jit cache (retrace regression tests)."""
    return _decode_mha_jit._cache_size()
