"""Oracle for the split-KV decode kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import decode_attention as model_decode_attention


def decode_attention_ref(q, k_cache, v_cache, cache_len):
    """q [B,H,D], caches [B,KV,S,D] → (out [B,H,D], lse [B,H]).

    The model-level chunked scan consumes the kernel-native layout directly
    (PR 4), so the oracle is a straight call.
    """
    out, lse = model_decode_attention(
        q[:, None],                          # [B,1,H,D]
        k_cache,
        v_cache,
        cache_len=jnp.asarray(cache_len),
        return_lse=True,
    )
    return out[:, 0], lse[:, 0]
