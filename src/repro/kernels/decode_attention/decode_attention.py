"""Split-KV flash-decode Pallas kernel (decode_32k / long_500k serve path).

One new token attends to a long KV cache.  Grid: (batch·kv_heads, n_kv
blocks); the q vector (all G query heads of one KV head) stays in VMEM while
KV blocks stream; (m, l, acc) scratch carries the running softmax across the
sequential kv sweep; the final block normalizes and writes.

On the production mesh the cache's sequence dim is sharded: each device runs
this kernel over its LOCAL shard and the partial (out, lse) pairs combine
via the lse-weighted average (``models.attention.combine_split_kv``) — the
kernel therefore also emits the lse.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr,
            acc_scr, *, bk: int, n_k: int, scale: float):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [G, D]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # [G, bk]
    kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos < len_ref[0], s, NEG_INF)
    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)
        lse_ref[0, 0] = (m_scr[...] + jnp.log(l))[:, 0]


def decode_attention(
    q: jnp.ndarray,        # [B, H, D] — one token's query heads
    k_cache: jnp.ndarray,  # [B, KV, S, D] (local shard)
    v_cache: jnp.ndarray,  # [B, KV, S, D]
    cache_len: jnp.ndarray,  # int32 [] — valid prefix
    block_k: int = 512,
    interpret: bool | None = None,
):
    """Returns (out [B, H, D], lse [B, H]) — normalized partials + lse.

    ``interpret=None`` resolves from the platform (interpreter off-TPU only).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    bk = min(block_k, S)
    assert S % bk == 0
    n_k = S // bk
    grid = (B * KV, n_k)
    scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, KV, G, D)
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (1,))

    out, lse = pl.pallas_call(
        functools.partial(_kernel, bk=bk, n_k=n_k, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, D), lambda bh, kj: (bh // KV, bh % KV, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda bh, kj: (bh // KV, bh % KV, kj, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda bh, kj: (bh // KV, bh % KV, kj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, D), lambda bh, kj: (bh // KV, bh % KV, 0, 0)),
            pl.BlockSpec((1, 1, G), lambda bh, kj: (bh // KV, bh % KV, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KV, G, D), q.dtype),
            jax.ShapeDtypeStruct((B, KV, G), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, k_cache, v_cache)
    return out.reshape(B, H, D), lse.reshape(B, H)
