"""Oracle for the fused SSD kernel (reuses the model-side chunked SSD)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def ssd_scan_ref(x, dt, A, Bm, Cm, chunk: int = 128):
    """x [B,H,L,P], dt [B,H,L], Bm/Cm [B,G,L,N] → (y [B,H,L,P], S [B,H,P,N])."""
    y, s = ssd_chunked(
        x.transpose(0, 2, 1, 3),       # [B,L,H,P]
        dt.transpose(0, 2, 1),         # [B,L,H]
        A,
        Bm.transpose(0, 2, 1, 3),      # [B,L,G,N]
        Cm.transpose(0, 2, 1, 3),
        chunk=chunk,
    )
    return y.transpose(0, 2, 1, 3), s
