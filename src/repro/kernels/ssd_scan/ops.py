"""Jitted wrapper for the fused SSD kernel."""

from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan

__all__ = ["ssd"]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    return ssd_scan(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
