"""Fused chunked-SSD Pallas kernel (mamba2 / zamba2 backbone hot spot).

One (batch, head) slice per grid row; the last grid dim sweeps chunks
sequentially, carrying the [P, N] state in VMEM scratch — the inter-chunk
recurrence never leaves VMEM, and each chunk's O(Q²) decay/score matrix
lives only inside its grid step (the memory property the pure-JAX version
achieves with per-chunk remat).

Per chunk (Q = chunk length, P = head dim, N = state dim):
  l      = cumsum(dt·A)                       [Q]
  M      = (C Bᵀ) ⊙ exp(l_t − l_s) ⊙ causal  [Q, Q]
  y      = M (x·dt)  +  exp(l) · (C S_prev)   [Q, P]
  S_next = exp(l_Q)·S_prev + Σ_s exp(l_Q−l_s)·dt_s·B_s⊗x_s

Inputs are pre-split per head group (B/C already expanded to heads by the
wrapper's index_map: g = h // rep).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_final_ref, s_scr, *,
            n_chunks: int, q: int):
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)     # [Q]
    a = a_ref[0]                              # scalar A (negative)
    bm = b_ref[0, 0].astype(jnp.float32)      # [Q, N]
    cm = c_ref[0, 0].astype(jnp.float32)      # [Q, N]

    l = jnp.cumsum(dt * a)                    # [Q] (≤ 0, decreasing)
    # intra-chunk
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)   # [Q, Q]
    diff = jnp.clip(l[:, None] - l[None, :], -60.0, 0.0)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    m = jnp.where(t_idx >= s_idx, cb * jnp.exp(diff), 0.0)
    xdt = x * dt[:, None]
    y = jnp.dot(m, xdt, preferred_element_type=jnp.float32)      # [Q, P]
    # inter-chunk contribution from the carried state
    s_prev = s_scr[...]                       # [P, N]
    y += jnp.exp(jnp.clip(l, -60.0, 0.0))[:, None] * jnp.dot(
        cm, s_prev.T, preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update
    w = jnp.exp(jnp.clip(l[-1] - l, -60.0, 0.0)) * dt             # [Q]
    s_new = s_prev * jnp.exp(jnp.clip(l[-1], -60.0, 0.0)) + jnp.dot(
        (x * w[:, None]).T, bm, preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(cj == n_chunks - 1)
    def _finish():
        s_final_ref[0] = s_new.astype(s_final_ref.dtype)


def ssd_scan(
    x: jnp.ndarray,    # [B, H, L, P]
    dt: jnp.ndarray,   # [B, H, L]
    A: jnp.ndarray,    # [H] (negative)
    Bm: jnp.ndarray,   # [B, G, L, N]
    Cm: jnp.ndarray,   # [B, G, L, N]
    chunk: int = 128,
    interpret: bool = True,
):
    """Returns (y [B, H, L, P], final_state [B, H, P, N])."""
    B, H, L, P = x.shape
    G, N = Bm.shape[1], Bm.shape[3]
    rep = H // G
    assert L % chunk == 0
    n_chunks = L // chunk
    grid = (B * H, n_chunks)

    y, s_final = pl.pallas_call(
        functools.partial(_kernel, n_chunks=n_chunks, q=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bh, cj: (bh // H, bh % H, cj, 0)),
            pl.BlockSpec((1, 1, chunk), lambda bh, cj: (bh // H, bh % H, cj)),
            pl.BlockSpec((1,), lambda bh, cj: (bh % H,)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, cj: (bh // H, (bh % H) // rep, cj, 0)),
            pl.BlockSpec((1, 1, chunk, N),
                         lambda bh, cj: (bh // H, (bh % H) // rep, cj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda bh, cj: (bh // H, bh % H, cj, 0)),
            pl.BlockSpec((1, P, N), lambda bh, cj: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, L, P), x.dtype),
            jax.ShapeDtypeStruct((B * H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A.astype(jnp.float32), Bm, Cm)
    return y, s_final.reshape(B, H, P, N)
