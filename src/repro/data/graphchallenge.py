"""Synthetic MIT/IEEE/Amazon GraphChallenge-style sparse DNNs (paper §VI-A).

The paper evaluates on the Sparse DNN Graph Challenge [Kepner et al., HPEC'19]:
L=120 layers, N ∈ {1024, 4096, 16384, 65536} neurons per layer, 32 nonzeros
per row (RadiX-Net topologies), ReLU with per-N bias and activations clipped
at 32.  The official nets are RadiX-Net mixed-radix butterflies — *structured*
sparsity, which is what hypergraph partitioning exploits (Table III).

We generate equivalent structured nets offline: each layer's rows connect to a
32-wide "digit window" of the column index space (a radix-32 butterfly whose
window position cycles across layers), optionally perturbed with random
rewires to control structure.  ``mode="random"`` gives the unstructured
worst case.

Ground truth comes from the dense oracle (`dense_inference`), mirroring the
Graph Challenge's provided truth files: the benchmark's correctness criterion
is the set of rows with nonzero activation after the last layer.
"""

from __future__ import annotations

import dataclasses
from typing import List, Literal

import numpy as np

from repro.core.sparse import CSRMatrix, random_sparse

__all__ = [
    "GraphChallengeNet",
    "BIAS_BY_NEURONS",
    "make_sparse_dnn",
    "make_inputs",
    "dense_inference",
    "relu_bias_threshold",
]

# Paper §VI-A1: biases of -0.30, -0.35, -0.40, -0.45 for N = 1024..65536.
BIAS_BY_NEURONS = {1024: -0.30, 4096: -0.35, 16384: -0.40, 65536: -0.45}
ACTIVATION_CLIP = 32.0
NNZ_PER_ROW = 32
WEIGHT_VALUE = 1.0 / 16.0  # GraphChallenge weights are ±1/16


@dataclasses.dataclass
class GraphChallengeNet:
    neurons: int
    layers: List[CSRMatrix]
    bias: float

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def total_nnz(self) -> int:
        return sum(W.nnz for W in self.layers)

    @property
    def model_bytes(self) -> int:
        # CSR storage: 4B value + 4B col id per nnz (+ indptr, negligible)
        return self.total_nnz * 8


def _butterfly_layer(
    n: int, window_offset: int, rng: np.random.Generator, rewire_frac: float
) -> CSRMatrix:
    """Radix-32 butterfly: row i connects to the 32 columns whose index agrees
    with i outside a 5-bit window starting at ``window_offset``."""
    bits = int(np.log2(n))
    assert 2**bits == n, "GraphChallenge sizes are powers of two"
    w = min(5, bits)
    window_offset = window_offset % max(1, bits - w + 1)
    mask = ((1 << w) - 1) << window_offset
    i = np.arange(n, dtype=np.int64)[:, None]
    t = np.arange(1 << w, dtype=np.int64)[None, :]
    cols = (i & ~mask) | (t << window_offset)
    if rewire_frac > 0:
        flat = cols.reshape(-1)
        n_rewire = int(rewire_frac * flat.size)
        pos = rng.choice(flat.size, size=n_rewire, replace=False)
        flat[pos] = rng.integers(0, n, size=n_rewire)
        cols = flat.reshape(n, 1 << w)
    cols = np.sort(cols, axis=1)
    nnz = cols.shape[1]
    indptr = np.arange(n + 1, dtype=np.int64) * nnz
    # GraphChallenge synthetic DNN weights are uniform +1/16 (positive), the
    # negative bias is what prunes activations.
    data = np.full(n * nnz, WEIGHT_VALUE, dtype=np.float32)
    return CSRMatrix(
        shape=(n, n), indptr=indptr, indices=cols.reshape(-1).astype(np.int32), data=data
    )


def make_sparse_dnn(
    neurons: int,
    n_layers: int = 120,
    seed: int = 0,
    mode: Literal["radix", "random"] = "radix",
    rewire_frac: float = 0.0,
    bias: float | None = None,
) -> GraphChallengeNet:
    rng = np.random.default_rng(seed)
    if bias is None:
        bias = BIAS_BY_NEURONS.get(neurons, -0.30)
    layers: List[CSRMatrix] = []
    for k in range(n_layers):
        if mode == "radix":
            layers.append(_butterfly_layer(neurons, window_offset=k * 3, rng=rng,
                                           rewire_frac=rewire_frac))
        else:
            layers.append(
                random_sparse(neurons, neurons, NNZ_PER_ROW, rng, value_scale=WEIGHT_VALUE)
            )
    return GraphChallengeNet(neurons=neurons, layers=layers, bias=bias)


def make_inputs(neurons: int, batch: int, seed: int = 0, density: float = 0.3) -> np.ndarray:
    """Thresholded, flattened MNIST-like inputs: x^0 of shape [neurons, batch].

    The Graph Challenge scales MNIST to N pixels and thresholds to {0,1}.
    We synthesize sparse binary columns at the benchmark's typical density.
    """
    rng = np.random.default_rng(seed + 17)
    x = (rng.random((neurons, batch)) < density).astype(np.float32)
    return x


def relu_bias_threshold(z: np.ndarray, bias: float) -> np.ndarray:
    """The Graph Challenge layer epilogue: y = min(max(z + b, 0), 32)."""
    return np.minimum(np.maximum(z + bias, 0.0), ACTIVATION_CLIP)


def dense_inference(net: GraphChallengeNet, x0: np.ndarray) -> np.ndarray:
    """Oracle: dense matmul reference for the full network."""
    x = x0.astype(np.float32)
    for W in net.layers:
        z = W.matmul_dense_fast(x)
        x = relu_bias_threshold(z, net.bias)
    return x


def category_counts(x_last: np.ndarray) -> np.ndarray:
    """Graph Challenge result: rows with any nonzero activation per sample."""
    return (x_last > 0).astype(np.int64)
