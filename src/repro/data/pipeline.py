"""Deterministic, step-keyed synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` via a counter-based RNG
(Philox), so a restarted — or *elastically rescaled* — job regenerates the
exact byte-identical batch stream with zero coordination: the fault-tolerance
contract the trainer's restart test relies on.  Host-sharded loading: a host
can materialize only its slice ``batch[lo:hi]`` without generating the rest.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class PipelineSpec:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0

    def _rng(self, step: int, stream: int = 0) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=(self.seed << 16) ^ (stream << 8) ^ 0x5eed,
                             counter=step)
        )

    def batch(self, step: int, lo: int = 0, hi: Optional[int] = None
              ) -> Dict[str, np.ndarray]:
        """Global batch slice [lo:hi) for ``step`` (hi=None → full batch)."""
        B, S = self.shape.global_batch, self.shape.seq_len
        hi = B if hi is None else hi
        vocab = max(2, self.cfg.vocab_size)
        rng = self._rng(step)
        # generate the full token block then slice — Philox makes this cheap
        # and guarantees identical content regardless of host topology
        tokens = rng.integers(0, vocab, size=(B, S), dtype=np.int64)[lo:hi]
        tokens = tokens.astype(np.int32)
        out: Dict[str, np.ndarray] = {"tokens": tokens, "labels": tokens.copy()}
        if self.cfg.family == "vlm":
            frng = self._rng(step, stream=1)
            out["extra_embeds"] = frng.standard_normal(
                (B, self.cfg.frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)[lo:hi]
        if self.cfg.family == "encdec":
            frng = self._rng(step, stream=2)
            out["frames"] = frng.standard_normal(
                (B, self.cfg.frontend_tokens, self.cfg.d_model)
            ).astype(np.float32)[lo:hi]
        return out

    def device_batch(self, step: int, shardings=None) -> Dict[str, jnp.ndarray]:
        host = self.batch(step)
        if shardings is None:
            return {k: jnp.asarray(v) for k, v in host.items()}
        return {
            k: jax.device_put(v, shardings[k]) if k in shardings
            else jnp.asarray(v)
            for k, v in host.items()
        }
