from repro.data import graphchallenge  # noqa: F401
