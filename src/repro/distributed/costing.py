"""Cost extraction for the roofline analysis.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body **once**, so any
``lax.scan`` (our layer stacks, attention chunk loops, microbatch
accumulation) is wildly undercounted.  Two fixes:

* :func:`traced_flops` — walks the *jaxpr* and multiplies ``scan`` bodies by
  their trip count: ``dot_general``/``conv`` get exact MACs, elementwise ops
  get size, everything cheap is ignored.  This measures the program actually
  staged out — including remat recompute, causal-mask waste and head padding.
* :func:`collective_bytes` — parses the partitioned HLO per *computation*,
  multiplies collective operand bytes inside while bodies by the loop trip
  count (recovered from the loop condition's comparison constant), and
  accumulates from ENTRY.

Memory traffic uses :func:`analytic_hbm_bytes`: the roofline memory term is
the *minimum required* HBM movement (params + optimizer states + activation
stash + cache + IO), which is what a perfectly-fused program would do — the
HLO "bytes accessed" number is reported alongside for reference.
"""

from __future__ import annotations

import math
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.extend import core as jcore

__all__ = [
    "traced_flops", "jaxpr_flops", "collective_bytes", "analytic_hbm_bytes",
]


# ---------------------------------------------------------------------------
# jaxpr flop counting
# ---------------------------------------------------------------------------

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "pow", "neg", "abs", "sign", "floor", "ceil",
    "integer_pow", "select_n", "clamp", "erf", "cos", "sin",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "cumsum", "cumlogsumexp", "cummax", "argmax", "argmin"}


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    batch = np.prod([a.shape[i] for i in lb], initial=1.0)
    contract = np.prod([a.shape[i] for i in lc], initial=1.0)
    m = np.prod([a.shape[i] for i in range(len(a.shape))
                 if i not in set(lc) | set(lb)], initial=1.0)
    n = np.prod([b.shape[i] for i in range(len(b.shape))
                 if i not in set(rc) | set(rb)], initial=1.0)
    return 2.0 * batch * m * n * contract


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    kernel_elems = np.prod(rhs.shape, initial=1.0)
    out_elems = np.prod(out.shape, initial=1.0)
    # per output element: one MAC per kernel element / out-channel share
    feature_group = eqn.params.get("feature_group_count", 1)
    return 2.0 * out_elems * kernel_elems / max(
        1, rhs.shape[-1] if len(rhs.shape) else 1) / feature_group


def _sub_jaxprs(params):
    """Every jaxpr-valued entry of an eqn's params (generic recursion)."""
    for v in params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jcore.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, jcore.Jaxpr):
                    yield item


def jaxpr_flops(jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim == "conv_general_dilated":
            total += _conv_flops(eqn)
        elif prim == "scan":
            inner = jaxpr_flops(eqn.params["jaxpr"].jaxpr)
            total += inner * eqn.params["length"]
        elif prim == "cond":
            total += max(
                (jaxpr_flops(b.jaxpr) for b in eqn.params["branches"]),
                default=0.0,
            )
        elif prim in _ELEMENTWISE or prim == "add_any":
            total += float(np.prod(eqn.outvars[0].aval.shape, initial=1.0))
        elif prim in _REDUCE:
            total += float(np.prod(eqn.invars[0].aval.shape, initial=1.0))
        elif prim == "shard_map":
            # the body is the PER-DEVICE program: multiply by the mesh size
            # to keep the total in global-FLOP units
            mesh = eqn.params.get("mesh")
            n = int(getattr(mesh, "size", 1) or 1)
            for sub in _sub_jaxprs(eqn.params):
                total += n * jaxpr_flops(sub)
        else:
            # generic: recurse into any nested jaxpr (jit, remat2,
            # closed_call, custom_vjp, while bodies, …); multiplier 1 —
            # while is unused by our models (everything is lax.scan)
            for sub in _sub_jaxprs(eqn.params):
                total += jaxpr_flops(sub)
    return total


def traced_flops(fn, *args, **kwargs) -> float:
    """Global (unpartitioned) FLOPs of ``fn(*args)`` via jaxpr walk."""
    jx = jax.make_jaxpr(fn)(*args, **kwargs)
    return jaxpr_flops(jx.jaxpr)


# ---------------------------------------------------------------------------
# HLO collective parsing with while-trip-count multiplication
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s8": 1, "u8": 1, "pred": 1, "s16": 2,
                "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COMP_HEADER = re.compile(r"^(?:ENTRY )?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->.*\{\s*$")
_COLL = re.compile(
    r"\s(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(", re.IGNORECASE)
_SHAPE = re.compile(r"(bf16|f16|f32|f64|s64|u64|s32|u32|s16|u16|s8|u8|pred|f8\w*)"
                    r"\[([0-9,]*)\]")
_CALLS = re.compile(
    r"(?:body|condition|branch_computations|to_apply|called_computations|"
    r"calls)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")
_WHILE = re.compile(r"while\(.*?\), condition=%?([\w\.\-]+), body=%?([\w\.\-]+)")
_CONST = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> Dict[str, str]:
    """Computation headers are unindented lines ending in '{'; bodies are
    indented; '}' at indent 0 (or 'ROOT'-style '} // ...') closes them."""
    comps: Dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        stripped = line.strip()
        if cur_name is None:
            if line and not line[0].isspace() and stripped.endswith("{"):
                first = stripped.split()[0]
                if first == "ENTRY":
                    first = stripped.split()[1]
                name = first.lstrip("%").split("(")[0].split(".{")[0]
                if name and name != "HloModule":
                    cur_name = name
                    cur_lines = [line]
                    if "ENTRY" in stripped:
                        cur_lines[0] = "ENTRY " + line
        else:
            cur_lines.append(line)
            if stripped == "}" or stripped.startswith("} "):
                comps[cur_name] = "\n".join(cur_lines)
                cur_name = None
    return comps


_GROUPS = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _line_collective_bytes(line: str) -> float:
    """On-wire bytes per device for one collective op (ring algorithms).

    Scheduled HLO annotates shapes on the *result* only.  With result size S
    and group size g:
      all-reduce      2·S·(g-1)/g     (reduce-scatter + all-gather ring)
      all-gather      S·(g-1)/g       (S = gathered result)
      reduce-scatter  S·(g-1)         (input = S·g)
      all-to-all      S·(g-1)/g
      collective-permute  S
    """
    m = _COLL.search(line)
    if m is None or "-done" in line.split("=")[0]:
        return 0.0
    kind = m.group(1).lower()
    lhs = line.split(" = ", 1)
    if len(lhs) < 2:
        return 0.0
    # result may be a tuple — sum every shape before the op name
    result_region = lhs[1][: lhs[1].lower().index(kind)]
    size = 0.0
    for sm in _SHAPE.finditer(result_region):
        dt, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        size += n * _DTYPE_BYTES.get(dt, 2)
    gm = _GROUPS.search(line)
    g = int(gm.group(2)) if gm else 2
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * size * (g - 1) / g
    if kind == "all-gather":
        return size * (g - 1) / g
    if kind == "reduce-scatter":
        return size * (g - 1)
    if kind == "all-to-all":
        return size * (g - 1) / g
    return size  # collective-permute


def collective_bytes(hlo: str) -> Tuple[Dict[str, float], float]:
    """(per-kind bytes, total) with while-body multiplication.

    Bytes are per-device (the partitioned HLO's shapes are shard shapes).
    """
    comps = _split_computations(hlo)

    def trip_count(cond_name: str) -> int:
        cond = comps.get(cond_name, "")
        consts = [int(c) for c in _CONST.findall(cond)]
        return max(consts) if consts else 1

    # direct bytes + child edges per computation
    direct: Dict[str, Dict[str, float]] = {}
    edges: Dict[str, list] = {}
    for name, text in comps.items():
        d: Dict[str, float] = {}
        es = []
        for line in text.splitlines():
            cm = _COLL.search(line)
            if cm and "-done" not in line.split("=")[0]:
                kind = cm.group(1).lower()
                d[kind] = d.get(kind, 0.0) + _line_collective_bytes(line)
            wm = _WHILE.search(line)
            if wm:
                es.append((wm.group(2), trip_count(wm.group(1))))
                continue
            for call in _CALLS.finditer(line):
                for callee in re.split(r",\s*%?", call.group(1)):
                    callee = callee.strip().lstrip("%")
                    if callee and callee in comps:
                        es.append((callee, 1))
        direct[name] = d
        edges[name] = es

    entry = None
    for name in comps:
        if "ENTRY" in comps[name].splitlines()[0]:
            entry = name
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {}, 0.0

    memo: Dict[str, Dict[str, float]] = {}

    def accumulate(name: str, depth=0) -> Dict[str, float]:
        if name in memo:
            return memo[name]
        if depth > 50:
            return {}
        out = dict(direct.get(name, {}))
        for callee, mult in edges.get(name, []):
            child = accumulate(callee, depth + 1)
            for k, v in child.items():
                out[k] = out.get(k, 0.0) + v * mult
        memo[name] = out
        return out

    per_kind = accumulate(entry)
    return per_kind, float(sum(per_kind.values()))


# ---------------------------------------------------------------------------
# analytic minimal HBM traffic (roofline memory term)
# ---------------------------------------------------------------------------


def analytic_hbm_bytes(
    *, param_bytes_dev: float, opt_bytes_dev: float, stash_bytes_dev: float,
    cache_bytes_dev: float, io_bytes_dev: float, kind: str,
) -> float:
    """Minimum HBM movement per step per device for a perfectly-fused program.

    train:   params read (fwd+bwd) + grads written+read + opt r/w + stash w+r
    prefill: params read + cache written + io
    decode:  params read + cache read(+append) + io
    """
    if kind == "train":
        return (3 * param_bytes_dev          # fwd read + bwd read + write back
                + 2 * param_bytes_dev        # grads write + read
                + 2 * opt_bytes_dev          # opt states read + write
                + 2 * stash_bytes_dev        # stash write + re-read
                + io_bytes_dev)
    if kind == "prefill":
        return param_bytes_dev + cache_bytes_dev + 2 * stash_bytes_dev + io_bytes_dev
    return param_bytes_dev + cache_bytes_dev + io_bytes_dev
