"""Sharding rules: params, batches and caches → PartitionSpecs.

Parameter rules are *name + trailing-dims* based: each parameter name maps to
a spec for its trailing semantic dims, and any extra leading dims (the
stacked-layer axis, zamba's [group, layer] axes) get ``None`` — so one table
covers every family.

Policies (DESIGN.md §6):

* weights: TP over ``model`` (heads / ffn / experts / ssd-heads); optional
  FSDP shards the non-TP dim over ``data`` (``cfg_fsdp=True`` for the models
  whose optimizer+grads exceed HBM otherwise);
* GQA with ``n_kv_heads`` not divisible by the model axis: KV projections
  stay replicated on the head dim (they are small) — scores still shard over
  Q heads;
* train/prefill activations: batch over ``(pod, data)``;
* decode KV cache: batch over dp axes when divisible, **sequence over
  model** (split-KV decode); long_500k (batch=1) puts sequence over
  (data, model) — 512k/512 = 1k per chip.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import MeshAxes

PyTree = Any


def shard_map_compat(body, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions: new API (``check_vma``),
    pre-0.6 top-level API (``check_rep``), or the experimental module."""
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_vma=False)
        except TypeError:
            return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, check_rep=False)
    from jax.experimental.shard_map import shard_map

    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def _tail_rules(cfg: ModelConfig, ax: MeshAxes, fsdp: bool):
    """name → trailing-dims spec (entries may be None / axis name / tuple)."""
    m = ax.model
    f = ax.dp if (fsdp and ax.dp) else None
    kv_ok = m is not None and cfg.n_kv_heads and (
        _padded_kv_heads(cfg) % ax.model_size == 0
    )
    heads_ok = m is not None and cfg.n_heads and (
        _padded_heads(cfg) % ax.model_size == 0
    )
    hm = m if heads_ok else None
    km = m if kv_ok else None
    return {
        # attention
        "wq": (f, hm, None),
        "wk": (f, km, None),
        "wv": (f, km, None),
        "wo@3": (hm, None, f),          # attn out-proj [H, dh, D]
        "bq": (hm, None),
        "bk": (km, None),
        "bv": (km, None),
        # mlp
        "wi_gate": (f, m),
        "wi_up": (f, m),
        "wo@2": (m, f),                 # mlp out-proj [F, D]
        # embeddings (vocab-sharded; a d_model-sharded variant was explored
        # in §Perf iteration 4 — better temp, worse collectives — and is
        # selectable by editing this rule)
        "embed": (m, f),
        "unembed": (m, f),
        # moe
        "router": (f, None),
        "w_gate": (m, f, None),
        "w_up": (m, f, None),
        "w_down": (m, None, f),
        # mamba2
        "in_z": (f, m),
        "in_x": (f, m),
        "in_B": (f, None),
        "in_C": (f, None),
        "in_dt": (f, None),
        "conv_x_w": (None, m),
        "conv_x_b": (m,),
        "conv_B_w": (None, None),
        "conv_B_b": (None,),
        "conv_C_w": (None, None),
        "conv_C_b": (None,),
        "A_log": (m,),
        "dt_bias": (m,),
        "D": (m,),
        "norm": (m,),                   # mamba RMSNorm over d_inner
        "out_proj": (m, f),
    }


def _padded_heads(cfg: ModelConfig) -> int:
    return cfg.eff_heads


def _padded_kv_heads(cfg: ModelConfig) -> int:
    return cfg.eff_kv_heads


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
    return ""


def zero_param_pspecs(cfg: ModelConfig, params_shape: PyTree,
                      ax: MeshAxes) -> PyTree:
    """ZeRO-3 / pure-DP strategy (the §Perf beyond-paper optimization):

    the batch shards over *every* mesh axis and parameters shard on their
    first divisible dim over the whole mesh — XLA all-gathers a layer's
    weights just-in-time and reduce-scatters its grads, so the per-step
    collective volume is O(params) instead of O(activations·layers), which
    wins whenever the model is small relative to the token batch.
    """
    all_axes = tuple(ax.dp) + ((ax.model,) if ax.model else ())
    # leading dims of scan-stacked parameter trees are the layer axis — the
    # lax.scan slices one layer per step, so sharding that dim would force a
    # full re-gather every iteration (measured: 5-15× collective blow-up;
    # EXPERIMENTS.md §Perf iteration 1)
    stacked_keys = {"blocks", "moe_blocks", "dense_blocks", "enc_blocks",
                    "dec_blocks", "tail"}

    def spec_for(path, leaf):
        shape = leaf.shape
        names = {str(e.key) for e in path
                 if isinstance(e, jax.tree_util.DictKey)}
        skip = 0
        if names & stacked_keys:
            skip = 1
        if "groups" in names:       # zamba: [group, layer, ...]
            skip = 2
        if not shape or max(shape) < 1024:   # tiny tensors stay replicated
            return P(*([None] * len(shape)))
        spec = [None] * len(shape)
        # shard the largest divisible non-stacked dim over the whole mesh
        order = sorted(range(skip, len(shape)), key=lambda i: -shape[i])
        for i in order:
            keep = _divisible_prefix(all_axes, shape[i], ax)
            if keep and len(keep) == len(all_axes):
                spec[i] = keep if len(keep) > 1 else keep[0]
                break
        else:
            for i in order:
                keep = _divisible_prefix(all_axes, shape[i], ax)
                if keep:
                    spec[i] = keep if len(keep) > 1 else keep[0]
                    break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def param_pspecs(cfg: ModelConfig, params_shape: PyTree, ax: MeshAxes,
                 fsdp: bool = False, strategy: str = "tp") -> PyTree:
    """PartitionSpec tree matching ``params_shape`` (a ShapeDtypeStruct tree).

    ``strategy="tp"`` — the baseline: tensor parallelism over ``model``
    (+ optional FSDP on the non-TP dim).  ``strategy="zero"`` — ZeRO-3 pure
    DP (see :func:`zero_param_pspecs`).

    ``ssm-heads over model`` requires divisibility; when it fails (reduced
    smoke configs on 1 device) everything degrades to replication because
    mesh axes are absent.
    """
    if strategy == "zero":
        return zero_param_pspecs(cfg, params_shape, ax)
    rules = _tail_rules(cfg, ax, fsdp)
    mamba_head_ok = ax.model is None or not cfg.ssm_heads or (
        cfg.ssm_heads % ax.model_size == 0
    )
    inner_ok = ax.model is None or not cfg.ssm_heads or (
        cfg.d_inner % ax.model_size == 0
    )

    def spec_for(path, leaf):
        name = _leaf_name(path)
        ndim = len(leaf.shape)
        key = name
        if name == "wo":
            key = f"wo@{min(ndim, 3) if ndim >= 3 else 2}"
            # stacked blocks add leading dims; attn wo tail is 3 dims,
            # mlp wo tail is 2 — disambiguate via trailing size heuristic:
            # attn wo trailing dims are [H, dh, D]; mlp wo is [F, D].
            key = "wo@3" if _looks_like_attn_wo(cfg, leaf.shape) else "wo@2"
        tail = rules.get(key)
        if tail is None:
            return P()
        # drop model-axis sharding for ssm tensors when heads don't divide
        if name in ("A_log", "dt_bias", "D") and not mamba_head_ok:
            tail = (None,) * len(tail)
        if name in ("in_z", "in_x", "conv_x_w", "conv_x_b", "norm",
                    "out_proj") and not inner_ok:
            tail = tuple(a if a != ax.model else None for a in tail)
        if len(tail) > ndim:
            tail = tail[-ndim:]
        spec = (None,) * (ndim - len(tail)) + tuple(tail)
        # never try to shard a dim the axis size doesn't divide
        spec = _drop_indivisible(spec, leaf.shape, ax)
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def _looks_like_attn_wo(cfg: ModelConfig, shape) -> bool:
    if len(shape) < 3:
        return False
    h, dh, d = shape[-3:]
    return dh == cfg.d_head and d == cfg.d_model


def _drop_indivisible(spec, shape, ax: MeshAxes):
    out = []
    for s, dim in zip(spec, shape):
        if s is None:
            out.append(None)
            continue
        size = ax.axis_size(s)
        out.append(s if size and dim % size == 0 else None)
    return tuple(out)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------


def _dp_for(batch: int, ax: MeshAxes) -> Optional[Tuple[str, ...]]:
    """Largest prefix of dp axes whose product divides the batch."""
    dims: Tuple[str, ...] = ()
    prod = 1
    for a in ax.dp:
        if batch % (prod * ax.axis_size(a)) == 0:
            dims = dims + (a,)
            prod *= ax.axis_size(a)
    return dims if dims else None


def batch_pspecs(cfg: ModelConfig, shape: ShapeConfig, batch_tree: PyTree,
                 ax: MeshAxes) -> PyTree:
    dp = _dp_for(shape.global_batch, ax)

    def spec_for(path, leaf):
        ndim = len(leaf.shape)
        return P(dp, *([None] * (ndim - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_tree)


def cache_pspecs(cfg: ModelConfig, shape: ShapeConfig, cache_tree: PyTree,
                 ax: MeshAxes) -> PyTree:
    """Decode-cache specs: mirror registry.cache_specs structurally.

    KV arrays [..., B, KV, S, dh] (kernel-native layout): B→dp, S→model
    (+leftover dp when B=1) — the split-KV decode sharding.
    SSM states [..., B, H, P, N]: H→model when divisible.
    Conv tails [..., B, K-1, C]: C→model for the x-conv when divisible.
    """
    B = shape.global_batch
    dp = _dp_for(B, ax)
    used_dp = set(dp or ())
    free_dp = tuple(a for a in ax.dp if a not in used_dp)
    seq_axes: Tuple[str, ...] = tuple(free_dp) + ((ax.model,) if ax.model else ())

    def kv_spec(leaf, s_dim_size):
        ndim = len(leaf.shape)
        # [..., B, KV, S, dh]
        lead = ndim - 4
        seq = _divisible_prefix(seq_axes, s_dim_size, ax)
        return P(*([None] * lead), dp, None, seq if seq else None, None)

    def ssm_spec(leaf):
        ndim = len(leaf.shape)
        # [..., B, H, P, N]
        lead = ndim - 4
        h = leaf.shape[-3]
        m = ax.model if ax.model and h % ax.model_size == 0 else None
        return P(*([None] * lead), dp, m, None, None)

    def conv_spec(leaf):
        ndim = len(leaf.shape)
        # [..., B, K-1, C]
        lead = ndim - 3
        c = leaf.shape[-1]
        m = ax.model if ax.model and c % ax.model_size == 0 else None
        return P(*([None] * lead), dp, None, m)

    def spec_for(path, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return P()
        names = [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        ndim = len(leaf.shape)
        if name in ("k", "v", "kc", "vc") or (
            "kv" in names and ndim >= 4
        ) or ("tail_kv" in names and ndim >= 4):
            return kv_spec(leaf, leaf.shape[-2])
        if name == "ssm" or ("states" in names and ndim >= 4 and
                             leaf.shape[-1] == cfg.ssm_state):
            return ssm_spec(leaf)
        if name in ("x", "B", "C") or "conv" in names:
            return conv_spec(leaf)
        if "tail_state" in names:
            return ssm_spec(leaf) if leaf.shape[-1] == cfg.ssm_state else conv_spec(leaf)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def _divisible_prefix(axes: Tuple[str, ...], dim: int, ax: MeshAxes):
    out: Tuple[str, ...] = ()
    prod = 1
    for a in axes:
        if dim % (prod * ax.axis_size(a)) == 0:
            out = out + (a,)
            prod *= ax.axis_size(a)
    return out


def to_named(mesh, spec_tree: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
