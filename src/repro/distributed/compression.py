"""On-wire gradient compression with error feedback.

The paper compresses every inter-worker payload with zlib (§IV-B); the TPU
analogue is low-precision collectives.  ``Int8Compressor`` quantizes
gradients to int8 with a per-tensor scale before the data-parallel reduction
and keeps the quantization residual in an *error-feedback* buffer that is
added back next step — the standard convergence-preserving trick (1-bit
Adam / EF-SGD lineage).

``compressed_psum`` is the shard_map building block: quantize → psum int32 →
dequantize, cutting DP all-reduce bytes 4× vs fp32 (2× vs bf16).  The
trainer exposes it via ``compress_grads=True``; tests verify (a) the wire
payload is int8-sized, (b) error feedback keeps a toy model's convergence
within tolerance of the fp32 run.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization → (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


@jax.tree_util.register_pytree_node_class
class _Quantized:
    """(int8 payload, fp32 scale) leaf container — a proper pytree node so
    it can flow through jit/scan boundaries."""

    def __init__(self, q, scale):
        self.q = q
        self.scale = scale

    def tree_flatten(self):
        return (self.q, self.scale), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


class Int8Compressor:
    """Error-feedback int8 compression over a gradient pytree."""

    def init(self, params: PyTree) -> PyTree:
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress(self, grads: PyTree, error: PyTree):
        """Returns (quantized pytree with (q, scale) at leaf positions,
        new error buffers)."""
        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = jax.tree_util.tree_leaves(error)
        quant_leaves, err_leaves = [], []
        for g, e in zip(flat_g, flat_e):
            target = g.astype(jnp.float32) + e
            q, s = quantize_int8(target)
            recon = dequantize_int8(q, s)
            quant_leaves.append(_Quantized(q, s))
            err_leaves.append(target - recon)
        return (jax.tree_util.tree_unflatten(treedef, quant_leaves),
                jax.tree_util.tree_unflatten(treedef, err_leaves))

    @staticmethod
    def decompress(quant: PyTree) -> PyTree:
        return jax.tree.map(
            lambda t: dequantize_int8(t.q, t.scale),
            quant,
            is_leaf=lambda x: isinstance(x, _Quantized),
        )

    @staticmethod
    def wire_bytes(grads: PyTree) -> Tuple[int, int]:
        """(fp32 bytes, int8 bytes) the DP reduction would move."""
        fp32 = sum(x.size * 4 for x in jax.tree.leaves(grads))
        int8 = sum(x.size * 1 + 4 for x in jax.tree.leaves(grads))
        return fp32, int8


def compressed_psum(g: jnp.ndarray, axis_name) -> jnp.ndarray:
    """shard_map building block: int8-quantized all-reduce.

    Each shard quantizes with its own scale; scales are maxed across the
    axis so the int32 accumulation is exact for the shared scale.
    """
    x32 = g.astype(jnp.float32)
    local_scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale
