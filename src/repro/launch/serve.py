"""Serving launcher (reduced configs on CPU; full configs via dry-run).

    PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
        --batch 4 --prompt-len 16 --max-new 8
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.serving.engine import ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--full-size", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    engine = ServingEngine(cfg, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(args.batch, args.prompt_len)).astype(np.int32)
    extra = {}
    if cfg.family == "vlm":
        extra["extra_embeds"] = rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
    if cfg.family == "encdec":
        extra["frames"] = rng.standard_normal(
            (args.batch, cfg.frontend_tokens, cfg.d_model)).astype(np.float32)
    result = engine.generate(prompts, max_new_tokens=args.max_new, extra=extra)
    print(f"[{args.arch}] generated {result.tokens.shape} tokens:")
    print(result.tokens)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
