"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count=512`` before first jax init; smoke
tests and benches see the 1 real CPU device.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "make_worker_mesh",
           "MeshAxes", "mesh_axes_of"]


def _mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    if hasattr(jax.sharding, "AxisType"):  # jax ≥ 0.5: explicit axis types
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh_compat(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small CPU meshes)."""
    return _mesh_compat(shape, axes)


def make_worker_mesh(n_devices: Optional[int] = None,
                     axis_name: str = "worker"):
    """1-D ``(worker,)`` mesh for the mesh-sharded FSI fleet backend
    (``pallas-bsr-sharded``): one mesh axis carrying the simulated-Lambda
    dimension, sized to the host's devices by default.  Tests get >1 CPU
    devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set
    before the first jax init."""
    n = n_devices or len(jax.devices())
    return _mesh_compat((n,), (axis_name,))


class MeshAxes:
    """Resolved axis names for a mesh: which axes carry data vs model.

    ``as_pure_dp()`` reinterprets the whole mesh as data-parallel (the ZeRO
    strategy): every axis carries batch, no TP axis.
    """

    def __init__(self, mesh):
        names = mesh.axis_names
        self.model: Optional[str] = "model" if "model" in names else None
        dp = tuple(n for n in names if n in ("pod", "data"))
        self.dp: Tuple[str, ...] = dp
        self.mesh = mesh

    def as_pure_dp(self) -> "MeshAxes":
        out = MeshAxes(self.mesh)
        out.dp = tuple(self.mesh.axis_names)
        out.model = None
        return out

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            return int(np.prod([self.axis_size(n) for n in name]))
        return self.mesh.shape[name]

    @property
    def dp_size(self) -> int:
        return self.axis_size(self.dp) if self.dp else 1

    @property
    def model_size(self) -> int:
        return self.axis_size(self.model)


def mesh_axes_of(mesh) -> MeshAxes:
    return MeshAxes(mesh)
