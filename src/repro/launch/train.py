"""Training launcher.

CPU-scale end-to-end run (reduced config by default):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Full-size configs are exercised through the dry-run (``repro.launch.dryrun``)
— this driver is the runnable example path (deliverable b).
"""

from __future__ import annotations

import argparse
import json

from repro.configs import ShapeConfig, get_config
from repro.training.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--full-size", action="store_true",
                    help="use the full public config (needs a real cluster)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    shape = ShapeConfig("cli", seq_len=args.seq, global_batch=args.batch,
                        kind="train")
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, base_lr=args.lr,
        microbatches=args.microbatches, compress_grads=args.compress_grads,
    )
    trainer = Trainer(cfg, shape, tcfg, seed=args.seed)
    history = trainer.fit()
    first, last = history["loss"][0], history["loss"][-1]
    print(f"[{args.arch}] steps={len(history['loss'])} "
          f"loss {first:.4f} → {last:.4f}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(history, f)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
