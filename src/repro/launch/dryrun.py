import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver:

1. builds the production mesh (16×16 single-pod, or 2×16×16 multi-pod);
2. derives parameter / optimizer / batch / cache PartitionSpecs;
3. ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` — nothing is ever
   allocated; success proves the sharding config is coherent end-to-end;
4. prints ``memory_analysis()`` (fits-in-HBM evidence) and
   ``cost_analysis()`` (FLOPs/bytes), and parses the compiled HLO for
   collective operand bytes;
5. emits the three roofline terms for EXPERIMENTS.md §Roofline.

Usage:
    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] --json out.json
"""

import argparse
import dataclasses
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_config, get_shape, list_archs
from repro.core.cost_model import TPU_V5E
from repro.distributed.costing import (
    analytic_hbm_bytes,
    collective_bytes,
    traced_flops,
)
from repro.distributed.sharding import (
    batch_pspecs,
    cache_pspecs,
    param_pspecs,
    to_named,
)
from repro.launch.mesh import MeshAxes, make_production_mesh
from repro.models.registry import cache_specs, get_model, input_specs
from repro.training.optimizer import get_optimizer
from repro.training.train_state import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P


def tree_bytes(tree) -> float:
    return float(sum(
        np.prod(l.shape, initial=1.0) * np.dtype(l.dtype).itemsize
        for l in jax.tree.leaves(tree)
        if hasattr(l, "shape")
    ))

# archs whose quadratic attention rules out the 512k decode cell (the shape
# sheet's own rule); recorded as SKIP in the sweep output.
LONG_CONTEXT_ARCHS = ("zamba2-7b", "mamba2-370m")

# Gradient-accumulation microbatch counts per train cell.  Measured finding
# (EXPERIMENTS.md §Perf): XLA's wide-loop buffer assignment keeps every
# microbatch's remat stash live simultaneously on this backend, so
# microbatching *increases* temp memory — default is therefore 1, and the
# hillclimb explores per-device batch via the pod axis instead.
TRAIN_MICROBATCHES = {}

@dataclasses.dataclass
class CellReport:
    arch: str
    shape: str
    mesh: str
    status: str                      # ok | skip | error
    note: str = ""
    compile_s: float = 0.0
    flops_per_device: float = 0.0    # jaxpr-traced, global / n_dev
    hbm_bytes_per_device: float = 0.0  # analytic minimal traffic
    hlo_flops_per_device: float = 0.0  # raw XLA number (while bodies ×1)
    hlo_bytes_per_device: float = 0.0
    collective_bytes: Optional[Dict[str, float]] = None
    collective_total: float = 0.0
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    compute_term_s: float = 0.0
    memory_term_s: float = 0.0
    collective_term_s: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    model_flops_ratio: float = 0.0
    fits_hbm: bool = True

    def to_dict(self):
        return dataclasses.asdict(self)


def _should_skip(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return ("full-attention arch: 512k decode requires sub-quadratic "
                "attention (shape-sheet rule; DESIGN.md §5)")
    return None


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for train, 2·N·D for inference (N = active params)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_cell(arch: str, shape_name: str, mesh, strategy: str = "tp") -> Any:
    """Returns (jitted_fn, raw_fn, args tuple of ShapeDtypeStructs, aux).

    ``strategy``: "tp" (baseline) or "zero" (§Perf ZeRO-3 pure-DP)."""
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    ax = MeshAxes(mesh)
    n_dev = mesh.size
    model = get_model(cfg)
    fsdp = cfg.param_count() * 2 > 8e9  # params above ~8GB must shard 2D
    pshape = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = param_pspecs(cfg, pshape, ax, fsdp=fsdp, strategy=strategy)
    if strategy == "zero":
        from repro.models import layers as _L

        ax = ax.as_pure_dp()        # batch over every axis; no TP axis
        _L.set_shard_ctx(mesh, ax.dp, None)
    if strategy == "bf16coll":
        from repro.models import layers as _L
        import jax.numpy as _jnp

        _L.set_tp_psum_dtype(_jnp.bfloat16)
    else:
        from repro.models import layers as _L
        import jax.numpy as _jnp

        _L.set_tp_psum_dtype(_jnp.float32)
    from repro.models import moe as _moe

    _moe.set_moe_ep_shardmap(strategy == "ep")
    param_bytes_dev = tree_bytes(pshape) / n_dev
    tokens_dev = shape.tokens / n_dev

    if shape.kind == "train":
        opt = get_optimizer(cfg)
        oshape = jax.eval_shape(opt.init, pshape)
        ospecs = opt.state_pspecs(pspecs, pshape)
        batch = input_specs(cfg, shape, abstract=True)
        bspecs = batch_pspecs(cfg, shape, batch, ax)
        mb = TRAIN_MICROBATCHES.get(arch, 1)
        if cfg.family == "moe":
            loss = lambda p, b: model.loss_fn(p, b, dp_groups=ax.dp_size)
        else:
            loss = model.loss_fn
        step = make_train_step(loss, opt, microbatches=mb,
                               grad_shardings=to_named(mesh, pspecs))
        jf = jax.jit(
            step,
            in_shardings=(to_named(mesh, pspecs), to_named(mesh, ospecs),
                          to_named(mesh, bspecs)),
            donate_argnums=(0, 1),
        )
        n_blocks = cfg.n_layers + cfg.n_encoder_layers
        aux = {
            "param_bytes_dev": param_bytes_dev,
            "opt_bytes_dev": tree_bytes(oshape) / n_dev,
            "stash_bytes_dev": n_blocks * tokens_dev * cfg.d_model * 2.0,
            "cache_bytes_dev": 0.0,
            "io_bytes_dev": tree_bytes(batch) / n_dev,
        }
        return jf, step, (pshape, oshape, batch), aux

    if shape.kind == "prefill":
        batch = input_specs(cfg, shape, abstract=True)
        bspecs = batch_pspecs(cfg, shape, batch, ax)
        max_len = shape.seq_len  # cache capacity = prompt length here
        if cfg.family == "moe":
            fn = lambda p, b: model.prefill(p, b, max_len, dp_groups=ax.dp_size)
        else:
            fn = lambda p, b: model.prefill(p, b, max_len)
        cache_shape = cache_specs(cfg, shape, abstract=True)
        cspecs = cache_pspecs(cfg, shape, cache_shape, ax)
        _, cache_struct = jax.eval_shape(fn, pshape, batch)
        cspecs_aligned = _align_specs(cache_struct, cspecs, cfg, shape, ax)
        jf = jax.jit(
            fn,
            in_shardings=(to_named(mesh, pspecs), to_named(mesh, bspecs)),
            out_shardings=(None, to_named(mesh, cspecs_aligned)),
        )
        aux = {
            "param_bytes_dev": param_bytes_dev,
            "opt_bytes_dev": 0.0,
            "stash_bytes_dev": 2 * tokens_dev * cfg.d_model * 2.0,
            "cache_bytes_dev": tree_bytes(cache_struct) / n_dev,
            "io_bytes_dev": tree_bytes(batch) / n_dev,
        }
        return jf, fn, (pshape, batch), aux

    # decode
    batch = input_specs(cfg, shape, abstract=True)
    cache = cache_specs(cfg, shape, abstract=True)
    cspecs = cache_pspecs(cfg, shape, cache, ax)
    tok_spec = batch_pspecs(cfg, shape, batch, ax)
    if cfg.family == "moe":
        fn = lambda p, t, c: model.decode_step(p, t, c, dp_groups=1)
    else:
        fn = model.decode_step
    jf = jax.jit(
        fn,
        in_shardings=(to_named(mesh, pspecs), to_named(mesh, tok_spec["token"]),
                      to_named(mesh, cspecs)),
        out_shardings=(None, to_named(mesh, cspecs)),
        donate_argnums=(2,),
    )
    aux = {
        "param_bytes_dev": param_bytes_dev,
        "opt_bytes_dev": 0.0,
        "stash_bytes_dev": 0.0,
        "cache_bytes_dev": tree_bytes(cache) / n_dev,
        "io_bytes_dev": tree_bytes(batch) / n_dev,
    }
    return jf, fn, (pshape, batch["token"], cache), aux


def _align_specs(struct, spec_tree, cfg, shape, ax):
    """Prefill cache structure may differ from registry.cache_specs (it *is*
    the same by construction); fall back to replicated for any mismatch."""
    try:
        jax.tree.map(lambda a, b: None, struct, spec_tree)
        return spec_tree
    except Exception:
        return jax.tree.map(lambda _: P(), struct)


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             mesh=None, verbose: bool = True,
             strategy: str = "tp") -> CellReport:
    mesh_name = ("2x16x16" if multi_pod else "16x16") + (
        "" if strategy == "tp" else f"+{strategy}")
    skip = _should_skip(arch, shape_name)
    if skip:
        return CellReport(arch=arch, shape=shape_name, mesh=mesh_name,
                          status="skip", note=skip)
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_dev = mesh.size
    t0 = time.time()
    try:
        from repro.models import layers as _L

        ax0 = MeshAxes(mesh)
        _L.set_shard_ctx(mesh, ax0.dp, ax0.model)
        jf, raw_fn, args, aux = build_cell(arch, shape_name, mesh,
                                           strategy=strategy)
        with mesh:
            lowered = jf.lower(*args)
            compiled = lowered.compile()
        dt = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # jax ≤ 0.4.x: one dict per device
            cost = cost[0] if cost else {}
        hlo_flops = float(cost.get("flops", 0.0)) / n_dev
        hlo_bytes = float(cost.get("bytes accessed", 0.0)) / n_dev
        # jaxpr-traced global flops (correct across scan bodies)
        flops = traced_flops(raw_fn, *args) / n_dev
        byts = analytic_hbm_bytes(kind=shape.kind, **aux)
        coll, coll_total = collective_bytes(compiled.as_text())
        compute_term = flops / TPU_V5E.peak_bf16_flops
        memory_term = byts / TPU_V5E.hbm_bandwidth
        # per-device collective bytes over 3 usable ICI links per direction
        collective_term = coll_total / (3 * TPU_V5E.ici_link_bandwidth)
        terms = {"compute": compute_term, "memory": memory_term,
                 "collective": collective_term}
        bottleneck = max(terms, key=terms.get)
        arg_b = float(getattr(mem, "argument_size_in_bytes", 0))
        out_b = float(getattr(mem, "output_size_in_bytes", 0))
        tmp_b = float(getattr(mem, "temp_size_in_bytes", 0))
        mf = model_flops_for(cfg, shape)
        report = CellReport(
            arch=arch, shape=shape_name, mesh=mesh_name, status="ok",
            compile_s=dt,
            flops_per_device=flops, hbm_bytes_per_device=byts,
            hlo_flops_per_device=hlo_flops, hlo_bytes_per_device=hlo_bytes,
            collective_bytes=coll, collective_total=coll_total,
            argument_bytes=arg_b, output_bytes=out_b, temp_bytes=tmp_b,
            compute_term_s=compute_term, memory_term_s=memory_term,
            collective_term_s=collective_term, bottleneck=bottleneck,
            model_flops=mf,
            model_flops_ratio=(mf / (flops * n_dev)) if flops else 0.0,
            fits_hbm=(arg_b + out_b + tmp_b) <= TPU_V5E.hbm_bytes,
        )
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] OK "
                  f"compile={dt:.1f}s flops/dev={flops:.3e} "
                  f"hbm_bytes/dev={byts:.3e} coll/dev={coll_total:.3e}")
            print(f"  memory_analysis: args={arg_b/1e9:.2f}GB out={out_b/1e9:.2f}GB "
                  f"temp={tmp_b/1e9:.2f}GB fits_hbm={report.fits_hbm}")
            print(f"  roofline terms (s): compute={compute_term:.4f} "
                  f"memory={memory_term:.4f} collective={collective_term:.4f} "
                  f"→ {bottleneck}-bound; model_flops_ratio="
                  f"{report.model_flops_ratio:.2f}")
        return report
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        import traceback
        note = f"{type(e).__name__}: {e}"
        if verbose:
            print(f"[{arch} × {shape_name} × {mesh_name}] ERROR {note}")
            traceback.print_exc()
        return CellReport(arch=arch, shape=shape_name, mesh=mesh_name,
                          status="error", note=note[:2000],
                          compile_s=time.time() - t0)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    reports = []
    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        for arch in archs:
            for shape in shapes:
                reports.append(run_cell(arch, shape, multi_pod=mp, mesh=mesh))
    ok = sum(r.status == "ok" for r in reports)
    sk = sum(r.status == "skip" for r in reports)
    er = sum(r.status == "error" for r in reports)
    print(f"\n=== dry-run sweep: {ok} ok / {sk} skip / {er} error ===")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([r.to_dict() for r in reports], f, indent=1)
        print(f"wrote {args.json}")
    return 0 if er == 0 else 1


if __name__ == "__main__":
    sys.exit(main())
