"""internvl2-2b [vlm]: InternViT frontend (STUB) + InternLM2-1.8b backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553 [arXiv:2404.16821; hf].
``input_specs()`` provides precomputed patch embeddings (256 visual tokens
after pixel-shuffle), prepended to the text sequence.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=92553,
    frontend_tokens=256,
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821",
)
