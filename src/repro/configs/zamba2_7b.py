"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention block.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified].  The shared transformer block (one parameter
set, applied every 6 mamba blocks on concat(hidden, embedding)) is Zamba's
signature; per-application LoRA deltas are omitted (DESIGN.md §5).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_head=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_heads=112,          # d_inner 7168 / head_dim 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=2,
    shared_attn_every=6,
    rope_theta=10000.0,
    source="arXiv:2411.15242",
)
