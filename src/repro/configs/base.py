"""Config system: architectures × input shapes.

``ModelConfig`` fully describes an architecture (public-literature configs —
sources cited in each ``configs/<id>.py``).  ``ShapeConfig`` describes the
assigned input-shape set.  ``reduced()`` derives the CPU smoke-test version of
any config (same family/topology, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Literal, Optional, Tuple

__all__ = [
    "ModelConfig", "ShapeConfig", "SHAPES", "get_config", "get_shape",
    "list_archs", "REGISTRY",
]

Family = Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    first_dense_layers: int = 0       # deepseek-moe keeps layer 0 dense
    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (zamba2) ---
    shared_attn_every: int = 0
    # --- encoder-decoder (seamless) ---
    n_encoder_layers: int = 0
    # --- modality frontend stub (vlm/audio) ---
    frontend_tokens: int = 0          # embeddings prepended / fed to encoder
    # --- details ---
    rope_theta: float = 1_000_000.0
    norm_eps: float = 1e-5
    qkv_bias: bool = False            # qwen-style
    tie_embeddings: bool = False
    act: str = "swiglu"
    # TP divisibility: pad head count at init (extra heads are dead weight
    # so the function class is unchanged; analytic param_count uses the true
    # head count — see DESIGN.md §6)
    pad_heads_to: int = 0
    # --- training ---
    optimizer: str = "adamw"          # "adafactor" for the 1T MoE
    lr_schedule: str = "cosine"       # "wsd" for minicpm
    remat: bool = True
    # --- notes / provenance ---
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def attn_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def eff_heads(self) -> int:
        """Head count actually instantiated (incl. TP padding)."""
        return self.pad_heads_to or self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        if self.pad_heads_to and self.n_kv_heads == self.n_heads:
            return self.pad_heads_to
        return self.n_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def padded_vocab(self, multiple: int = 256) -> int:
        return ((self.vocab_size + multiple - 1) // multiple) * multiple

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k runs only for sub-quadratic archs (ssm / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Total parameters (embedding + blocks), analytic."""
        V, D = self.padded_vocab(), self.d_model
        emb = V * D * (1 if self.tie_embeddings else 2)
        per_layer = self._block_params()
        total = emb + self.n_layers * per_layer + D  # final norm
        if self.family == "encdec":
            total += self.n_encoder_layers * self._encoder_block_params() + D
        if self.family == "hybrid" and self.shared_attn_every:
            total += self._shared_block_params()
        return int(total)

    def active_param_count(self) -> int:
        """Active per-token parameters (MoE: shared + top-k experts only)."""
        if self.family != "moe":
            return self.param_count()
        V, D = self.padded_vocab(), self.d_model
        emb = V * D * (1 if self.tie_embeddings else 2)
        attn = self._attn_params()
        act_ffn = 3 * D * self.moe_d_ff * (
            self.experts_per_token + self.n_shared_experts
        ) + D * self.n_experts
        dense_ffn = 3 * D * self.d_ff if self.d_ff else 0
        n_moe = self.n_layers - self.first_dense_layers
        total = emb + n_moe * (attn + act_ffn + 2 * D)
        total += self.first_dense_layers * (attn + (dense_ffn or act_ffn) + 2 * D)
        return int(total)

    # -- analytic per-block parameter counts --------------------------------
    def _attn_params(self) -> int:
        D = self.d_model
        return D * self.attn_dim + 2 * D * self.kv_dim + self.attn_dim * D

    def _dense_ffn_params(self) -> int:
        return 3 * self.d_model * self.d_ff

    def _moe_ffn_params(self) -> int:
        D = self.d_model
        return (
            3 * D * self.moe_d_ff * (self.n_experts + self.n_shared_experts)
            + D * self.n_experts  # router
        )

    def _mamba_block_params(self) -> int:
        D, DI = self.d_model, self.d_inner
        H, N, G = self.ssm_heads, self.ssm_state, self.ssm_groups
        in_proj = D * (2 * DI + 2 * G * N + H)   # x, z, B, C, dt
        conv = (DI + 2 * G * N) * self.conv_kernel
        out = DI * D
        return in_proj + conv + out + 2 * H + D  # A, D params + norm

    def _block_params(self) -> int:
        D = self.d_model
        if self.family in ("dense", "vlm"):
            return self._attn_params() + self._dense_ffn_params() + 2 * D
        if self.family == "moe":
            return self._attn_params() + self._moe_ffn_params() + 2 * D
        if self.family in ("ssm",):
            return self._mamba_block_params()
        if self.family == "hybrid":
            return self._mamba_block_params()
        if self.family == "encdec":
            # decoder block: self-attn + cross-attn + ffn
            return 2 * self._attn_params() + self._dense_ffn_params() + 3 * D
        raise ValueError(self.family)

    def _encoder_block_params(self) -> int:
        return self._attn_params() + self._dense_ffn_params() + 2 * self.d_model

    def _shared_block_params(self) -> int:
        # zamba2 shared attention block consumes concat(h, emb) → 2D input
        D = self.d_model
        qkv = (2 * D) * self.attn_dim + 2 * (2 * D) * self.kv_dim + self.attn_dim * D
        ffn = 3 * D * self.d_ff
        return qkv + ffn + 4 * D

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test twin: same family & topology, tiny dims."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 7),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_head=32,
            d_ff=256,
            vocab_size=512,
            n_experts=min(self.n_experts, 8),
            n_shared_experts=min(self.n_shared_experts, 1),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=64 if self.moe_d_ff else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            # d_inner = ssm_expand·128 must equal ssm_heads·ssm_head_dim
            ssm_heads=(self.ssm_expand * 128) // 32 if self.ssm_heads else 0,
            ssm_head_dim=32 if self.ssm_heads else 64,
            ssm_chunk=32,
            shared_attn_every=3 if self.shared_attn_every else 0,
            n_encoder_layers=min(self.n_encoder_layers, 2),
            frontend_tokens=min(self.frontend_tokens, 8),
            first_dense_layers=min(self.first_dense_layers, 1),
            pad_heads_to=0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "mamba2-370m": "mamba2_370m",
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3.2-1b": "llama3_2_1b",
    "minicpm-2b": "minicpm_2b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "internvl2-2b": "internvl2_2b",
    "sparse-dnn-graphchallenge": "sparse_dnn_graphchallenge",
}

REGISTRY = dict(_ARCH_MODULES)


def list_archs() -> Tuple[str, ...]:
    return tuple(k for k in _ARCH_MODULES if k != "sparse-dnn-graphchallenge")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
