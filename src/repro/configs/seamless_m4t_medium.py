"""seamless-m4t-medium [audio]: encoder-decoder transformer backbone.

12L(+12L dec) d_model=1024 16H (kv=16) d_ff=4096 vocab=256206
[arXiv:2308.11596; hf].  The speech frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings (DESIGN.md §5); the text decoder is a
standard causal transformer with cross-attention.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,            # decoder layers
    n_encoder_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=256206,
    frontend_tokens=1024,   # precomputed speech frames fed to the encoder
    rope_theta=10_000.0,
    source="arXiv:2308.11596",
)
