"""minicpm-2b [dense]: llama-like arch trained with the WSD schedule.

40L d_model=2304 36H (kv=36) d_ff=5760 vocab=122753 [arXiv:2404.06395; hf].
The WSD (warmup-stable-decay) schedule is wired into the trainer.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_head=64,
    d_ff=5760,
    vocab_size=122753,
    rope_theta=10_000.0,
    tie_embeddings=True,
    pad_heads_to=48,
    lr_schedule="wsd",
    source="arXiv:2404.06395",
)
