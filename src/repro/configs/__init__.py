"""Architecture configs (``--arch <id>``) + shape registry."""

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    get_shape,
    list_archs,
    REGISTRY,
)
