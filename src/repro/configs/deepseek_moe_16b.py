"""deepseek-moe-16b [moe]: 2 shared + 64 routed top-6, fine-grained experts.

28L d_model=2048 16H (kv=16) d_ff=1408(expert) vocab=102400
[arXiv:2401.06066; hf].  Layer 0 is dense with d_ff=10944.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_head=128,
    d_ff=10944,             # dense first layer
    vocab_size=102400,
    n_experts=64,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    first_dense_layers=1,
    rope_theta=10_000.0,
    source="arXiv:2401.06066",
)
