"""kimi-k2-1t-a32b [moe]: trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, 384 experts top-8
+ 1 shared expert [arXiv:2501.kimi2; unverified].  moe_d_ff=2048 per expert;
dense d_ff applies to the first dense layer.  Adafactor keeps optimizer
state within the 16GB/chip HBM budget at 512 chips (DESIGN.md §6).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=18432,             # dense first layer (deepseek-v3-style)
    vocab_size=163840,
    n_experts=384,
    n_shared_experts=1,
    experts_per_token=8,
    moe_d_ff=2048,
    first_dense_layers=1,
    optimizer="adafactor",
    rope_theta=50_000.0,
    source="arXiv:2501.kimi2 (paper table)",
)
