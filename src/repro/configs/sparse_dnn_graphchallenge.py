"""The paper's own evaluation model: GraphChallenge sparse DNN (§VI-A).

Not part of the assigned LM pool — this config drives the FSI reproduction
benchmarks and the BSR kernel path.  N is selectable at run time.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="sparse-dnn-graphchallenge",
    family="dense",
    n_layers=120,
    d_model=1024,           # default N; benchmarks sweep {1024..65536}
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=0,
    source="GraphChallenge [Kepner et al., HPEC'19]",
)
