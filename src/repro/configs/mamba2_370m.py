"""mamba2-370m [ssm]: attention-free SSD (state-space duality).

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060].
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_head=64,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_heads=32,           # d_inner 2048 / head_dim 64
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_groups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
