"""Sparse matrix containers used across FSD-Inference.

Two formats:

* :class:`CSRMatrix` — row-compressed, the natural format for the paper's
  Lambda-side SpMM (cheap row extraction for the Xsend maps, cache-friendly
  row-major traversal on CPU workers).
* :class:`BSRMatrix` — block-compressed rows with MXU-aligned dense tiles.
  This is the TPU adaptation: the MXU wants dense (8,128)/(128,128) tiles, so
  instead of scalar-granular CSR we snap the sparsity pattern to a block grid
  and store dense blocks.  ``kernels/bsr_spmm`` consumes this format.

Everything here is plain numpy — device placement happens at the JAX layer.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "CSRMatrix",
    "BSRMatrix",
    "random_sparse",
    "csr_from_dense",
    "bsr_from_dense",
    "bsr_from_csr",
]


@dataclasses.dataclass
class CSRMatrix:
    """Compressed sparse row matrix.

    ``indptr``  int32[nrows+1]
    ``indices`` int32[nnz]   column ids, sorted within each row
    ``data``    float32[nnz]
    """

    shape: Tuple[int, int]
    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    @property
    def nrows(self) -> int:
        return self.shape[0]

    @property
    def ncols(self) -> int:
        return self.shape[1]

    def row(self, i: int) -> Tuple[np.ndarray, np.ndarray]:
        """(column ids, values) of row ``i``."""
        lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
        return self.indices[lo:hi], self.data[lo:hi]

    def row_nnz(self) -> np.ndarray:
        return np.diff(self.indptr)

    def col_nnz(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.ncols)

    def nonzero_cols(self) -> np.ndarray:
        """Sorted unique column ids that contain at least one nonzero."""
        return np.unique(self.indices)

    def to_dense(self) -> np.ndarray:
        out = np.zeros(self.shape, dtype=self.data.dtype)
        rows = np.repeat(np.arange(self.nrows), np.diff(self.indptr))
        out[rows, self.indices] = self.data
        return out

    def select_rows(self, rows: np.ndarray) -> "CSRMatrix":
        """Sub-matrix keeping only ``rows`` (global column ids preserved)."""
        rows = np.asarray(rows, dtype=np.int64)
        starts = self.indptr[rows].astype(np.int64)
        counts = (self.indptr[rows + 1] - self.indptr[rows]).astype(np.int64)
        indptr = np.zeros(len(rows) + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        total = int(indptr[-1])
        # gather index: for each kept row, a contiguous run into data/indices
        idx = np.repeat(starts - indptr[:-1], counts) + np.arange(total)
        return CSRMatrix(
            shape=(len(rows), self.ncols),
            indptr=indptr,
            indices=self.indices[idx],
            data=self.data[idx],
        )

    def matmul_dense(self, x: np.ndarray) -> np.ndarray:
        """``self @ x`` with x dense [ncols, B] (the FSI local SpMM)."""
        out = np.zeros((self.nrows, x.shape[1]), dtype=np.result_type(self.data, x))
        for i in range(self.nrows):
            lo, hi = int(self.indptr[i]), int(self.indptr[i + 1])
            if hi > lo:
                out[i] = self.data[lo:hi] @ x[self.indices[lo:hi]]
        return out

    def matmul_dense_scatter(self, x: np.ndarray) -> np.ndarray:
        """``self @ x`` via ``np.add.at`` scatter-add.

        Kept as the bit-exact oracle for the ``numpy-csr`` compute backend;
        ``np.add.at`` is unbuffered and 10-50x slower than the segment
        formulations in :meth:`matmul_dense_fast`.
        """
        rows = np.repeat(np.arange(self.nrows), np.diff(self.indptr))
        contrib = self.data[:, None] * x[self.indices]
        out = np.zeros((self.nrows, x.shape[1]), dtype=contrib.dtype)
        np.add.at(out, rows, contrib)
        return out

    def matmul_dense_fast(self, x: np.ndarray,
                          tile_elems: int = 1 << 22) -> np.ndarray:
        """Vectorized ``self @ x`` with x dense [ncols, B].

        Uniform-nnz rows (the GraphChallenge case: every row has exactly
        ``nnz_per_row`` entries, and row subsets keep whole rows) reshape the
        gathered contributions to [nrows, k, B] and contract the k axis with a
        batched matmul — no [nnz, B] temporary, no scatter.  Ragged rows use
        a segment ``np.add.reduceat`` over the CSR row pointers, **tiled over
        the batch axis**: the contribution temporary is materialized one
        [nnz, bt] panel at a time with ``bt = tile_elems // nnz`` columns, so
        peak extra memory is bounded by ~``tile_elems`` elements (default
        4Mi ≈ 16–32MB) instead of growing as nnz·B — big-batch ragged shards
        no longer spike the worker's high-water mark.
        """
        B = x.shape[1]
        counts = np.diff(self.indptr)
        dtype = np.result_type(self.data, x)
        if self.nnz == 0:
            return np.zeros((self.nrows, B), dtype=dtype)
        if counts.size and counts[0] > 0 and np.all(counts == counts[0]):
            k = int(counts[0])
            xg = x[self.indices].reshape(self.nrows, k, B)
            return np.matmul(self.data.reshape(self.nrows, 1, k), xg)[:, 0, :]
        out = np.zeros((self.nrows, B), dtype=dtype)
        nonempty = counts > 0
        starts = self.indptr[:-1][nonempty]
        if not starts.size:
            return out
        data_col = self.data[:, None]
        bt = max(1, min(B, tile_elems // max(1, self.nnz)))
        for b0 in range(0, B, bt):
            # advanced row index + basic column slice: gathers only the
            # [nnz, bt] panel, never the full [nnz, B] temporary
            contrib = data_col * x[self.indices, b0:b0 + bt]
            out[nonempty, b0:b0 + bt] = np.add.reduceat(contrib, starts, axis=0)
        return out


@dataclasses.dataclass
class BSRMatrix:
    """Block-compressed sparse rows with dense (bm, bn) tiles.

    ``indptr``  int32[n_block_rows+1]
    ``indices`` int32[n_blocks]  block-column ids
    ``blocks``  float32[n_blocks, bm, bn]
    """

    shape: Tuple[int, int]
    block_shape: Tuple[int, int]
    indptr: np.ndarray
    indices: np.ndarray
    blocks: np.ndarray

    @property
    def n_block_rows(self) -> int:
        return self.shape[0] // self.block_shape[0]

    @property
    def n_block_cols(self) -> int:
        return self.shape[1] // self.block_shape[1]

    @property
    def n_blocks(self) -> int:
        return int(self.blocks.shape[0])

    @property
    def block_density(self) -> float:
        return self.n_blocks / max(1, self.n_block_rows * self.n_block_cols)

    def to_dense(self) -> np.ndarray:
        bm, bn = self.block_shape
        out = np.zeros(self.shape, dtype=self.blocks.dtype)
        for br in range(self.n_block_rows):
            for p in range(int(self.indptr[br]), int(self.indptr[br + 1])):
                bc = int(self.indices[p])
                out[br * bm : (br + 1) * bm, bc * bn : (bc + 1) * bn] = self.blocks[p]
        return out

    def padded(self, max_blocks_per_row: int | None = None):
        """Dense-padded layout for the Pallas kernel.

        Returns ``(blocks [n_block_rows, K, bm, bn], cols int32[n_block_rows, K],
        counts int32[n_block_rows])`` where K = max blocks per block-row and
        padding entries point at block-col 0 with all-zero data (safe to
        multiply — contributes nothing).
        """
        counts = np.diff(self.indptr).astype(np.int32)
        k = int(max_blocks_per_row or max(1, counts.max(initial=1)))
        bm, bn = self.block_shape
        nbr = self.n_block_rows
        blocks = np.zeros((nbr, k, bm, bn), dtype=self.blocks.dtype)
        cols = np.zeros((nbr, k), dtype=np.int32)
        if self.n_blocks:
            br_idx = np.repeat(np.arange(nbr), counts)
            slot = np.arange(self.n_blocks) - np.repeat(
                self.indptr[:-1].astype(np.int64), counts
            )
            blocks[br_idx, slot] = self.blocks
            cols[br_idx, slot] = self.indices
        return blocks, cols, counts


def csr_from_dense(dense: np.ndarray) -> CSRMatrix:
    nrows, _ = dense.shape
    rows, cols = np.nonzero(dense)
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    indptr = np.zeros(nrows + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRMatrix(
        shape=dense.shape,
        indptr=indptr,
        indices=cols.astype(np.int32),
        data=dense[rows, cols].astype(dense.dtype),
    )


def bsr_from_dense(dense: np.ndarray, block_shape: Tuple[int, int]) -> BSRMatrix:
    bm, bn = block_shape
    m, n = dense.shape
    if m % bm or n % bn:
        raise ValueError(f"dense shape {dense.shape} not divisible by {block_shape}")
    nbr, nbc = m // bm, n // bn
    tiled = dense.reshape(nbr, bm, nbc, bn).transpose(0, 2, 1, 3)
    mask = np.abs(tiled).sum(axis=(2, 3)) != 0
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    indices, blocks = [], []
    for br in range(nbr):
        cols = np.nonzero(mask[br])[0]
        indptr[br + 1] = indptr[br] + len(cols)
        indices.append(cols)
        blocks.append(tiled[br, cols])
    indices = (
        np.concatenate(indices).astype(np.int32) if indices else np.zeros(0, np.int32)
    )
    blocks = (
        np.concatenate(blocks, axis=0)
        if blocks and sum(b.shape[0] for b in blocks)
        else np.zeros((0, bm, bn), dense.dtype)
    )
    return BSRMatrix(
        shape=dense.shape,
        block_shape=block_shape,
        indptr=indptr,
        indices=indices,
        blocks=blocks.astype(dense.dtype),
    )


def bsr_from_csr(
    csr: CSRMatrix, block_shape: Tuple[int, int], pad: bool = False
) -> BSRMatrix:
    """CSR → BSR straight from the block coordinates of each nonzero.

    Never materializes the dense matrix: every nonzero ``(r, c)`` maps to a
    block coordinate ``(r // bm, c // bn)`` and an in-block offset, the
    distinct block coordinates become the BSR structure (sorted row-major,
    like :func:`bsr_from_dense` produces), and a single vectorized scatter
    fills the block data.  Memory is O(nnz + n_blocks·bm·bn) — a 1024×65536
    worker shard with 32 nnz/row costs ~the blocks themselves, not a 256MB
    densified panel (the ROADMAP N=65536 sweep bottleneck).

    With ``pad=True`` the matrix shape is rounded up to the next block-grid
    multiple (arbitrary worker-shard shapes become legal; padding rows/cols
    are all-zero so they never contribute).  Without it, non-divisible shapes
    raise like :func:`bsr_from_dense`.
    """
    bm, bn = block_shape
    m, n = csr.shape
    if pad:
        m = -(-max(m, 1) // bm) * bm
        n = -(-max(n, 1) // bn) * bn
    elif m % bm or n % bn:
        raise ValueError(f"dense shape {csr.shape} not divisible by {block_shape}")
    nbr, nbc = m // bm, n // bn
    if csr.nnz == 0:
        return BSRMatrix(
            shape=(m, n), block_shape=block_shape,
            indptr=np.zeros(nbr + 1, dtype=np.int64),
            indices=np.zeros(0, np.int32),
            blocks=np.zeros((0, bm, bn), csr.data.dtype),
        )
    rows = np.repeat(np.arange(csr.nrows, dtype=np.int64), np.diff(csr.indptr))
    cols = csr.indices.astype(np.int64)
    # nnz → flat block id (row-major over the block grid) + in-block offset
    key = (rows // bm) * nbc + cols // bn
    order = np.argsort(key, kind="stable")
    uniq, inv = np.unique(key[order], return_inverse=True)
    blocks = np.zeros((uniq.size, bm, bn), dtype=csr.data.dtype)
    blocks[inv, rows[order] % bm, cols[order] % bn] = csr.data[order]
    indptr = np.zeros(nbr + 1, dtype=np.int64)
    np.add.at(indptr, uniq // nbc + 1, 1)
    np.cumsum(indptr, out=indptr)
    return BSRMatrix(
        shape=(m, n), block_shape=block_shape,
        indptr=indptr,
        indices=(uniq % nbc).astype(np.int32),
        blocks=blocks,
    )


def random_sparse(
    nrows: int,
    ncols: int,
    nnz_per_row: int,
    rng: np.random.Generator,
    dtype=np.float32,
    value_scale: float = 1.0,
) -> CSRMatrix:
    """Fixed-nnz-per-row random sparse matrix (GraphChallenge-style).

    The GraphChallenge synthetic DNNs (RadiX-Net) have exactly 32 nonzeros per
    row; we generalize to ``nnz_per_row`` with values in {-value_scale,
    +value_scale} like the benchmark's ±1/16-ish weights.
    """
    nnz_per_row = min(nnz_per_row, ncols)
    indptr = np.arange(nrows + 1, dtype=np.int64) * nnz_per_row
    # Vectorized sampling-without-replacement per row: draw, sort, and
    # resample rows that contain duplicates (rare for nnz << ncols).
    idx = np.sort(rng.integers(0, ncols, size=(nrows, nnz_per_row)), axis=1)
    for _ in range(64):
        dup_rows = np.nonzero((np.diff(idx, axis=1) == 0).any(axis=1))[0]
        if dup_rows.size == 0:
            break
        idx[dup_rows] = np.sort(
            rng.integers(0, ncols, size=(dup_rows.size, nnz_per_row)), axis=1
        )
    else:  # pathological nnz≈ncols: fall back to exact per-row choice
        for i in np.nonzero((np.diff(idx, axis=1) == 0).any(axis=1))[0]:
            idx[i] = np.sort(rng.choice(ncols, size=nnz_per_row, replace=False))
    indices = idx.reshape(-1).astype(np.int32)
    signs = rng.integers(0, 2, size=nrows * nnz_per_row) * 2 - 1
    data = (signs * value_scale).astype(dtype)
    return CSRMatrix(
        shape=(nrows, ncols), indptr=indptr, indices=indices, data=data
    )
