"""Model partitioning for FSD-Inference (paper §II-C, §III, Table III).

The paper row-partitions every (sparse) weight matrix ``W^k`` and the
activation vectors ``x^k`` across ``P`` FaaS workers, using *column-net
hypergraph partitioning* (HGP-DNN, adapting Demirci & Ferhatosmanoglu, ICS'21)
so that (a) compute load (nnz) is balanced and (b) the total inter-worker
communication volume — rows of ``x^{k-1}`` that must travel between workers —
is minimized.  Random partitioning (RP) is the paper's baseline (Table III
shows HGP-DNN beats RP by ~1 OOM of traffic).

Ownership model (row-parallel SpMM, z^k = W^k @ x^{k-1}):

* the worker that owns row ``i`` of ``W^k`` computes and therefore *owns*
  ``x^k[i]``;
* to compute its rows, a worker needs ``x^{k-1}[j]`` for every nonzero column
  ``j`` in its row block — if owned elsewhere, that row must be communicated.

For constant-width networks (the GraphChallenge DNNs: every layer is N×N) we
partition the *neuron index space once, jointly over all layers* — vertex
``v`` is a neuron, its weight is its total nnz across layers, and each column
``j`` of each layer contributes a net ``{j} ∪ {rows with nnz in col j}``.
Joint partitioning is what lets layer-(k) producers sit with their layer-(k+1)
consumers.  For varying-width networks we partition each layer greedily given
the previous layer's placement.

The partitioner here is a greedy hypergraph-growing pass + FM-style
refinement: not PaToH, but the same objective (connectivity-1 cut) and
balance constraint, fully deterministic given a seed.
"""

from __future__ import annotations

import dataclasses
from typing import List, Literal, Sequence

import numpy as np

from repro.core.sparse import CSRMatrix

__all__ = [
    "PartitionResult",
    "partition_network",
    "random_partition",
    "block_partition",
    "hypergraph_partition",
    "measure_comm_volume",
    "CommVolumeReport",
    "StageSpec",
    "StagePlan",
    "plan_stages",
]

Method = Literal["hgp", "random", "block"]


@dataclasses.dataclass
class PartitionResult:
    """``parts[k]`` maps row index of layer-k output (= x^k row) → worker id.

    ``parts[0]`` is the placement of the input vector x^0.  For constant-width
    joint partitioning all entries alias the same array.
    """

    P: int
    parts: List[np.ndarray]  # len L+1, parts[k].shape == (N_k,)
    method: str

    def loads(self, layers: Sequence[CSRMatrix]) -> np.ndarray:
        """Per-worker compute load (total nnz of owned rows, all layers)."""
        loads = np.zeros(self.P, dtype=np.int64)
        for k, W in enumerate(layers):
            row_nnz = W.row_nnz()
            np.add.at(loads, self.parts[k + 1], row_nnz)
        return loads

    def imbalance(self, layers: Sequence[CSRMatrix]) -> float:
        loads = self.loads(layers)
        mean = loads.mean()
        return float(loads.max() / mean) if mean > 0 else 1.0


def random_partition(n: int, P: int, seed: int = 0) -> np.ndarray:
    """Balanced random assignment (paper's RP baseline, PaToH 'random')."""
    rng = np.random.default_rng(seed)
    parts = np.arange(n, dtype=np.int32) % P
    rng.shuffle(parts)
    return parts

def block_partition(n: int, P: int) -> np.ndarray:
    """Contiguous row blocks — the naive tensor-parallel default."""
    # ceil-split so every part gets at most ceil(n/P)
    bounds = np.linspace(0, n, P + 1).astype(np.int64)
    parts = np.zeros(n, dtype=np.int32)
    for p in range(P):
        parts[bounds[p] : bounds[p + 1]] = p
    return parts


def _build_nets(layers: Sequence[CSRMatrix]) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Exact per-layer column-net hypergraph over a constant-width network.

    Net ``(k, j)`` pins producer vertex ``j`` plus every row with a nonzero in
    column ``j`` of layer ``k``.  Net ids are ``k*N + j``.  Returns CSR-style
    ``(net_ptr, net_pins, vertex_weights)``, fully vectorized (O(nnz log nnz)).
    """
    n = layers[0].ncols
    L = len(layers)
    vertex_w = np.zeros(n, dtype=np.int64)
    net_id_chunks: List[np.ndarray] = []
    pin_chunks: List[np.ndarray] = []
    for k, W in enumerate(layers):
        vertex_w[: W.nrows] += W.row_nnz()
        rows = np.repeat(np.arange(W.nrows, dtype=np.int64), W.row_nnz())
        cols = W.indices.astype(np.int64)
        base = k * n
        # producer pins (net k*n+j pins vertex j) + consumer pins
        net_id_chunks.append(base + np.arange(n, dtype=np.int64))
        pin_chunks.append(np.arange(n, dtype=np.int64))
        net_id_chunks.append(base + cols)
        pin_chunks.append(rows)
    net_ids = np.concatenate(net_id_chunks)
    pins = np.concatenate(pin_chunks)
    # dedupe (net, pin) pairs
    key = net_ids * n + pins
    key = np.unique(key)
    net_ids = key // n
    pins = (key % n).astype(np.int32)
    # CSR over nets (net ids are already sorted by unique)
    counts = np.bincount(net_ids, minlength=L * n)
    net_ptr = np.zeros(L * n + 1, dtype=np.int64)
    np.cumsum(counts, out=net_ptr[1:])
    return net_ptr, pins, vertex_w


def _vertex_nets(net_ptr: np.ndarray, net_pins: np.ndarray, n: int):
    """Inverse map: for each vertex, the (sorted) list of nets pinning it."""
    n_nets = net_ptr.shape[0] - 1
    nets_of_pins = np.repeat(
        np.arange(n_nets, dtype=np.int64), np.diff(net_ptr)
    )
    order = np.argsort(net_pins, kind="stable")
    out = nets_of_pins[order].astype(np.int64)
    counts = np.bincount(net_pins, minlength=n)
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=ptr[1:])
    return ptr, out


def hypergraph_partition(
    layers: Sequence[CSRMatrix],
    P: int,
    seed: int = 0,
    eps: float = 0.05,
    refine_passes: int = 3,
) -> np.ndarray:
    """Greedy hypergraph-growing + FM refinement on the joint neuron space."""
    n = layers[0].ncols
    for W in layers:
        if W.ncols != n or W.nrows != n:
            raise ValueError("joint HGP requires constant-width layers")
    net_ptr, net_pins, vertex_w = _build_nets(layers)
    vptr, vnets = _vertex_nets(net_ptr, net_pins, n)

    rng = np.random.default_rng(seed)
    cap = (1.0 + eps) * vertex_w.sum() / P

    # Initial solution: contiguous blocks.  Structured DNN sparsity (radix
    # butterflies, conv-like locality) is near-optimal under contiguity, and
    # FM refinement below only ever improves the connectivity-1 cut, so HGP
    # dominates both the block and random baselines by construction.
    parts = block_partition(n, P).copy()
    loads = np.zeros(P, dtype=np.float64)
    np.add.at(loads, parts, vertex_w.astype(np.float64))

    # part_count[net, p]: how many pins of `net` are in part p
    n_nets = net_ptr.shape[0] - 1
    part_count = np.zeros((n_nets, P), dtype=np.int16)
    nets_of_pins = np.repeat(np.arange(n_nets, dtype=np.int64), np.diff(net_ptr))
    np.add.at(part_count, (nets_of_pins, parts[net_pins]), 1)

    # FM-style refinement: move vertices with positive connectivity gain.
    for _ in range(refine_passes):
        moved = 0
        for v in rng.permutation(n):
            a = parts[v]
            nets = vnets[vptr[v] : vptr[v + 1]]
            if not nets.size:
                continue
            counts = part_count[nets]  # [n_nets_v, P]
            # removing v from a: nets where v is the sole pin in a lose a part
            sole = counts[:, a] == 1
            gain_remove = int(sole.sum())
            # adding v to b: nets where b is empty gain a part
            add_cost = (counts == 0).sum(axis=0).astype(np.int64)
            add_cost[a] = gain_remove  # moving to self = no-op
            b = int(np.argmin(add_cost))
            gain = gain_remove - int(add_cost[b])
            if b != a and gain > 0 and loads[b] + vertex_w[v] <= cap:
                parts[v] = b
                loads[a] -= vertex_w[v]
                loads[b] += vertex_w[v]
                part_count[nets, a] -= 1
                part_count[nets, b] += 1
                moved += 1
        if moved == 0:
            break
    return parts


def partition_network(
    layers: Sequence[CSRMatrix],
    P: int,
    method: Method = "hgp",
    seed: int = 0,
    eps: float = 0.05,
) -> PartitionResult:
    """Partition a whole network; returns per-interface row→worker maps."""
    widths = {W.ncols for W in layers} | {W.nrows for W in layers}
    constant = len(widths) == 1
    L = len(layers)
    if method == "random":
        if constant:
            p = random_partition(layers[0].ncols, P, seed)
            parts = [p] * (L + 1)
        else:
            parts = [random_partition(layers[0].ncols, P, seed)]
            parts += [random_partition(W.nrows, P, seed + 1 + k) for k, W in enumerate(layers)]
    elif method == "block":
        if constant:
            p = block_partition(layers[0].ncols, P)
            parts = [p] * (L + 1)
        else:
            parts = [block_partition(layers[0].ncols, P)]
            parts += [block_partition(W.nrows, P) for W in layers]
    elif method == "hgp":
        if constant:
            p = hypergraph_partition(layers, P, seed=seed, eps=eps)
            parts = [p] * (L + 1)
        else:
            # Layer-by-layer greedy: place rows of W^k near their inputs.
            parts = [block_partition(layers[0].ncols, P)]
            for W in layers:
                parts.append(_greedy_layer_partition(W, parts[-1], P, eps))
    else:
        raise ValueError(f"unknown method {method!r}")
    return PartitionResult(P=P, parts=list(parts), method=method)


def _greedy_layer_partition(
    W: CSRMatrix, prev_parts: np.ndarray, P: int, eps: float
) -> np.ndarray:
    """Assign rows of W to the part owning most of their input rows."""
    row_nnz = W.row_nnz()
    cap = (1.0 + eps) * row_nnz.sum() / P
    loads = np.zeros(P, dtype=np.float64)
    parts = np.zeros(W.nrows, dtype=np.int32)
    order = np.argsort(-row_nnz)
    for i in order:
        cols, _ = W.row(i)
        if cols.size:
            affinity = np.bincount(prev_parts[cols], minlength=P).astype(np.float64)
        else:
            affinity = np.zeros(P)
        affinity -= 1e-9 * loads
        affinity[loads + row_nnz[i] > cap] = -np.inf
        p = int(np.argmax(affinity)) if not np.all(np.isinf(affinity)) else int(np.argmin(loads))
        parts[i] = p
        loads[p] += row_nnz[i]
    return parts


@dataclasses.dataclass
class CommVolumeReport:
    """Exact communication accounting for a partition (Table III analogue)."""

    total_rows_sent: int            # Σ over layers of rows crossing workers
    total_bytes_sent: int           # rows × bytes_per_row (batch dependent)
    per_layer_rows: np.ndarray      # [L]
    per_worker_sent_rows: np.ndarray  # [P]
    mean_rows_per_target: float     # paper's "NNZ sent per target" analogue
    max_worker_rows: int

    @property
    def imbalance(self) -> float:
        m = self.per_worker_sent_rows.mean()
        return float(self.per_worker_sent_rows.max() / m) if m > 0 else 1.0


def measure_comm_volume(
    layers: Sequence[CSRMatrix],
    result: PartitionResult,
    bytes_per_row: int = 4 * 1,
) -> CommVolumeReport:
    """Exact per-layer comm volume: a row of x^{k-1} travels once per distinct
    remote consumer worker (the FSI channels send per-target copies)."""
    P = result.P
    L = len(layers)
    per_layer = np.zeros(L, dtype=np.int64)
    per_worker = np.zeros(P, dtype=np.int64)
    pair_counts = []
    for k, W in enumerate(layers):
        src_parts = result.parts[k]       # owner of x^{k-1} rows
        dst_parts = result.parts[k + 1]   # owner of W^k rows
        rows = np.repeat(np.arange(W.nrows, dtype=np.int64), W.row_nnz())
        cols = W.indices.astype(np.int64)
        src = src_parts[cols]
        dst = dst_parts[rows]
        remote = src != dst
        if remote.any():
            # distinct (col j, src worker, dst worker) triples ⇒ one row send
            key = (cols[remote] * P + src[remote]) * P + dst[remote]
            uniq = np.unique(key)
            per_layer[k] = uniq.shape[0]
            senders = (uniq // P) % P
            np.add.at(per_worker, senders, 1)
            pairs = np.unique(uniq % (P * P))
            pair_counts.append((uniq.shape[0], pairs.shape[0]))
        else:
            pair_counts.append((0, 0))
    total_rows = int(per_layer.sum())
    total_pairs = sum(p for _, p in pair_counts)
    return CommVolumeReport(
        total_rows_sent=total_rows,
        total_bytes_sent=total_rows * bytes_per_row,
        per_layer_rows=per_layer,
        per_worker_sent_rows=per_worker,
        mean_rows_per_target=(total_rows / total_pairs) if total_pairs else 0.0,
        max_worker_rows=int(per_worker.max(initial=0)),
    )


# ---------------------------------------------------------------------------
# Pipeline-stage planning for the serverless LM executor
# ---------------------------------------------------------------------------
#
# The FSI partitioners above split a *constant-width sparse network* row-wise
# (data parallel over neurons).  LM serving over the FaaS fabric splits the
# other way: the layer stack is cut into P **contiguous stages**, each stage
# runs as one worker with its layer slice (and KV cache) resident, and only
# the [B, S, d_model] activation crosses a stage boundary — the pipeline
# analogue of the paper's "send only the rows the consumer needs".


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One contiguous slice of the layer stack.

    ``start``/``stop`` are global layer indices (``[start, stop)``).
    ``has_embed`` marks the stage that owns the token embedding (always the
    first); ``has_head`` marks the stage that owns the final norm + unembed
    (always the last).  With tied embeddings the table is resident on both —
    the real deployment replicates it, and the weight-load bill reflects
    that."""

    index: int
    start: int
    stop: int
    has_embed: bool
    has_head: bool

    @property
    def n_layers(self) -> int:
        return self.stop - self.start


@dataclasses.dataclass(frozen=True)
class StagePlan:
    P: int
    n_layers: int
    stages: tuple  # Tuple[StageSpec, ...]

    def __post_init__(self):
        assert self.stages[0].start == 0
        assert self.stages[-1].stop == self.n_layers


def plan_stages(layer_costs: Sequence[float], P: int) -> StagePlan:
    """Cut ``len(layer_costs)`` layers into P contiguous, non-empty stages
    balancing cumulative cost (cost = FLOPs or parameter bytes per layer —
    any nonnegative weight; uniform costs give an even split).

    Boundary ``i`` lands where the cumulative cost crosses ``total·i/P``,
    then boundaries are repaired so every stage keeps ≥1 layer — the planner
    is deterministic and never emits an empty stage.
    """
    L = len(layer_costs)
    if P < 1:
        raise ValueError(f"P must be >= 1, got {P}")
    if P > L:
        raise ValueError(f"cannot cut {L} layers into {P} non-empty stages")
    costs = np.asarray(layer_costs, dtype=np.float64)
    if (costs < 0).any():
        raise ValueError("layer costs must be nonnegative")
    cum = np.cumsum(costs)
    total = cum[-1] if cum[-1] > 0 else float(L)
    if cum[-1] <= 0:
        cum = np.arange(1, L + 1, dtype=np.float64)
    # ideal boundary after the layer where cumsum crosses total*i/P
    bounds = [0]
    for i in range(1, P):
        b = int(np.searchsorted(cum, total * i / P, side="left")) + 1
        # keep at least one layer per stage on both sides
        b = max(b, bounds[-1] + 1)
        b = min(b, L - (P - i))
        bounds.append(b)
    bounds.append(L)
    stages = tuple(
        StageSpec(index=i, start=bounds[i], stop=bounds[i + 1],
                  has_embed=(i == 0), has_head=(i == P - 1))
        for i in range(P)
    )
    return StagePlan(P=P, n_layers=L, stages=stages)
