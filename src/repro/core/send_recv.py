"""Per-layer ``Xsend`` / ``Xrecv`` maps (paper §III-C).

The hypergraph partitioning stage equips every worker ``P_m`` with, for each
layer ``k``:

* ``Xsend_m^k``: {target worker n → global row ids of x^{k-1} that m owns and
  n needs},
* ``Xrecv_m^k``: {source worker n → global row ids of x^{k-1} that m needs
  and n owns}.

These are static (model × partition) artifacts computed offline — exactly the
paper's "reads its share of the model weights, inference data and per-layer
send and receive maps".  The same maps drive (a) the faithful FaaS simulator
and (b) the TPU sparse-exchange collectives in ``core/tensor_parallel.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import numpy as np

from repro.core.partitioner import PartitionResult
from repro.core.sparse import CSRMatrix

__all__ = ["LayerCommPlan", "WorkerLayerPlan", "build_comm_plans"]


@dataclasses.dataclass
class WorkerLayerPlan:
    """One worker's view of one layer's exchange."""

    worker: int
    layer: int
    # global row ids of x^{k-1} this worker owns (sorted)
    owned_in_rows: np.ndarray
    # global row ids of W^k (⇒ x^k) this worker owns (sorted)
    owned_out_rows: np.ndarray
    # target worker → global x^{k-1} row ids to send (sorted, non-empty)
    send: Dict[int, np.ndarray]
    # source worker → global x^{k-1} row ids to receive (sorted, non-empty)
    recv: Dict[int, np.ndarray]
    # all x^{k-1} rows needed locally (owned ∪ received), sorted
    needed_rows: np.ndarray

    @property
    def rows_sent(self) -> int:
        return sum(len(v) for v in self.send.values())

    @property
    def rows_received(self) -> int:
        return sum(len(v) for v in self.recv.values())


@dataclasses.dataclass
class LayerCommPlan:
    layer: int
    workers: List[WorkerLayerPlan]

    def total_rows_sent(self) -> int:
        return sum(w.rows_sent for w in self.workers)


def build_comm_plans(
    layers: Sequence[CSRMatrix], result: PartitionResult
) -> List[LayerCommPlan]:
    """Construct all per-layer, per-worker send/recv maps.

    Complexity: O(nnz) per layer, fully vectorized.
    """
    P = result.P
    plans: List[LayerCommPlan] = []
    for k, W in enumerate(layers):
        src_parts = result.parts[k]
        dst_parts = result.parts[k + 1]
        n_in = W.ncols

        rows = np.repeat(np.arange(W.nrows, dtype=np.int64), W.row_nnz())
        cols = W.indices.astype(np.int64)
        dst = dst_parts[rows].astype(np.int64)

        # need[j, n] = worker n reads column j in this layer
        key = cols * P + dst
        uniq = np.unique(key)
        need_cols = uniq // P
        need_workers = (uniq % P).astype(np.int32)
        src_of_need = src_parts[need_cols].astype(np.int32)
        remote = src_of_need != need_workers

        workers: List[WorkerLayerPlan] = []
        # pre-bucket the remote (src → dst, col) triples
        r_cols = need_cols[remote]
        r_src = src_of_need[remote]
        r_dst = need_workers[remote]

        owned_in = [np.nonzero(src_parts == m)[0] for m in range(P)]
        owned_out = [np.nonzero(dst_parts == m)[0] for m in range(P)]

        # group by (src, dst)
        pair_key = r_src.astype(np.int64) * P + r_dst
        order = np.argsort(pair_key, kind="stable")
        pair_key_s = pair_key[order]
        cols_s = r_cols[order]
        boundaries = np.nonzero(np.diff(pair_key_s))[0] + 1
        groups = np.split(cols_s, boundaries)
        keys = pair_key_s[np.concatenate([[0], boundaries])] if pair_key_s.size else []

        send_maps: List[Dict[int, np.ndarray]] = [dict() for _ in range(P)]
        recv_maps: List[Dict[int, np.ndarray]] = [dict() for _ in range(P)]
        for pk, g in zip(keys, groups):
            s, d = int(pk // P), int(pk % P)
            rows_sd = np.sort(g)
            send_maps[s][d] = rows_sd
            recv_maps[d][s] = rows_sd

        for m in range(P):
            recv_rows = (
                np.concatenate(list(recv_maps[m].values()))
                if recv_maps[m]
                else np.zeros(0, dtype=np.int64)
            )
            # restrict to columns actually read by m's rows — one vectorized
            # multi-range gather of the owned rows' nnz index spans (a
            # per-row ``np.arange`` here costs O(rows) Python calls, which
            # dominated offline prep at N=65536)
            if len(owned_out[m]):
                starts = W.indptr[owned_out[m]].astype(np.int64)
                counts = (W.indptr[owned_out[m] + 1] - starts).astype(np.int64)
                total = int(counts.sum())
                prev = np.concatenate([[0], np.cumsum(counts[:-1])])
                idx = np.repeat(starts - prev, counts) + np.arange(total)
                my_cols = np.unique(W.indices[idx])
            else:
                my_cols = np.zeros(0, np.int64)
            workers.append(
                WorkerLayerPlan(
                    worker=m,
                    layer=k,
                    owned_in_rows=owned_in[m],
                    owned_out_rows=owned_out[m],
                    send=send_maps[m],
                    recv=recv_maps[m],
                    needed_rows=np.union1d(
                        np.intersect1d(owned_in[m], my_cols), recv_rows
                    ),
                )
            )
        plans.append(LayerCommPlan(layer=k, workers=workers))
    return plans
