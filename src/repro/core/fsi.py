"""FSI — Fully Serverless Inference (paper Algorithms 1 & 2).

This module contains the exact per-layer logic both channels share:

* offline artifact preparation (the paper's "reads its share of the model
  weights, inference data and per-layer send and receive maps"),
* Algorithm 1 (FSD-Inf-Queue): pack → publish batches → local MVP overlap →
  long-poll → deserialize → accumulate → activation,
* Algorithm 2 (FSD-Inf-Object): per-target single object (or `.nul`) → local
  MVP overlap → LIST/GET loop → accumulate → activation,
* the Serial variant (whole model on one worker, no channel).

The math is executed for real (numpy), byte streams are really compressed
and size-capped, and the clock/billing charges follow the algorithm order —
including the compute/communication overlap the paper exploits (local MVP is
charged *between* the sends and the receives).

Two host execution modes drive the same algorithm:

* the **per-worker** functions (``fsi_queue_send_and_local`` /
  ``fsi_queue_recv`` and the object twins) run one simulated Lambda each;
* the ``*_fleet`` variants batch the host-side hot path across all P
  workers of a layer — one ``pack_rows_fleet`` call packs every worker's
  outgoing row-sets, and the fleet drain decodes every pending chunk and
  lands them with ONE vectorized scatter into a flat fleet buffer
  (:class:`FleetRecvBuffers`).

Both modes share the publish/drain helpers, so billed units, message
counts, and per-worker clock charges are bit-identical by construction
(asserted in ``tests/test_fleet_channels.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Literal, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.backends import ComputeBackend, get_backend
from repro.core.partitioner import PartitionResult
from repro.core.send_recv import LayerCommPlan
from repro.core.sparse import CSRMatrix
from repro.data.graphchallenge import GraphChallengeNet
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk, decode_chunk, pack_rows_fleet
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import ComputeModel, WorkerState, estimate_worker_memory_bytes

__all__ = [
    "WorkerLayerArtifact",
    "WorkerArtifacts",
    "FleetRecvBuffers",
    "prepare_worker_artifacts",
    "fsi_queue_send_and_local",
    "fsi_queue_send_and_local_fleet",
    "fsi_queue_recv",
    "fsi_queue_recv_fleet",
    "fsi_queue_recv_and_finish",
    "fsi_object_send_and_local",
    "fsi_object_send_and_local_fleet",
    "fsi_object_recv",
    "fsi_object_recv_fleet",
    "fsi_object_recv_and_finish",
    "finish_layer",
    "charge_finish",
    "run_serial",
]

Channel = Literal["queue", "object"]


@dataclasses.dataclass
class WorkerLayerArtifact:
    """Worker ``m``'s offline-prepared share of layer ``k``."""

    layer: int
    W_local: CSRMatrix              # rows = owned out rows, cols = positions in needed_rows
    out_rows: np.ndarray            # global x^k row ids produced here (sorted)
    needed_rows: np.ndarray         # global x^{k-1} row ids required (sorted)
    owned_positions: np.ndarray     # positions of locally-owned inputs in needed_rows
    owned_source_positions: np.ndarray  # positions of those rows in the local x^{k-1} panel
    send_global: Dict[int, np.ndarray]   # target → global row ids
    send_positions: Dict[int, np.ndarray]  # target → positions in local x^{k-1} panel
    recv_expect: Dict[int, int]     # source → number of rows expected
    recv_positions: Dict[int, np.ndarray]  # source → positions in needed_rows
    local_flops: float              # 2·nnz over owned-input columns · batch≈ charged pre-recv
    remote_flops: float             # remainder, charged as contributions arrive
    # per-backend offline compute artifacts (e.g. padded BSR operands),
    # lazily populated; keyed by the backend's state_key (name + config, so
    # two differently-configured instances of one backend never share state)
    backend_states: Dict[str, Any] = dataclasses.field(
        default_factory=dict, repr=False
    )

    def state_for(self, backend: ComputeBackend) -> Any:
        key = getattr(backend, "state_key", backend.name)
        state = self.backend_states.get(key)
        if state is None:
            state = self.backend_states[key] = backend.prepare(self.W_local)
        return state


@dataclasses.dataclass
class WorkerArtifacts:
    rank: int
    layers: List[WorkerLayerArtifact]
    x0_rows: np.ndarray             # global input rows owned (sorted)
    weight_nnz: int
    max_needed: int
    max_out: int

    def memory_bytes(self, batch: int) -> int:
        return estimate_worker_memory_bytes(
            self.weight_nnz, self.max_needed, self.max_out, batch
        )


def prepare_worker_artifacts(
    layers: Sequence[CSRMatrix],
    partition: PartitionResult,
    plans: Sequence[LayerCommPlan],
    backend: Union[str, ComputeBackend, None] = None,
) -> List[WorkerArtifacts]:
    """Offline post-processing of the trained model (paper: hypergraph
    partitioning and map construction happen a priori, not per request).

    When ``backend`` is given, its per-worker-layer compute artifacts (e.g.
    the Pallas backend's padded BSR operands) are prepared here too — this is
    offline work, so it is never billed to a worker clock.
    """
    backend = get_backend(backend) if backend is not None else None
    P = partition.P
    out: List[WorkerArtifacts] = []
    for m in range(P):
        arts: List[WorkerLayerArtifact] = []
        weight_nnz = 0
        max_needed = max_out = 0
        prev_owned = np.nonzero(partition.parts[0] == m)[0]
        for k, W in enumerate(layers):
            wp = plans[k].workers[m]
            needed = wp.needed_rows
            out_rows = wp.owned_out_rows
            W_rows = W.select_rows(out_rows)
            # remap columns into the compact needed-space
            col_pos = np.searchsorted(needed, W_rows.indices)
            if needed.size:
                ok = (col_pos < needed.size) & (needed[np.minimum(col_pos, needed.size - 1)] == W_rows.indices)
                if not np.all(ok):
                    raise AssertionError("needed_rows misses a referenced column")
            W_local = CSRMatrix(
                shape=(len(out_rows), len(needed)),
                indptr=W_rows.indptr,
                indices=col_pos.astype(np.int32),
                data=W_rows.data,
            )
            # both operands are sorted-unique global row id sets
            owned_in = np.intersect1d(prev_owned, needed, assume_unique=True)
            owned_positions = np.searchsorted(needed, owned_in)
            owned_source_positions = np.searchsorted(prev_owned, owned_in)
            send_positions = {
                t: np.searchsorted(prev_owned, rows) for t, rows in wp.send.items()
            }
            recv_positions = {
                s: np.searchsorted(needed, rows) for s, rows in wp.recv.items()
            }
            # flops split for the overlap charging
            nnz_per_col = np.bincount(W_local.indices, minlength=len(needed))
            local_nnz = int(nnz_per_col[owned_positions].sum()) if len(needed) else 0
            arts.append(
                art := WorkerLayerArtifact(
                    layer=k,
                    W_local=W_local,
                    out_rows=out_rows,
                    needed_rows=needed,
                    owned_positions=owned_positions,
                    owned_source_positions=owned_source_positions,
                    send_global=dict(wp.send),
                    send_positions=send_positions,
                    recv_expect={s: len(r) for s, r in wp.recv.items()},
                    recv_positions=recv_positions,
                    local_flops=2.0 * local_nnz,
                    remote_flops=2.0 * (W_local.nnz - local_nnz),
                )
            )
            if backend is not None:
                art.state_for(backend)
            weight_nnz += W_local.nnz
            max_needed = max(max_needed, len(needed))
            max_out = max(max_out, len(out_rows))
            prev_owned = out_rows
        out.append(
            WorkerArtifacts(
                rank=m, layers=arts, x0_rows=np.nonzero(partition.parts[0] == m)[0],
                weight_nnz=weight_nnz, max_needed=max_needed, max_out=max_out,
            )
        )
    return out


# ---------------------------------------------------------------------------
# shared send/recv building blocks (per-worker and fleet modes)
# ---------------------------------------------------------------------------


def _empty_marker(layer: int, src: int, batch: int) -> Chunk:
    from repro.faas.payload import encode_chunk

    blob = encode_chunk(
        layer, src, np.zeros(0, np.int32), np.zeros((0, batch), np.float32), 0, 1
    )
    return Chunk(blob, raw_bytes=24)


def _send_jobs(
    art: WorkerLayerArtifact, x_prev: np.ndarray, rank: int,
    exploit_sparsity: bool,
) -> Tuple[List[tuple], List[int]]:
    """Per-target ``(layer, src, rows, vals)`` pack jobs for one worker.

    Activation-sparsity exploitation (paper §III-C2): rows of x^{k-1} that
    are entirely zero carry no information — the receive buffer is
    zero-initialized — so they are dropped from the payload.  The keep mask
    is computed ONCE over the worker's whole panel and gathered per target:
    one panel pass instead of |targets| sliced scans.
    """
    targets = sorted(art.send_global)
    if not targets:
        return [], []
    keep_mask = np.any(x_prev != 0.0, axis=1) if exploit_sparsity else None
    jobs: List[tuple] = []
    for target in targets:
        rows = art.send_global[target]
        posn = art.send_positions[target]
        if keep_mask is None:
            vals = x_prev[posn]
        else:
            k = keep_mask[posn]
            rows, vals = rows[k], x_prev[posn[k]]
        jobs.append((art.layer, rank, rows, vals))
    return jobs, targets


def _collect_entries(
    art: WorkerLayerArtifact, rank: int, batch: int,
    packed: Sequence[Tuple[int, List[Chunk]]],
) -> Tuple[List[Tuple[int, Chunk]], int]:
    """(target, chunk) publish entries + raw-byte total for one worker; a
    target whose payload packed to nothing still gets the per-source
    completion marker (an empty byte string with total=1 — the paper's
    message-attribute handling of multi-message sends)."""
    entries: List[Tuple[int, Chunk]] = []
    raw_total = 0
    for target, chunks in packed:
        if not chunks:
            chunks = [_empty_marker(art.layer, rank, batch)]
        for c in chunks:
            entries.append((target, c))
            raw_total += c.raw_bytes
    return entries, raw_total


def _charge_pack_event(worker: WorkerState, compute: ComputeModel,
                       raw_total: int) -> None:
    """Pack/serialize event: compute-side on both clock models (the payload
    must exist before any lane can send it)."""
    pack_s = raw_total / compute.pack_bandwidth * worker.slowdown
    worker.charge_seconds(pack_s)
    if worker.ledger is not None:
        worker.ledger.compute(pack_s)


def _batch_publish_entries(
    entries: List[Tuple[int, Chunk]], pricing,
) -> List[List[Tuple[int, Chunk]]]:
    """Greedy batching under the SNS caps (≤10 messages, ≤256KB payload)."""
    batches: List[List[Tuple[int, Chunk]]] = []
    cur: List[Tuple[int, Chunk]] = []
    cur_bytes = 0
    for target, c in entries:
        if cur and (
            len(cur) >= pricing.max_messages_per_publish
            or cur_bytes + len(c) > pricing.max_publish_payload
        ):
            batches.append(cur)
            cur, cur_bytes = [], 0
        cur.append((target, c))
        cur_bytes += len(c)
    if cur:
        batches.append(cur)
    return batches


def _queue_publish_entries(
    entries: List[Tuple[int, Chunk]], worker: WorkerState, fabric: QueueFabric,
    compute: ComputeModel, raw_total: int, send_threads: int,
) -> None:
    """The layer send as two schedulable events: the pack event (compute
    timeline), then one aggregated publish event — ALL of the worker's
    per-peer entries batched under the SNS caps and issued round-robin over
    ``send_threads`` lanes in a single fabric interaction (one publish API
    call per ≤10-message batch, not one per destination peer).

    On the overlapped ledger the publish occupies the channel timeline,
    gated on the pack completion; the subsequent local MVP then runs on the
    compute timeline concurrently with the in-flight lanes."""
    _charge_pack_event(worker, compute, raw_total)
    batches = _batch_publish_entries(entries, fabric.pricing)
    if batches:
        led = worker.ledger
        if led is None:
            lane_time = fabric.publish_batches(
                topic=worker.rank % fabric.n_topics, batches=batches,
                at_time=worker.abs_time, lanes=send_threads,
            )
        else:
            lane_time, led_lanes = fabric.publish_batches(
                topic=worker.rank % fabric.n_topics, batches=batches,
                at_time=worker.abs_time, lanes=send_threads,
                ledger_at=max(led.t_channel, led.t_compute),
            )
            led.t_channel = max(led_lanes)
        worker.messages_sent += sum(len(b) for b in batches)
        worker.bytes_sent += sum(len(c) for b in batches for _, c in b)
        worker.advance_to_abs(max(lane_time))


def _object_put_targets(
    art: WorkerLayerArtifact, rank: int,
    packed: Sequence[Tuple[int, List[Chunk]]], worker: WorkerState,
    fabric: ObjectFabric, compute: ComputeModel, io_threads: int,
) -> None:
    """One object (or 0-byte ``.nul`` marker) per target, round-robin over
    ``io_threads`` connections, then the pack-time charge.

    Event split mirrors the queue path: on the overlapped ledger the pack is
    a compute event and the PUT schedule occupies the channel timeline gated
    on it (phased billing keeps its original charge order — the totals are
    order-independent)."""
    target_blobs = [(t, chunks if chunks else []) for t, chunks in packed]
    raw_total = sum(c.raw_bytes for _, chunks in target_blobs for c in chunks)
    led = worker.ledger
    if led is None:
        lane_time = fabric.put_multiparts(
            art.layer, rank, target_blobs, worker.abs_time, lanes=io_threads
        )
        worker.charge_seconds(raw_total / compute.pack_bandwidth * worker.slowdown)
    else:
        # ledger: pack first (the PUT needs its payload), then the lanes
        pack_s = raw_total / compute.pack_bandwidth * worker.slowdown
        led.compute(pack_s)
        lane_time, led_lanes = fabric.put_multiparts(
            art.layer, rank, target_blobs, worker.abs_time, lanes=io_threads,
            ledger_at=max(led.t_channel, led.t_compute),
        )
        if target_blobs:
            led.t_channel = max(led_lanes)
        worker.charge_seconds(pack_s)
    worker.messages_sent += len(target_blobs)
    worker.bytes_sent += sum(
        len(c) for _, chunks in target_blobs for c in chunks
    )
    if target_blobs:
        worker.advance_to_abs(max(lane_time))


@dataclasses.dataclass
class FleetRecvBuffers:
    """One layer's receive buffers for the whole fleet, backed by a single
    flat panel so the fleet drain lands every decoded chunk with one
    vectorized scatter.  ``views[m]`` aliases worker ``m``'s compact input
    buffer (rows = ``arts[m].needed_rows``)."""

    flat: np.ndarray                 # f32[sum(needed_m), batch]
    offsets: np.ndarray              # i64[P+1] row offsets into flat
    views: List[np.ndarray]

    @classmethod
    def allocate(cls, arts: Sequence[WorkerLayerArtifact], batch: int
                 ) -> "FleetRecvBuffers":
        sizes = np.array([len(a.needed_rows) for a in arts], dtype=np.int64)
        offsets = np.zeros(len(arts) + 1, dtype=np.int64)
        np.cumsum(sizes, out=offsets[1:])
        flat = np.zeros((int(offsets[-1]), batch), dtype=np.float32)
        views = [flat[offsets[m]: offsets[m + 1]] for m in range(len(arts))]
        return cls(flat=flat, offsets=offsets, views=views)


def _fleet_local_overlap(
    arts: Sequence[WorkerLayerArtifact], x_panels: Sequence[np.ndarray],
    workers: Sequence[WorkerState], compute: ComputeModel, batch: int,
) -> FleetRecvBuffers:
    """Line 8 / line 9 for the whole fleet: one allocation + one scatter of
    every worker's locally-owned rows, then the per-worker local-MVP charge."""
    fb = FleetRecvBuffers.allocate(arts, batch)
    pos = [fb.offsets[m] + art.owned_positions
           for m, art in enumerate(arts) if art.owned_positions.size]
    if pos:
        vals = [x_panels[m][art.owned_source_positions]
                for m, art in enumerate(arts) if art.owned_positions.size]
        fb.flat[np.concatenate(pos)] = np.vstack(vals)
    for art, worker in zip(arts, workers):
        worker.charge_compute(art.local_flops * batch, compute)
    return fb


# ---------------------------------------------------------------------------
# Algorithm 1 — FSI with FSD-Inf-Queue
# ---------------------------------------------------------------------------


def fsi_queue_send_and_local(
    art: WorkerLayerArtifact,
    x_prev: np.ndarray,              # local panel of owned x^{k-1} rows
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    *,
    send_threads: int = 8,
    exploit_sparsity: bool = True,
) -> np.ndarray:
    """Algorithm 1 lines 3-8 for one worker: publish + overlapped local MVP.

    Returns the partially-filled compact input buffer; the recv half runs
    after every worker has entered its send phase (the real system's workers
    run concurrently — the simulator phases them to stay deterministic).
    """
    batch = x_prev.shape[1] if x_prev.ndim == 2 else 1
    # ---- lines 3-7: extract rows, pack byte strings, publish batches -------
    jobs, targets = _send_jobs(art, x_prev, worker.rank, exploit_sparsity)
    packed = list(zip(targets, pack_rows_fleet(
        jobs, fabric.pricing.max_publish_payload)))
    entries, raw_total = _collect_entries(art, worker.rank, batch, packed)
    _queue_publish_entries(entries, worker, fabric, compute, raw_total,
                           send_threads)

    # ---- line 8: local MVP overlapped with in-flight communication --------
    x_buf = np.zeros((len(art.needed_rows), batch), dtype=np.float32)
    x_buf[art.owned_positions] = x_prev[art.owned_source_positions]
    worker.charge_compute(art.local_flops * batch, compute)
    return x_buf


def fsi_queue_send_and_local_fleet(
    arts: Sequence[WorkerLayerArtifact],
    x_panels: Sequence[np.ndarray],
    workers: Sequence[WorkerState],
    fabric: QueueFabric,
    compute: ComputeModel,
    *,
    send_threads: int = 8,
    exploit_sparsity: bool = True,
) -> FleetRecvBuffers:
    """Algorithm 1 lines 3-8 for the WHOLE fleet: every worker's outgoing
    row-sets are packed in one ``pack_rows_fleet`` call (shared normalization
    + one deflate-state pool), then each worker publishes its own batches in
    rank order — byte streams, publish batching, and clock charges are
    bit-identical to P ``fsi_queue_send_and_local`` calls."""
    batch = x_panels[0].shape[1]
    jobs: List[tuple] = []
    fleet_targets: List[List[int]] = []
    for art, x_prev, worker in zip(arts, x_panels, workers):
        wjobs, targets = _send_jobs(art, x_prev, worker.rank, exploit_sparsity)
        jobs.extend(wjobs)
        fleet_targets.append(targets)
    packed_iter = pack_rows_fleet(jobs, fabric.pricing.max_publish_payload)
    for art, worker, targets in zip(arts, workers, fleet_targets):
        packed = [(t, next(packed_iter)) for t in targets]
        entries, raw_total = _collect_entries(art, worker.rank, batch, packed)
        _queue_publish_entries(entries, worker, fabric, compute, raw_total,
                               send_threads)
    return _fleet_local_overlap(arts, x_panels, workers, compute, batch)


def charge_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    x_out: np.ndarray,
    worker: WorkerState,
    compute: ComputeModel,
) -> np.ndarray:
    """Bill the layer-finish work (remote-contribution MVP + epilogue).

    The charges are derived from the CSR shard (2·nnz FLOPs + 3 ops/output),
    NOT from what the host backend actually executed — billed time is the
    modeled Lambda's, identical across compute backends by construction.
    """
    batch = x_buf.shape[1]
    if worker.ledger is not None:
        # dependency edge: the remote-contribution MVP needs the drain done
        worker.ledger.join_compute()
    worker.charge_compute(art.remote_flops * batch, compute)
    worker.charge_compute(3.0 * x_out.size, compute)
    worker.touch_memory((x_buf.nbytes + x_out.nbytes) + art.W_local.nnz * 8)
    return x_out.astype(np.float32, copy=False)


def finish_layer(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Lines 16-18 / 21-23: accumulate contributions + fused activation."""
    backend = get_backend(backend)
    x_out = backend.apply(art.state_for(backend), x_buf, bias)
    return charge_finish(art, x_buf, x_out, worker, compute)


def _queue_drain_one(
    art: WorkerLayerArtifact,
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    emit: Callable[[np.ndarray, np.ndarray], None],
    *,
    receipts_out: Optional[List[int]] = None,
) -> None:
    """Algorithm 1 lines 9-15 for one worker: long-poll until every source
    completes, handing each fresh chunk's (buffer positions, value view) to
    ``emit``.  The per-worker and fleet drains share this loop, so the
    (src, seq) dedupe and stale-layer handling cannot diverge.

    ``receipts_out`` defers the receipt deletes: instead of a
    DeleteMessageBatch per poll iteration, receipts are appended to the
    given list and the caller commits (or abandons — the crash-injection
    path) them after the drain.  This is how a ``drain``-phase crash leaves
    its messages in flight to redeliver after the visibility timeout."""
    # Completion is per-source via the 'total byte strings' message attribute
    # (paper: "we cater for the case where source P_n needs to send multiple
    # messages ... using message attributes"), since activation sparsity
    # makes the delivered row count data-dependent.
    pending = set(art.recv_expect)  # sources that will definitely send
    seen_chunks: set[tuple[int, int]] = set()  # (src, seq) — dedupe redeliveries
    got_chunks: Dict[int, int] = {}
    while pending:
        now, deliveries = fabric.poll(worker.rank, worker.abs_time, long_poll=True)
        worker.advance_to_abs(now)
        receipts = []
        for d in deliveries:
            layer, src, rows, vals, seq, total = decode_chunk(bytes(d.blob))
            unpack_s = len(d.blob) / compute.unpack_bandwidth * worker.slowdown
            worker.charge_seconds(unpack_s)
            if worker.ledger is not None:
                # receiver thread: the chunk is in hand at its service-side
                # availability on the sender's ledger; only the decode cost
                # occupies the channel timeline (deletes are fire-and-forget
                # trailing work, off the critical path).  Under eager polling
                # the receive gates on the eager stamp (the poll was already
                # parked when the publish landed).
                avail = worker.ledger.recv_available(
                    d.ledger_at if d.ledger_at is not None else d.deliver_at,
                    d.ledger_eager_at)
                worker.ledger.receive(avail, unpack_s)
            worker.messages_received += 1
            worker.bytes_received += len(d.blob)
            receipts.append(d.receipt)
            if layer != art.layer:
                if layer < art.layer:
                    # stale redelivery of an already-completed layer's chunk
                    # (at-least-once): retire the receipt, touch nothing
                    continue
                raise AssertionError("cross-layer message leakage")
            # SQS is at-least-once: the same (src, seq) chunk may be
            # redelivered.  Writes are idempotent (row-addressed assignment),
            # but completion counting must not be — a duplicate counted
            # toward ``total`` would retire the source before its remaining
            # chunks arrive.
            if (src, seq) in seen_chunks:
                continue
            seen_chunks.add((src, seq))
            if rows.size:
                emit(np.searchsorted(art.needed_rows, rows), vals)
            got_chunks[src] = got_chunks.get(src, 0) + 1
            if src in pending and got_chunks[src] >= total:
                pending.discard(src)
        if receipts_out is not None:
            receipts_out.extend(receipts)
        elif receipts:
            worker.advance_to_abs(fabric.delete_batch(worker.rank, receipts, worker.abs_time))


def fsi_queue_recv(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    *,
    receipts_out: Optional[List[int]] = None,
) -> np.ndarray:
    """Algorithm 1 lines 9-15 for one worker: long-poll until the buffer is
    complete (compute deferred — see ``finish_layer``)."""
    def emit(pos: np.ndarray, vals: np.ndarray) -> None:
        x_buf[pos] = vals            # the one copy of the zero-copy views

    _queue_drain_one(art, worker, fabric, compute, emit,
                     receipts_out=receipts_out)
    return x_buf


def fsi_queue_recv_fleet(
    arts: Sequence[WorkerLayerArtifact],
    bufs: FleetRecvBuffers,
    workers: Sequence[WorkerState],
    fabric: QueueFabric,
    compute: ComputeModel,
) -> List[np.ndarray]:
    """Fleet drain (Algorithm 1 lines 9-15 × P): every worker's queue is
    drained with the shared dedupe loop, but decoded chunks are accumulated
    as (global position, value view) pairs and land in ONE vectorized
    scatter into the flat fleet buffer — the single copy the zero-copy
    ``decode_chunk`` views ever see."""
    pos_parts: List[np.ndarray] = []
    val_parts: List[np.ndarray] = []
    for m, (art, worker) in enumerate(zip(arts, workers)):
        off = int(bufs.offsets[m])

        def emit(pos: np.ndarray, vals: np.ndarray, _off=off) -> None:
            pos_parts.append(_off + pos)
            val_parts.append(vals)

        _queue_drain_one(art, worker, fabric, compute, emit)
    if pos_parts:
        # positions are unique fleet-wide: workers' buffers are disjoint
        # slices, sources own disjoint row sets, and (src, seq) dedupe keeps
        # each chunk once — so one fancy-index assignment is exact.
        bufs.flat[np.concatenate(pos_parts)] = np.vstack(val_parts)
    return bufs.views


def fsi_queue_recv_and_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Algorithm 1 lines 9-18 for one worker: poll, accumulate, activate."""
    x_buf = fsi_queue_recv(art, x_buf, worker, fabric, compute)
    # ---- lines 16-18: accumulate contributions + activation ---------------
    return finish_layer(art, x_buf, worker, compute, bias, backend)


# ---------------------------------------------------------------------------
# Algorithm 2 — FSI with FSD-Inf-Object
# ---------------------------------------------------------------------------


def fsi_object_send_and_local(
    art: WorkerLayerArtifact,
    x_prev: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
    *,
    io_threads: int = 8,
    max_object_part: int = 8 * 1024 * 1024,
    exploit_sparsity: bool = True,
) -> np.ndarray:
    """Algorithm 2 lines 3-9 for one worker: non-blocking PUTs + local MVP."""
    batch = x_prev.shape[1] if x_prev.ndim == 2 else 1
    # ---- lines 3-8: one object (or .nul) per target ------------------------
    # Empty payloads (all mapped rows zero under activation sparsity) become
    # 0-byte `.nul` markers, which readers retire without a GET (lines 4-5).
    jobs, targets = _send_jobs(art, x_prev, worker.rank, exploit_sparsity)
    packed = list(zip(targets, pack_rows_fleet(jobs, max_object_part)))
    _object_put_targets(art, worker.rank, packed, worker, fabric, compute,
                        io_threads)

    # ---- line 9: local MVP overlap -----------------------------------------
    x_buf = np.zeros((len(art.needed_rows), batch), dtype=np.float32)
    x_buf[art.owned_positions] = x_prev[art.owned_source_positions]
    worker.charge_compute(art.local_flops * batch, compute)
    return x_buf


def fsi_object_send_and_local_fleet(
    arts: Sequence[WorkerLayerArtifact],
    x_panels: Sequence[np.ndarray],
    workers: Sequence[WorkerState],
    fabric: ObjectFabric,
    compute: ComputeModel,
    *,
    io_threads: int = 8,
    max_object_part: int = 8 * 1024 * 1024,
    exploit_sparsity: bool = True,
) -> FleetRecvBuffers:
    """Algorithm 2 lines 3-9 for the whole fleet: one batched pack, then each
    worker's PUTs in rank order — billing-identical to the per-worker path."""
    batch = x_panels[0].shape[1]
    jobs: List[tuple] = []
    fleet_targets: List[List[int]] = []
    for art, x_prev, worker in zip(arts, x_panels, workers):
        wjobs, targets = _send_jobs(art, x_prev, worker.rank, exploit_sparsity)
        jobs.extend(wjobs)
        fleet_targets.append(targets)
    packed_iter = pack_rows_fleet(jobs, max_object_part)
    for art, worker, targets in zip(arts, workers, fleet_targets):
        packed = [(t, next(packed_iter)) for t in targets]
        _object_put_targets(art, worker.rank, packed, worker, fabric, compute,
                            io_threads)
    return _fleet_local_overlap(arts, x_panels, workers, compute, batch)


def _object_drain_one(
    art: WorkerLayerArtifact,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
    emit: Callable[[np.ndarray, np.ndarray], None],
) -> None:
    """Algorithm 2 lines 10-20 for one worker: LIST/GET until the recv map is
    satisfied, handing each part's (positions, value view) to ``emit``."""
    expect = dict(art.recv_expect)
    seen: set[str] = set()
    while expect:
        now, handles = fabric.list_files(art.layer, worker.rank, worker.abs_time)
        worker.advance_to_abs(now)
        progress = False
        for h in handles:
            if h.key in seen:
                continue
            if h.src not in expect:
                continue  # line 16: already received / not awaited — no GET
            seen.add(h.key)
            led_avail = (h.ledger_visible_at if h.ledger_visible_at is not None
                         else h.visible_at)
            if worker.ledger is not None:
                led_avail = worker.ledger.recv_available(
                    led_avail, h.ledger_eager_visible_at)
            if h.is_nul:
                if worker.ledger is not None:
                    # the reader must still observe the marker appear
                    worker.ledger.receive(led_avail, 0.0)
                del expect[h.src]  # line 13-14: retire source, never read
                progress = True
                continue
            now, blob = fabric.get_obj(art.layer, worker.rank, h.key, worker.abs_time)
            worker.advance_to_abs(now)
            unpack_s = len(blob) / compute.unpack_bandwidth * worker.slowdown
            worker.charge_seconds(unpack_s)
            if worker.ledger is not None:
                # reader thread: GET stream + decode, gated on the object's
                # ledger visibility (LIST polling is folded into the blocked
                # reader loop, like the queue path's long poll)
                worker.ledger.receive(
                    led_avail,
                    fabric.get_first_byte + h.size / fabric.bandwidth + unpack_s,
                )
            worker.messages_received += 1
            worker.bytes_received += len(blob)
            for part in ObjectFabric.split_multipart(bytes(blob)):
                layer, src, rows, vals, _, _ = decode_chunk(part)
                emit(np.searchsorted(art.needed_rows, rows), vals)
            del expect[h.src]
            progress = True
        if expect and not progress:
            # back off one LIST interval before re-scanning the prefix
            worker.charge_seconds(fabric.list_latency)


def fsi_object_recv(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
) -> np.ndarray:
    """Algorithm 2 lines 10-20 for one worker: LIST/GET until the recv map is
    satisfied (compute deferred — see ``finish_layer``)."""
    def emit(pos: np.ndarray, vals: np.ndarray) -> None:
        x_buf[pos] = vals

    _object_drain_one(art, worker, fabric, compute, emit)
    return x_buf


def fsi_object_recv_fleet(
    arts: Sequence[WorkerLayerArtifact],
    bufs: FleetRecvBuffers,
    workers: Sequence[WorkerState],
    fabric: ObjectFabric,
    compute: ComputeModel,
) -> List[np.ndarray]:
    """Fleet drain (Algorithm 2 lines 10-20 × P) with one vectorized scatter
    into the flat fleet buffer — the object twin of ``fsi_queue_recv_fleet``."""
    pos_parts: List[np.ndarray] = []
    val_parts: List[np.ndarray] = []
    for m, (art, worker) in enumerate(zip(arts, workers)):
        off = int(bufs.offsets[m])

        def emit(pos: np.ndarray, vals: np.ndarray, _off=off) -> None:
            pos_parts.append(_off + pos)
            val_parts.append(vals)

        _object_drain_one(art, worker, fabric, compute, emit)
    if pos_parts:
        bufs.flat[np.concatenate(pos_parts)] = np.vstack(val_parts)
    return bufs.views


def fsi_object_recv_and_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Algorithm 2 lines 10-23 for one worker: LIST/GET, accumulate, activate."""
    x_buf = fsi_object_recv(art, x_buf, worker, fabric, compute)
    # ---- lines 21-23: accumulate + activation -------------------------------
    return finish_layer(art, x_buf, worker, compute, bias, backend)


# ---------------------------------------------------------------------------
# FSD-Inf-Serial
# ---------------------------------------------------------------------------


def run_serial(
    net: GraphChallengeNet,
    x0: np.ndarray,
    memory_mb: int = 10240,
    compute: ComputeModel | None = None,
    backend: Union[str, ComputeBackend, None] = None,
) -> tuple[np.ndarray, WorkerState]:
    """Single-instance execution (Algorithm 1 with communication removed)."""
    compute = compute or ComputeModel()
    backend = get_backend(backend)
    batch = x0.shape[1]
    need = estimate_worker_memory_bytes(
        net.total_nnz, net.neurons, net.neurons, batch
    )
    if need > memory_mb * 1024 * 1024:
        raise MemoryError(
            f"FSD-Inf-Serial needs ~{need/1e9:.1f}GB > {memory_mb}MB Lambda limit"
        )
    # offline artifact prep (unbilled, like the distributed path's maps)
    states = [backend.prepare(W) for W in net.layers]
    w = WorkerState(rank=0, memory_mb=memory_mb)
    x = x0.astype(np.float32)
    for W, state in zip(net.layers, states):
        x = backend.apply(state, x, net.bias).astype(np.float32, copy=False)
        w.charge_compute(2.0 * W.nnz * batch + 3.0 * x.size, compute)
    w.touch_memory(need)
    return x, w
