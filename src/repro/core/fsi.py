"""FSI — Fully Serverless Inference (paper Algorithms 1 & 2).

This module contains the exact per-layer logic both channels share:

* offline artifact preparation (the paper's "reads its share of the model
  weights, inference data and per-layer send and receive maps"),
* Algorithm 1 (FSD-Inf-Queue): pack → publish batches → local MVP overlap →
  long-poll → deserialize → accumulate → activation,
* Algorithm 2 (FSD-Inf-Object): per-target single object (or `.nul`) → local
  MVP overlap → LIST/GET loop → accumulate → activation,
* the Serial variant (whole model on one worker, no channel).

The math is executed for real (numpy), byte streams are really compressed
and size-capped, and the clock/billing charges follow the algorithm order —
including the compute/communication overlap the paper exploits (local MVP is
charged *between* the sends and the receives).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Literal, Sequence, Union

import numpy as np

from repro.core.backends import ComputeBackend, get_backend
from repro.core.partitioner import PartitionResult
from repro.core.send_recv import LayerCommPlan
from repro.core.sparse import CSRMatrix
from repro.data.graphchallenge import GraphChallengeNet
from repro.faas.object_service import ObjectFabric
from repro.faas.payload import Chunk, decode_chunk, pack_rows
from repro.faas.queue_service import QueueFabric
from repro.faas.worker import ComputeModel, WorkerState, estimate_worker_memory_bytes

__all__ = [
    "WorkerLayerArtifact",
    "WorkerArtifacts",
    "prepare_worker_artifacts",
    "fsi_queue_send_and_local",
    "fsi_queue_recv",
    "fsi_queue_recv_and_finish",
    "fsi_object_send_and_local",
    "fsi_object_recv",
    "fsi_object_recv_and_finish",
    "finish_layer",
    "charge_finish",
    "run_serial",
]

Channel = Literal["queue", "object"]


@dataclasses.dataclass
class WorkerLayerArtifact:
    """Worker ``m``'s offline-prepared share of layer ``k``."""

    layer: int
    W_local: CSRMatrix              # rows = owned out rows, cols = positions in needed_rows
    out_rows: np.ndarray            # global x^k row ids produced here (sorted)
    needed_rows: np.ndarray         # global x^{k-1} row ids required (sorted)
    owned_positions: np.ndarray     # positions of locally-owned inputs in needed_rows
    owned_source_positions: np.ndarray  # positions of those rows in the local x^{k-1} panel
    send_global: Dict[int, np.ndarray]   # target → global row ids
    send_positions: Dict[int, np.ndarray]  # target → positions in local x^{k-1} panel
    recv_expect: Dict[int, int]     # source → number of rows expected
    recv_positions: Dict[int, np.ndarray]  # source → positions in needed_rows
    local_flops: float              # 2·nnz over owned-input columns · batch≈ charged pre-recv
    remote_flops: float             # remainder, charged as contributions arrive
    # per-backend offline compute artifacts (e.g. padded BSR operands),
    # lazily populated; keyed by the backend's state_key (name + config, so
    # two differently-configured instances of one backend never share state)
    backend_states: Dict[str, Any] = dataclasses.field(
        default_factory=dict, repr=False
    )

    def state_for(self, backend: ComputeBackend) -> Any:
        key = getattr(backend, "state_key", backend.name)
        state = self.backend_states.get(key)
        if state is None:
            state = self.backend_states[key] = backend.prepare(self.W_local)
        return state


@dataclasses.dataclass
class WorkerArtifacts:
    rank: int
    layers: List[WorkerLayerArtifact]
    x0_rows: np.ndarray             # global input rows owned (sorted)
    weight_nnz: int
    max_needed: int
    max_out: int

    def memory_bytes(self, batch: int) -> int:
        return estimate_worker_memory_bytes(
            self.weight_nnz, self.max_needed, self.max_out, batch
        )


def prepare_worker_artifacts(
    layers: Sequence[CSRMatrix],
    partition: PartitionResult,
    plans: Sequence[LayerCommPlan],
    backend: Union[str, ComputeBackend, None] = None,
) -> List[WorkerArtifacts]:
    """Offline post-processing of the trained model (paper: hypergraph
    partitioning and map construction happen a priori, not per request).

    When ``backend`` is given, its per-worker-layer compute artifacts (e.g.
    the Pallas backend's padded BSR operands) are prepared here too — this is
    offline work, so it is never billed to a worker clock.
    """
    backend = get_backend(backend) if backend is not None else None
    P = partition.P
    out: List[WorkerArtifacts] = []
    for m in range(P):
        arts: List[WorkerLayerArtifact] = []
        weight_nnz = 0
        max_needed = max_out = 0
        prev_owned = np.nonzero(partition.parts[0] == m)[0]
        for k, W in enumerate(layers):
            wp = plans[k].workers[m]
            needed = wp.needed_rows
            out_rows = wp.owned_out_rows
            W_rows = W.select_rows(out_rows)
            # remap columns into the compact needed-space
            col_pos = np.searchsorted(needed, W_rows.indices)
            if needed.size:
                ok = (col_pos < needed.size) & (needed[np.minimum(col_pos, needed.size - 1)] == W_rows.indices)
                if not np.all(ok):
                    raise AssertionError("needed_rows misses a referenced column")
            W_local = CSRMatrix(
                shape=(len(out_rows), len(needed)),
                indptr=W_rows.indptr,
                indices=col_pos.astype(np.int32),
                data=W_rows.data,
            )
            owned_in = np.intersect1d(prev_owned, needed)
            owned_positions = np.searchsorted(needed, owned_in)
            owned_source_positions = np.searchsorted(prev_owned, owned_in)
            send_positions = {
                t: np.searchsorted(prev_owned, rows) for t, rows in wp.send.items()
            }
            recv_positions = {
                s: np.searchsorted(needed, rows) for s, rows in wp.recv.items()
            }
            # flops split for the overlap charging
            nnz_per_col = np.bincount(W_local.indices, minlength=len(needed))
            local_nnz = int(nnz_per_col[owned_positions].sum()) if len(needed) else 0
            arts.append(
                art := WorkerLayerArtifact(
                    layer=k,
                    W_local=W_local,
                    out_rows=out_rows,
                    needed_rows=needed,
                    owned_positions=owned_positions,
                    owned_source_positions=owned_source_positions,
                    send_global=dict(wp.send),
                    send_positions=send_positions,
                    recv_expect={s: len(r) for s, r in wp.recv.items()},
                    recv_positions=recv_positions,
                    local_flops=2.0 * local_nnz,
                    remote_flops=2.0 * (W_local.nnz - local_nnz),
                )
            )
            if backend is not None:
                art.state_for(backend)
            weight_nnz += W_local.nnz
            max_needed = max(max_needed, len(needed))
            max_out = max(max_out, len(out_rows))
            prev_owned = out_rows
        out.append(
            WorkerArtifacts(
                rank=m, layers=arts, x0_rows=np.nonzero(partition.parts[0] == m)[0],
                weight_nnz=weight_nnz, max_needed=max_needed, max_out=max_out,
            )
        )
    return out


# ---------------------------------------------------------------------------
# Algorithm 1 — FSI with FSD-Inf-Queue
# ---------------------------------------------------------------------------


def _nonzero_row_subset(rows: np.ndarray, vals: np.ndarray):
    """Activation-sparsity exploitation (paper §III-C2): rows of x^{k-1} that
    are entirely zero carry no information — the receive buffer is
    zero-initialized — so they are dropped from the payload."""
    keep = np.any(vals != 0.0, axis=1)
    return rows[keep], vals[keep]


def _empty_marker(layer: int, src: int, batch: int) -> Chunk:
    from repro.faas.payload import encode_chunk

    blob = encode_chunk(
        layer, src, np.zeros(0, np.int32), np.zeros((0, batch), np.float32), 0, 1
    )
    return Chunk(blob, raw_bytes=24)


def fsi_queue_send_and_local(
    art: WorkerLayerArtifact,
    x_prev: np.ndarray,              # local panel of owned x^{k-1} rows
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    *,
    send_threads: int = 8,
    exploit_sparsity: bool = True,
) -> np.ndarray:
    """Algorithm 1 lines 3-8 for one worker: publish + overlapped local MVP.

    Returns the partially-filled compact input buffer; the recv half runs
    after every worker has entered its send phase (the real system's workers
    run concurrently — the simulator phases them to stay deterministic).
    """
    batch = x_prev.shape[1] if x_prev.ndim == 2 else 1
    # ---- lines 3-7: extract rows, pack byte strings, publish batches -------
    entries: List[tuple[int, Chunk]] = []
    raw_total = 0
    for target in sorted(art.send_global):
        rows = art.send_global[target]
        vals = x_prev[art.send_positions[target]]
        if exploit_sparsity:
            rows, vals = _nonzero_row_subset(rows, vals)
        chunks = pack_rows(
            art.layer, worker.rank, rows, vals, fabric.pricing.max_publish_payload
        )
        if not chunks:
            # the target still awaits a per-source completion signal: an
            # empty byte string with total=1 (message attributes carry the
            # expected count, exactly the paper's multi-message handling)
            chunks = [_empty_marker(art.layer, worker.rank, batch)]
        for c in chunks:
            entries.append((target, c))
            raw_total += c.raw_bytes
    worker.charge_seconds(raw_total / compute.pack_bandwidth * worker.slowdown)
    # batch entries: ≤10 messages and ≤256KB per publish; round-robin threads
    batches: List[List[tuple[int, Chunk]]] = []
    cur: List[tuple[int, Chunk]] = []
    cur_bytes = 0
    for target, c in entries:
        if cur and (
            len(cur) >= fabric.pricing.max_messages_per_publish
            or cur_bytes + len(c) > fabric.pricing.max_publish_payload
        ):
            batches.append(cur)
            cur, cur_bytes = [], 0
        cur.append((target, c))
        cur_bytes += len(c)
    if cur:
        batches.append(cur)
    lane_time = [worker.abs_time] * max(1, send_threads)
    for i, b in enumerate(batches):
        lane = i % len(lane_time)
        lane_time[lane] = fabric.publish_batch(
            topic=worker.rank % fabric.n_topics, entries=b, at_time=lane_time[lane]
        )
        worker.messages_sent += len(b)
        worker.bytes_sent += sum(len(c) for _, c in b)
    if batches:
        worker.advance_to_abs(max(lane_time))

    # ---- line 8: local MVP overlapped with in-flight communication --------
    x_buf = np.zeros((len(art.needed_rows), batch), dtype=np.float32)
    x_buf[art.owned_positions] = x_prev[art.owned_source_positions]
    worker.charge_compute(art.local_flops * batch, compute)
    return x_buf


def charge_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    x_out: np.ndarray,
    worker: WorkerState,
    compute: ComputeModel,
) -> np.ndarray:
    """Bill the layer-finish work (remote-contribution MVP + epilogue).

    The charges are derived from the CSR shard (2·nnz FLOPs + 3 ops/output),
    NOT from what the host backend actually executed — billed time is the
    modeled Lambda's, identical across compute backends by construction.
    """
    batch = x_buf.shape[1]
    worker.charge_compute(art.remote_flops * batch, compute)
    worker.charge_compute(3.0 * x_out.size, compute)
    worker.touch_memory((x_buf.nbytes + x_out.nbytes) + art.W_local.nnz * 8)
    return x_out.astype(np.float32, copy=False)


def finish_layer(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Lines 16-18 / 21-23: accumulate contributions + fused activation."""
    backend = get_backend(backend)
    x_out = backend.apply(art.state_for(backend), x_buf, bias)
    return charge_finish(art, x_buf, x_out, worker, compute)


def fsi_queue_recv(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
) -> np.ndarray:
    """Algorithm 1 lines 9-15 for one worker: long-poll until the buffer is
    complete (compute deferred — see ``finish_layer``)."""
    # ---- lines 9-15: long-poll until every source completes ----------------
    # Completion is per-source via the 'total byte strings' message attribute
    # (paper: "we cater for the case where source P_n needs to send multiple
    # messages ... using message attributes"), since activation sparsity
    # makes the delivered row count data-dependent.
    pending = set(art.recv_expect)  # sources that will definitely send
    seen_chunks: set[tuple[int, int]] = set()  # (src, seq) — dedupe redeliveries
    got_chunks: Dict[int, int] = {}
    while pending:
        now, deliveries = fabric.poll(worker.rank, worker.abs_time, long_poll=True)
        worker.advance_to_abs(now)
        receipts = []
        for d in deliveries:
            layer, src, rows, vals, seq, total = decode_chunk(bytes(d.blob))
            worker.charge_seconds(len(d.blob) / compute.unpack_bandwidth * worker.slowdown)
            worker.messages_received += 1
            worker.bytes_received += len(d.blob)
            receipts.append(d.receipt)
            if layer != art.layer:
                if layer < art.layer:
                    # stale redelivery of an already-completed layer's chunk
                    # (at-least-once): retire the receipt, touch nothing
                    continue
                raise AssertionError("cross-layer message leakage")
            # SQS is at-least-once: the same (src, seq) chunk may be
            # redelivered.  Writes are idempotent (row-addressed assignment),
            # but completion counting must not be — a duplicate counted
            # toward ``total`` would retire the source before its remaining
            # chunks arrive.
            if (src, seq) in seen_chunks:
                continue
            seen_chunks.add((src, seq))
            if rows.size:
                pos = np.searchsorted(art.needed_rows, rows)
                x_buf[pos] = vals
            got_chunks[src] = got_chunks.get(src, 0) + 1
            if src in pending and got_chunks[src] >= total:
                pending.discard(src)
        if receipts:
            worker.advance_to_abs(fabric.delete_batch(worker.rank, receipts, worker.abs_time))
    return x_buf


def fsi_queue_recv_and_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: QueueFabric,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Algorithm 1 lines 9-18 for one worker: poll, accumulate, activate."""
    x_buf = fsi_queue_recv(art, x_buf, worker, fabric, compute)
    # ---- lines 16-18: accumulate contributions + activation ---------------
    return finish_layer(art, x_buf, worker, compute, bias, backend)


# ---------------------------------------------------------------------------
# Algorithm 2 — FSI with FSD-Inf-Object
# ---------------------------------------------------------------------------


def fsi_object_send_and_local(
    art: WorkerLayerArtifact,
    x_prev: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
    *,
    io_threads: int = 8,
    max_object_part: int = 8 * 1024 * 1024,
    exploit_sparsity: bool = True,
) -> np.ndarray:
    """Algorithm 2 lines 3-9 for one worker: non-blocking PUTs + local MVP."""
    batch = x_prev.shape[1] if x_prev.ndim == 2 else 1
    # ---- lines 3-8: one object (or .nul) per target ------------------------
    # Empty payloads (all mapped rows zero under activation sparsity) become
    # 0-byte `.nul` markers, which readers retire without a GET (lines 4-5).
    lane_time = [worker.abs_time] * max(1, io_threads)
    raw_total = 0
    lane = 0
    for target in sorted(art.send_global):
        rows = art.send_global[target]
        vals = x_prev[art.send_positions[target]]
        if exploit_sparsity:
            rows, vals = _nonzero_row_subset(rows, vals)
        chunks = pack_rows(art.layer, worker.rank, rows, vals, max_object_part)
        raw_total += sum(c.raw_bytes for c in chunks)
        lane_time[lane % len(lane_time)] = fabric.put_multipart(
            art.layer, worker.rank, target, chunks if chunks else [],
            lane_time[lane % len(lane_time)],
        )
        worker.messages_sent += 1
        worker.bytes_sent += sum(len(c) for c in chunks)
        lane += 1
    worker.charge_seconds(raw_total / compute.pack_bandwidth * worker.slowdown)
    if lane:
        worker.advance_to_abs(max(lane_time))

    # ---- line 9: local MVP overlap -----------------------------------------
    x_buf = np.zeros((len(art.needed_rows), batch), dtype=np.float32)
    x_buf[art.owned_positions] = x_prev[art.owned_source_positions]
    worker.charge_compute(art.local_flops * batch, compute)
    return x_buf


def fsi_object_recv(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
) -> np.ndarray:
    """Algorithm 2 lines 10-20 for one worker: LIST/GET until the recv map is
    satisfied (compute deferred — see ``finish_layer``)."""
    # ---- lines 10-20: LIST / GET until recv map satisfied ------------------
    expect = dict(art.recv_expect)
    seen: set[str] = set()
    while expect:
        now, handles = fabric.list_files(art.layer, worker.rank, worker.abs_time)
        worker.advance_to_abs(now)
        progress = False
        for h in handles:
            if h.key in seen:
                continue
            if h.src not in expect:
                continue  # line 16: already received / not awaited — no GET
            seen.add(h.key)
            if h.is_nul:
                del expect[h.src]  # line 13-14: retire source, never read
                progress = True
                continue
            now, blob = fabric.get_obj(art.layer, worker.rank, h.key, worker.abs_time)
            worker.advance_to_abs(now)
            worker.charge_seconds(len(blob) / compute.unpack_bandwidth * worker.slowdown)
            worker.messages_received += 1
            worker.bytes_received += len(blob)
            for part in ObjectFabric.split_multipart(bytes(blob)):
                layer, src, rows, vals, _, _ = decode_chunk(part)
                pos = np.searchsorted(art.needed_rows, rows)
                x_buf[pos] = vals
            del expect[h.src]
            progress = True
        if expect and not progress:
            # back off one LIST interval before re-scanning the prefix
            worker.charge_seconds(fabric.list_latency)
    return x_buf


def fsi_object_recv_and_finish(
    art: WorkerLayerArtifact,
    x_buf: np.ndarray,
    worker: WorkerState,
    fabric: ObjectFabric,
    compute: ComputeModel,
    bias: float,
    backend: Union[str, ComputeBackend, None] = None,
) -> np.ndarray:
    """Algorithm 2 lines 10-23 for one worker: LIST/GET, accumulate, activate."""
    x_buf = fsi_object_recv(art, x_buf, worker, fabric, compute)
    # ---- lines 21-23: accumulate + activation -------------------------------
    return finish_layer(art, x_buf, worker, compute, bias, backend)


# ---------------------------------------------------------------------------
# FSD-Inf-Serial
# ---------------------------------------------------------------------------


def run_serial(
    net: GraphChallengeNet,
    x0: np.ndarray,
    memory_mb: int = 10240,
    compute: ComputeModel | None = None,
    backend: Union[str, ComputeBackend, None] = None,
) -> tuple[np.ndarray, WorkerState]:
    """Single-instance execution (Algorithm 1 with communication removed)."""
    compute = compute or ComputeModel()
    backend = get_backend(backend)
    batch = x0.shape[1]
    need = estimate_worker_memory_bytes(
        net.total_nnz, net.neurons, net.neurons, batch
    )
    if need > memory_mb * 1024 * 1024:
        raise MemoryError(
            f"FSD-Inf-Serial needs ~{need/1e9:.1f}GB > {memory_mb}MB Lambda limit"
        )
    # offline artifact prep (unbilled, like the distributed path's maps)
    states = [backend.prepare(W) for W in net.layers]
    w = WorkerState(rank=0, memory_mb=memory_mb)
    x = x0.astype(np.float32)
    for W, state in zip(net.layers, states):
        x = backend.apply(state, x, net.bias).astype(np.float32, copy=False)
        w.charge_compute(2.0 * W.nnz * batch + 3.0 * x.size, compute)
    w.touch_memory(need)
    return x, w
