"""FSD-Inference cost model (paper §IV, Equations 1–7) + design recommender.

    C_Queue  = C_λ + C_SNS + C_SQS          (Eq. 1)
    C_Object = C_λ + C_S3                   (Eq. 2)
    C_Serial = C_λ                          (Eq. 3)
    C_λ      = P·C_λ(Inv) + P·T̄·M·C_λ(Run)  (Eq. 4)
    C_SNS    = S·C_SNS(Pub) + Z·C_SNS(Byte) (Eq. 5)
    C_SQS    = Q·C_SQS(API)                 (Eq. 6)
    C_S3     = V·C_S3(Put) + R·C_S3(Get) + L·C_S3(List)   (Eq. 7)

Pricing constants are the published AWS us-east-1 rates the paper's
experiments ran under (late-2023).  §VI-F of the paper validates the model:
at N=16384, P=20, 10k samples it predicts Queue = (comp $0.10, comms $0.25)
and Object = (comp $0.09, comms $0.28), matching actual billing — our
``tests/test_cost_model.py`` reproduces those totals from the same formulas.

The recommender encodes §IV-C: Serial for models that fit one instance,
Queue while payloads stay within pub-sub limits (API calls ≈1–2 OOM cheaper),
Object once volumes saturate queue payloads.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

__all__ = [
    "PricingConstants",
    "AWS_PRICING",
    "WorkloadStats",
    "CostBreakdown",
    "lambda_cost",
    "queue_cost",
    "object_cost",
    "serial_cost",
    "warm_pool_cost",
    "activation_hop_cost",
    "recommend_configuration",
    "TpuCostConstants",
    "TPU_V5E",
]


@dataclasses.dataclass(frozen=True)
class PricingConstants:
    """Per-unit prices (USD)."""

    lambda_invoke: float = 0.20 / 1e6          # per invocation
    lambda_mb_second: float = 0.0000166667 / 1024.0  # per MB-second
    sns_publish_64kb: float = 0.50 / 1e6       # per billed 64KB publish unit
    sns_byte_to_sqs: float = 0.09 / (1 << 30)  # per byte SNS→SQS transfer
    sqs_api_request: float = 0.40 / 1e6        # per SQS API call
    s3_put: float = 0.005 / 1e3                # per PUT
    s3_get: float = 0.0004 / 1e3               # per GET
    s3_list: float = 0.005 / 1e3               # per LIST

    # Provider-imposed message constraints (AWS, time of paper)
    max_publish_payload: int = 256 * 1024      # bytes per publish batch
    publish_billing_unit: int = 64 * 1024      # billed in 64KB increments
    max_messages_per_publish: int = 10
    max_lambda_memory_mb: int = 10240
    max_lambda_runtime_s: float = 900.0


AWS_PRICING = PricingConstants()


@dataclasses.dataclass
class WorkloadStats:
    """Measured or estimated per-request quantities (paper's S, Z, Q, V, R, L).

    Captured programmatically by the FaaS simulator (51 per-layer / 26
    per-batch metrics in the paper; we keep the billable aggregates).
    """

    P: int                     # number of workers
    mean_runtime_s: float      # T̄
    memory_mb: int             # M
    publish_units: int = 0     # S  (64KB-billed publish units)
    bytes_sns_to_sqs: int = 0  # Z
    sqs_api_calls: int = 0     # Q  (polls + deletes + sends)
    s3_puts: int = 0           # V
    s3_gets: int = 0           # R
    s3_lists: int = 0          # L


@dataclasses.dataclass
class CostBreakdown:
    compute: float
    communication: float
    # Pre-request provisioning $ under the warm-pool policy (GB-seconds from
    # each worker's invocation through pool-hot).  Zero for on-demand runs,
    # so the field is invisible to every existing cost comparison.
    warm_pool: float = 0.0
    # Crash-recovery $ under an injected FaultPlan: re-invocation fees plus
    # the durable checkpoint store's PUT/GET/LIST tariffs.  Redelivery and
    # replay traffic on the main fabrics stays on ``communication`` (that is
    # where the provider bills it); recovery *runtime* stays on ``compute``
    # via mean_runtime.  Zero for fault-free runs.
    recovery: float = 0.0

    @property
    def total(self) -> float:
        return self.compute + self.communication + self.warm_pool + self.recovery

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        warm = f", warm=${self.warm_pool:.4f}" if self.warm_pool else ""
        rec = f", recovery=${self.recovery:.4f}" if self.recovery else ""
        return (
            f"CostBreakdown(comp=${self.compute:.4f}, "
            f"comms=${self.communication:.4f}{warm}{rec}, total=${self.total:.4f})"
        )


def lambda_cost(stats: WorkloadStats, pricing: PricingConstants = AWS_PRICING) -> float:
    """Eq. 4 — C_λ = P·C_inv + P·T̄·M·C_run."""
    return stats.P * pricing.lambda_invoke + (
        stats.P * stats.mean_runtime_s * stats.memory_mb * pricing.lambda_mb_second
    )


def queue_cost(
    stats: WorkloadStats, pricing: PricingConstants = AWS_PRICING
) -> CostBreakdown:
    """Eq. 1/5/6."""
    c_sns = (
        stats.publish_units * pricing.sns_publish_64kb
        + stats.bytes_sns_to_sqs * pricing.sns_byte_to_sqs
    )
    c_sqs = stats.sqs_api_calls * pricing.sqs_api_request
    return CostBreakdown(compute=lambda_cost(stats, pricing), communication=c_sns + c_sqs)


def object_cost(
    stats: WorkloadStats, pricing: PricingConstants = AWS_PRICING
) -> CostBreakdown:
    """Eq. 2/7."""
    c_s3 = (
        stats.s3_puts * pricing.s3_put
        + stats.s3_gets * pricing.s3_get
        + stats.s3_lists * pricing.s3_list
    )
    return CostBreakdown(compute=lambda_cost(stats, pricing), communication=c_s3)


def serial_cost(
    stats: WorkloadStats, pricing: PricingConstants = AWS_PRICING
) -> CostBreakdown:
    """Eq. 3."""
    return CostBreakdown(compute=lambda_cost(stats, pricing), communication=0.0)


def warm_pool_cost(
    provision_seconds, memory_mb: int,
    pricing: PricingConstants = AWS_PRICING,
) -> float:
    """Pre-request $ of a warm pool: each worker's billed runtime from its
    invocation through pool-hot (``warm_pool_schedule``'s ``provision_s``),
    priced as ordinary Lambda GB-seconds.  Invocations themselves are billed
    once in :func:`lambda_cost` — pre-invoking merely moves them earlier."""
    return float(sum(provision_seconds)) * memory_mb * pricing.lambda_mb_second


def billed_publish_units(payload_bytes: int, pricing: PricingConstants = AWS_PRICING) -> int:
    """Publishes are billed in 64KB increments (a 256KB publish = 4 units)."""
    return max(1, math.ceil(payload_bytes / pricing.publish_billing_unit))


Channel = Literal["serial", "queue", "object"]


def activation_hop_cost(
    channel: Channel,
    activation_bytes: int,
    pricing: PricingConstants = AWS_PRICING,
    est_compression_ratio: float = 0.45,
) -> float:
    """Analytic $ for ONE inter-stage activation hop of the LM pipeline.

    The pipeline executor ships a [B, S, d] (prefill) or [B, 1, d] (decode)
    activation between consecutive stages; this prices that single
    point-to-point transfer per channel so the stage planner / router can
    predict $-per-token before running anything (the billed counterpart is
    aggregated in ``WorkloadStats`` by ``run_lm_pipeline``).

    Queue (Eq. 5/6): the compressed payload splits into ≤256KB publishes
    billed in 64KB units, plus SNS→SQS bytes, plus one receive + one delete
    batch per ≤10 messages.  Object (Eq. 7): one PUT, one GET, one LIST —
    size-independent, which is exactly why Object wins at long prefills and
    loses on per-token decode hops.
    """
    wire = max(1, int(activation_bytes * est_compression_ratio))
    if channel == "queue":
        n_msgs = max(1, math.ceil(wire / pricing.max_publish_payload))
        units = max(n_msgs, billed_publish_units(wire, pricing))
        publishes = math.ceil(n_msgs / pricing.max_messages_per_publish)
        sqs = 2 * math.ceil(n_msgs / 10)  # receive + delete batches
        return (
            max(publishes, units) * pricing.sns_publish_64kb
            + wire * pricing.sns_byte_to_sqs
            + sqs * pricing.sqs_api_request
        )
    if channel == "object":
        return pricing.s3_put + pricing.s3_get + pricing.s3_list
    if channel == "serial":
        return 0.0
    raise ValueError(channel)


def recommend_configuration(
    model_bytes: int,
    per_layer_exchange_bytes: float,
    n_layers: int,
    P_candidates: tuple[int, ...] = (1, 8, 20, 42, 62),
    memory_mb_per_worker: int = 2000,
    est_runtime_s: float = 120.0,
    pricing: PricingConstants = AWS_PRICING,
) -> tuple[Channel, int, dict]:
    """§IV-C design recommendations, made executable.

    Estimates each (channel, P) candidate's cost from the analytic model and
    returns the cheapest feasible one.  Feasibility: the per-worker model
    shard (plus 25% headroom) must fit in the instance memory, and the
    estimated runtime must respect the FaaS runtime limit.
    """
    table: dict = {}
    best: tuple[float, Channel, int] | None = None
    # per-layer channel round latency a parallel fleet pays and serial avoids
    round_latency = {"queue": 0.06, "object": 0.10}
    for P in P_candidates:
        shard_mb = model_bytes / P / 1e6 * 1.25
        if P == 1:
            # serial runs the whole model in one right-sized instance
            mem_req = model_bytes * 2.0 / 1e6  # model + activations + overhead
            if mem_req > pricing.max_lambda_memory_mb:
                continue
            if est_runtime_s > pricing.max_lambda_runtime_s:
                continue
            mem = int(min(pricing.max_lambda_memory_mb, max(512, mem_req)))
            stats = WorkloadStats(P=1, mean_runtime_s=est_runtime_s, memory_mb=mem)
            cost = serial_cost(stats, pricing)
            table[("serial", 1)] = cost
            if best is None or cost.total < best[0]:
                best = (cost.total, "serial", 1)
            continue
        if shard_mb > min(memory_mb_per_worker, pricing.max_lambda_memory_mb):
            continue
        runtime = est_runtime_s / P + n_layers * round_latency["queue"]
        if runtime > pricing.max_lambda_runtime_s:
            continue
        # per-target payload per layer (paper: HGP keeps targets ≈ P-1 worst case)
        pair_bytes = per_layer_exchange_bytes / max(1, P - 1)
        publishes = n_layers * P * max(
            1, math.ceil((P - 1) / pricing.max_messages_per_publish)
        )
        units = n_layers * P * (P - 1) * billed_publish_units(
            int(min(pair_bytes, pricing.max_publish_payload)), pricing
        ) // max(1, (P - 1))
        z = int(per_layer_exchange_bytes * n_layers)
        q = n_layers * P * (2 + math.ceil((P - 1) / 10))
        qstats = WorkloadStats(
            P=P, mean_runtime_s=runtime, memory_mb=memory_mb_per_worker,
            publish_units=max(publishes, units), bytes_sns_to_sqs=z, sqs_api_calls=q,
        )
        qcost = queue_cost(qstats, pricing)
        table[("queue", P)] = qcost
        v = n_layers * P * (P - 1)
        ostats = WorkloadStats(
            P=P, mean_runtime_s=runtime, memory_mb=memory_mb_per_worker,
            s3_puts=v, s3_gets=v, s3_lists=n_layers * P * 3,
        )
        ocost = object_cost(ostats, pricing)
        table[("object", P)] = ocost
        for ch, cost in (("queue", qcost), ("object", ocost)):
            if best is None or cost.total < best[0]:
                best = (cost.total, ch, P)  # type: ignore[assignment]
    if best is None:
        raise ValueError("no feasible configuration (model too large for FaaS fleet)")
    return best[1], best[2], table


# ---------------------------------------------------------------------------
# TPU-side constants — used by the roofline analysis, and by the serving
# router when it translates the paper's $-cost trade-off into a time-cost
# trade-off on the production mesh.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TpuCostConstants:
    peak_bf16_flops: float   # per chip, FLOP/s
    hbm_bandwidth: float     # per chip, bytes/s
    ici_link_bandwidth: float  # per link, bytes/s
    hbm_bytes: float         # per chip


TPU_V5E = TpuCostConstants(
    peak_bf16_flops=197e12,
    hbm_bandwidth=819e9,
    ici_link_bandwidth=50e9,
    hbm_bytes=16e9,
)
