"""Pluggable backends for the two serving hot paths: worker SpMM and decode
attention.

**Compute backends** (:class:`ComputeBackend`) execute the FSI per-layer
SpMM.  Every simulated Lambda runs the same inner loop per layer: a sparse
matrix–panel product ``z = W_local @ x_buf`` followed by the GraphChallenge
epilogue ``y = clip(relu(z + bias), 0, 32)``.  The *billed* cost of that work
is fixed by :class:`repro.faas.worker.ComputeModel` (FLOPs → Lambda-seconds),
but the *host* wall-clock of the simulator is whatever backend actually runs
the numbers.  This module makes that choice pluggable:

* ``numpy-csr``  — the seed's ``np.add.at`` scatter-add CSR SpMM, kept
  verbatim as the bit-exact oracle.
* ``numpy-fast`` — segment formulation (uniform-row batched matmul with a
  ``np.add.reduceat`` ragged fallback); same math, 5-30x faster on
  GraphChallenge shapes.
* ``pallas-bsr`` — the MXU-tiled Pallas kernel in ``kernels/bsr_spmm``:
  offline ``bsr_from_csr(pad=True)`` + ``padded()`` artifact prep per
  worker-layer, jit-cached fused bias+ReLU+clip dispatch, and a fleet mode
  that stacks every worker's panel so ONE vmapped device dispatch serves the
  whole simulated fleet per layer.
* ``pallas-bsr-sharded`` — the same fleet panel laid out over a real device
  mesh: the stacked worker axis is sharded over a 1-D ``worker`` mesh axis
  (``launch.mesh.make_worker_mesh``) and each layer dispatches through
  ``distributed.sharding.shard_map_compat``, so simulated workers map 1:1
  (or blocked P/D) onto devices — the paper's "one worker ≈ one isolated
  compute unit" execution model.  The default ``dispatch="fused"`` runs ONE
  fleet-megakernel ``pallas_call`` per device (worker index folded into the
  grid, per-panel block counts bounding the K loop);
  ``dispatch="vmap"`` keeps the PR 3 vmap-within-shard body as the parity
  baseline.  P not divisible by the device count is padded with zero
  workers.

Backends only change how the arithmetic is executed — FLOP charging, message
accounting and memory high-water marks are computed by the caller from the
CSR shard itself, so billed cost is identical across backends by
construction (asserted in ``tests/test_backends.py``).

**Attention backends** (:class:`AttentionBackend`) execute the serving
engine's per-step decode attention — the second hot path under the paper's
batch-serving posture (§V-B).  Every decoding model family dispatches its
single-token attention through one of:

* ``dense-ref``     — ``models.attention.decode_attention_dense``, the
  no-chunking oracle (sequence-shardable under pjit);
* ``chunked-lse``   — the streaming ``models.attention.decode_attention``
  scan (bounded memory for very long caches);
* ``pallas-splitk`` — the split-KV Pallas kernel ``kernels/decode_attention``
  via the jit-cached ``decode_mha`` wrapper, with the cache padded to a
  ``block_k`` multiple picked from an autotune table.

All three take ``(q [B,1,H,D], k_cache [B,KV,S,D], v_cache [B,KV,S,D],
cache_len)`` — the **kernel-native** cache layout, with the capacity ``S``
padded at prefill per the backend's :class:`KVCacheLayout` — and return
``[B,1,H,D]`` in ``q.dtype``.  Because the cache is already in the kernel's
layout, ``pallas-splitk`` dispatches with zero per-step re-layout (no
``moveaxis``/``pad`` — asserted on the jaxpr in
``tests/test_sharded_decode.py``), and the other backends read the same
buffers through views.  Each backend also exposes ``decode_partial`` — the
``(out, lse)`` split-KV form — which the families' sequence-sharded decode
branch combines across shards via ``models.attention.combine_split_kv``.
Logits parity across backends and model families is asserted in
``tests/test_attention_backends.py`` and (sharded) ``tests/test_sharded_decode.py``.

Both registries resolve through one entry point: ``get_backend(kind, name)``
with ``kind in {"compute", "attention"}``; the legacy one-argument form
``get_backend(name)`` keeps meaning a compute backend.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple, Union

import numpy as np

from repro.core.sparse import CSRMatrix, bsr_from_csr
from repro.data.graphchallenge import ACTIVATION_CLIP, relu_bias_threshold

__all__ = [
    "ComputeBackend",
    "NumpyCsrBackend",
    "NumpyFastBackend",
    "PallasBsrBackend",
    "PallasBsrShardedBackend",
    "AttentionBackend",
    "KVCacheLayout",
    "cache_layout_for",
    "DenseRefAttention",
    "ChunkedLseAttention",
    "PallasSplitKAttention",
    "BACKEND_NAMES",
    "ATTENTION_BACKEND_NAMES",
    "get_backend",
]


class ComputeBackend(Protocol):
    """One worker-layer SpMM + fused epilogue, with optional fleet batching."""

    name: str

    def prepare(self, W: CSRMatrix) -> Any:
        """Offline per-worker-layer artifact prep (unbilled, like the paper's
        a-priori partitioning/map construction)."""
        ...

    def apply(self, state: Any, x: np.ndarray, bias: float) -> np.ndarray:
        """``clip(relu(W @ x + bias), 0, 32)`` for one worker."""
        ...

    def fleet_prepare_all(
        self, layer_states: Sequence[Sequence[Any]]
    ) -> Optional[List[Any]]:
        """Optional: stack per-layer states [layer][worker] into one batched
        panel per layer.  ``None`` means no fleet mode (per-worker apply)."""
        ...

    def fleet_apply(
        self, fleet_state: Any, xs: Sequence[np.ndarray], bias: float
    ) -> List[np.ndarray]:
        """One dispatch for the whole fleet's layer-k panels."""
        ...


class _NumpyBackend:
    @property
    def state_key(self) -> str:
        return self.name

    def prepare(self, W: CSRMatrix) -> CSRMatrix:
        return W

    def fleet_prepare_all(self, layer_states):
        return None

    def fleet_apply(self, fleet_state, xs, bias):  # pragma: no cover
        raise NotImplementedError(f"{self.name} has no fleet mode")


class NumpyCsrBackend(_NumpyBackend):
    """Seed behavior: scatter-add CSR SpMM (the parity oracle)."""

    name = "numpy-csr"

    def apply(self, state: CSRMatrix, x: np.ndarray, bias: float) -> np.ndarray:
        return relu_bias_threshold(state.matmul_dense_scatter(x), bias)


class NumpyFastBackend(_NumpyBackend):
    """Segment-reduce CSR SpMM — no ``np.add.at``."""

    name = "numpy-fast"

    def apply(self, state: CSRMatrix, x: np.ndarray, bias: float) -> np.ndarray:
        return relu_bias_threshold(state.matmul_dense_fast(x), bias)


@dataclasses.dataclass
class _PallasLayerState:
    """Offline-prepared padded-BSR operands for one worker-layer shard."""

    blocks: np.ndarray      # f32[NBR, K, bm, bn]
    cols: np.ndarray        # i32[NBR, K]
    counts: np.ndarray      # i32[NBR] true blocks per row (BSR indptr diff)
    m: int                  # true output rows (unpadded)
    n: int                  # true input rows (unpadded)
    n_pad: int              # padded input height = NBC * bn


@dataclasses.dataclass
class _PallasFleetState:
    """One layer's fleet panel: every worker's operands padded to common
    [P, NBRmax, Kmax, bm, bn] so a single batched dispatch covers the fleet
    (``counts`` carries each panel row's true block depth so the fused
    megakernel's K loop skips the fleet-global padding)."""

    blocks: Any             # device f32[P, NBR, K, bm, bn]
    cols: Any               # device i32[P, NBR, K]
    counts: Any             # device i32[P, NBR]
    m: List[int]
    n: List[int]
    n_pad: int


class PallasBsrBackend:
    """MXU-tiled BSR SpMM via ``kernels/bsr_spmm`` (fused bias+ReLU+clip).

    ``interpret=True`` (the default) runs the Pallas kernel through the
    interpreter, which works on CPU-only hosts; on a real TPU pass
    ``interpret=False`` for compiled MXU dispatch.
    """

    name = "pallas-bsr"

    def __init__(
        self,
        block_shape: Tuple[int, int] = (32, 32),
        batch_block: int = 128,
        interpret: bool = True,
        clip: float = ACTIVATION_CLIP,
    ):
        import jax  # gate the optional accelerator dep at construction time

        del jax
        self.block_shape = block_shape
        self.batch_block = batch_block
        self.interpret = interpret
        self.clip = clip

    @property
    def state_key(self) -> str:
        bm, bn = self.block_shape
        return f"{self.name}:{bm}x{bn}:bb{self.batch_block}:i{self.interpret}:c{self.clip}"

    # -- shape helpers -------------------------------------------------------

    def _bb(self, batch: int) -> int:
        """Largest legal batch panel: the kernel requires bb | batch."""
        return self.batch_block if batch % self.batch_block == 0 else batch

    # -- per-worker path -----------------------------------------------------

    def prepare(self, W: CSRMatrix) -> _PallasLayerState:
        bsr = bsr_from_csr(W, self.block_shape, pad=True)
        blocks, cols, counts = bsr.padded()
        return _PallasLayerState(
            blocks=blocks.astype(np.float32),
            cols=cols,
            counts=counts.astype(np.int32),
            m=W.nrows,
            n=W.ncols,
            n_pad=bsr.shape[1],
        )

    def apply(self, state: _PallasLayerState, x: np.ndarray, bias: float) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels.bsr_spmm.ops import bsr_spmm

        batch = x.shape[1]
        if state.m == 0 or batch == 0:
            return np.zeros((state.m, batch), dtype=np.float32)
        xp = np.zeros((state.n_pad, batch), dtype=np.float32)
        xp[: state.n] = x
        y = bsr_spmm(
            jnp.asarray(state.blocks),
            jnp.asarray(state.cols),
            jnp.asarray(xp),
            bias=float(bias),
            clip=self.clip,
            batch_block=self._bb(batch),
            interpret=self.interpret,
        )
        return np.asarray(y)[: state.m]

    # -- fleet path ----------------------------------------------------------

    def _fleet_maxima(self, layer_states):
        """(nbr_max, k_max, n_pad_max) over every worker-layer state, or
        ``None`` when the fleet is empty — padding everything to these maxima
        lets one jit-compiled shape serve every layer."""
        all_states = [s for layer in layer_states for s in layer]
        if not all_states:
            return None
        bn = self.block_shape[1]
        return (
            max(1, max(s.blocks.shape[0] for s in all_states)),
            max(1, max(s.blocks.shape[1] for s in all_states)),
            max(bn, max(s.n_pad for s in all_states)),
        )

    def _stack_layer(self, states, p_rows: int, nbr_max: int, k_max: int):
        """Stack one layer's per-worker operands into [p_rows, ...] host
        panels (rows beyond ``len(states)`` stay zero — inert pad workers,
        whose ``counts`` of 0 also keep the fused megakernel's K loop off
        them entirely)."""
        bm, bn = self.block_shape
        blocks = np.zeros((p_rows, nbr_max, k_max, bm, bn), dtype=np.float32)
        cols = np.zeros((p_rows, nbr_max, k_max), dtype=np.int32)
        counts = np.zeros((p_rows, nbr_max), dtype=np.int32)
        for i, s in enumerate(states):
            nbr, k = s.blocks.shape[:2]
            blocks[i, :nbr, :k] = s.blocks
            cols[i, :nbr, :k] = s.cols
            counts[i, :nbr] = s.counts
        return blocks, cols, counts

    def fleet_prepare_all(
        self, layer_states: Sequence[Sequence[_PallasLayerState]]
    ) -> List[_PallasFleetState]:
        """Pad every worker-layer operand to the fleet-and-depth-global maxima
        so each layer's dispatch shares one jit-compiled shape."""
        import jax.numpy as jnp

        maxima = self._fleet_maxima(layer_states)
        if maxima is None:
            return []
        nbr_max, k_max, n_pad_max = maxima
        out: List[_PallasFleetState] = []
        for states in layer_states:
            blocks, cols, counts = self._stack_layer(
                states, len(states), nbr_max, k_max)
            out.append(
                _PallasFleetState(
                    blocks=jnp.asarray(blocks),
                    cols=jnp.asarray(cols),
                    counts=jnp.asarray(counts),
                    m=[s.m for s in states],
                    n=[s.n for s in states],
                    n_pad=n_pad_max,
                )
            )
        return out

    def fleet_apply(
        self, fleet_state: _PallasFleetState, xs: Sequence[np.ndarray], bias: float
    ) -> List[np.ndarray]:
        import jax.numpy as jnp

        from repro.kernels.bsr_spmm.ops import bsr_spmm_fleet

        P = len(xs)
        batch = xs[0].shape[1]
        X = np.zeros((P, fleet_state.n_pad, batch), dtype=np.float32)
        for i, x in enumerate(xs):
            X[i, : x.shape[0]] = x
        y = np.asarray(
            bsr_spmm_fleet(
                fleet_state.blocks,
                fleet_state.cols,
                jnp.asarray(X),
                bias=float(bias),
                clip=self.clip,
                batch_block=self._bb(batch),
                interpret=self.interpret,
            )
        )
        return [y[i, : fleet_state.m[i]] for i in range(P)]


@dataclasses.dataclass
class _PallasShardedFleetState(_PallasFleetState):
    """Fleet panel whose worker axis is padded to a device-count multiple and
    laid out over the ``worker`` mesh axis (blocks/cols live device-resident
    under a NamedSharding from prepare time on)."""

    p_pad: int = 0          # padded worker count (multiple of mesh axis size)


class PallasBsrShardedBackend(PallasBsrBackend):
    """``pallas-bsr`` fleet mode over a real device mesh via ``shard_map``.

    The per-worker-layer artifacts are identical to :class:`PallasBsrBackend`
    (inherited ``prepare``/``apply``); only the fleet dispatch differs: the
    stacked [P, ...] panel is sharded over a 1-D ``worker`` mesh axis and
    every device runs the Pallas BSR body for its block of P/D workers —
    simulated Lambdas map onto devices the way the paper (and FMI-style
    serverless collectives) assume one worker maps onto one isolated compute
    unit.  When P is not divisible by the device count the panel is padded
    with all-zero workers whose outputs are never read.

    ``dispatch`` picks the per-device execution:

    * ``"fused"`` (default) — the fleet megakernel: ONE ``pallas_call`` per
      device whose grid walks that device's P/D worker panels (worker index
      folded into the grid, per-panel block counts bounding the K loop) —
      no vmap, no XLA re-entry between workers.
    * ``"vmap"`` — the PR 3 dispatch (``jax.vmap`` of the single-worker
      Pallas body inside each shard), kept as the parity baseline and the
      fallback when a kernel-level issue needs bisecting.

    Both dispatches are bitwise-identical on the produced panels (the fused
    K loop only skips all-zero padding terms; asserted in
    ``tests/test_sharded_fleet.py``).

    ``mesh`` defaults to every visible device
    (:func:`repro.launch.mesh.make_worker_mesh`); pass an explicit mesh — or
    use ``run_fsi(..., mesh=...)`` — to pin the layout.  On CPU-only hosts
    multi-device meshes come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
    """

    name = "pallas-bsr-sharded"

    def __init__(
        self,
        block_shape: Tuple[int, int] = (32, 32),
        batch_block: int = 128,
        interpret: bool = True,
        clip: float = ACTIVATION_CLIP,
        mesh: Any = None,
        axis_name: str = "worker",
        dispatch: str = "fused",
    ):
        super().__init__(block_shape=block_shape, batch_block=batch_block,
                         interpret=interpret, clip=clip)
        if dispatch not in ("fused", "vmap"):
            raise ValueError(
                f"dispatch must be 'fused' or 'vmap', got {dispatch!r}")
        self._mesh = mesh
        self.axis_name = axis_name
        self.dispatch = dispatch

    @property
    def mesh(self):
        if self._mesh is None:
            from repro.launch.mesh import make_worker_mesh

            self._mesh = make_worker_mesh(axis_name=self.axis_name)
        return self._mesh

    def with_mesh(self, mesh) -> "PallasBsrShardedBackend":
        """A copy of this backend pinned to ``mesh`` (the hook ``run_fsi``
        uses to thread an explicit mesh through backend selection)."""
        return PallasBsrShardedBackend(
            block_shape=self.block_shape, batch_block=self.batch_block,
            interpret=self.interpret, clip=self.clip, mesh=mesh,
            axis_name=self.axis_name, dispatch=self.dispatch,
        )

    @property
    def n_devices(self) -> int:
        return int(self.mesh.shape[self.axis_name])

    @property
    def state_key(self) -> str:
        return (f"{super().state_key}:d{self.n_devices}:{self.axis_name}"
                f":{self.dispatch}")

    def _sharding(self):
        from jax.sharding import NamedSharding, PartitionSpec

        return NamedSharding(self.mesh, PartitionSpec(self.axis_name))

    def fleet_prepare_all(
        self, layer_states: Sequence[Sequence[_PallasLayerState]]
    ) -> List[_PallasShardedFleetState]:
        """Stack + pad the worker axis to a device-count multiple and place
        the panels over the mesh at prepare time (offline, unbilled) so no
        layer dispatch pays a host→device reshard for the weights."""
        import jax

        maxima = self._fleet_maxima(layer_states)
        if maxima is None:
            return []
        nbr_max, k_max, n_pad_max = maxima
        D = self.n_devices
        sharding = self._sharding()
        out: List[_PallasShardedFleetState] = []
        for states in layer_states:
            P = len(states)
            p_pad = -(-P // D) * D
            blocks, cols, counts = self._stack_layer(
                states, p_pad, nbr_max, k_max)
            out.append(
                _PallasShardedFleetState(
                    blocks=jax.device_put(blocks, sharding),
                    cols=jax.device_put(cols, sharding),
                    counts=jax.device_put(counts, sharding),
                    m=[s.m for s in states],
                    n=[s.n for s in states],
                    n_pad=n_pad_max,
                    p_pad=p_pad,
                )
            )
        return out

    def fleet_apply(
        self, fleet_state: _PallasShardedFleetState, xs: Sequence[np.ndarray],
        bias: float,
    ) -> List[np.ndarray]:
        import jax

        from repro.kernels.bsr_spmm.ops import (
            bsr_spmm_fleet_fused_sharded,
            bsr_spmm_fleet_sharded,
        )

        P = len(xs)
        batch = xs[0].shape[1]
        X = np.zeros((fleet_state.p_pad, fleet_state.n_pad, batch),
                     dtype=np.float32)
        for i, x in enumerate(xs):
            X[i, : x.shape[0]] = x
        Xd = jax.device_put(X, self._sharding())
        if self.dispatch == "fused":
            y = bsr_spmm_fleet_fused_sharded(
                fleet_state.blocks, fleet_state.cols, fleet_state.counts, Xd,
                mesh=self.mesh, axis_name=self.axis_name, bias=float(bias),
                clip=self.clip, batch_block=self._bb(batch),
                interpret=self.interpret,
            )
        else:
            y = bsr_spmm_fleet_sharded(
                fleet_state.blocks, fleet_state.cols, Xd,
                mesh=self.mesh, axis_name=self.axis_name, bias=float(bias),
                clip=self.clip, batch_block=self._bb(batch),
                interpret=self.interpret,
            )
        y = np.asarray(y)
        return [y[i, : fleet_state.m[i]] for i in range(P)]


# ---------------------------------------------------------------------------
# decode-attention backends (serving per-step hot path)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KVCacheLayout:
    """Canonical decode KV-cache layout descriptor.

    Every decoding family allocates its cache **kernel-native** —
    ``[..., B, KV, S, D]`` with the sequence capacity ``S`` padded up to a
    ``block_k`` multiple at prefill — so the per-step decode dispatch never
    re-lays the cache out (the old ``moveaxis``+``pad`` in the splitk path).
    ``block_k`` is the padding quantum: 1 for the view-based backends
    (dense-ref / chunked-lse accept any capacity), the kernel's KV block
    size for ``pallas-splitk``.  The descriptor is resolved once per serving
    configuration (``AttentionBackend.cache_layout(max_len)`` /
    ``router.route_decode_plan``) and threaded ``ServingEngine`` →
    ``get_model`` → family ``prefill``/``decode_step``.
    """

    block_k: int = 1

    def padded_len(self, max_len: int) -> int:
        """Cache capacity for a requested ``max_len``: the next ``block_k``
        multiple (identity when ``block_k == 1``)."""
        bk = max(1, int(self.block_k))
        return -(-max(int(max_len), 1) // bk) * bk

    def check_capacity(self, seq_cap: int) -> None:
        if seq_cap % max(1, int(self.block_k)):
            raise ValueError(
                f"KV cache capacity {seq_cap} is not a multiple of "
                f"block_k={self.block_k}; pad the cache at prefill with "
                f"KVCacheLayout.padded_len (ServingEngine does this)")

    def blocks_for(self, max_len: int) -> int:
        """Number of ``block_k``-sized pages a sequence of up to ``max_len``
        tokens occupies — the allocation unit of the paged KV pool
        (``serving/kv_pool.py``): a request holds ``blocks_for(prompt +
        max_new)`` pages for its lifetime and frees them at retirement."""
        return self.padded_len(max_len) // max(1, int(self.block_k))


def cache_layout_for(backend, max_len: int) -> KVCacheLayout:
    """The :class:`KVCacheLayout` a backend instance wants for a cache of
    capacity ``max_len`` (identity layout for duck-typed externals)."""
    fn = getattr(backend, "cache_layout", None)
    return fn(max_len) if fn is not None else KVCacheLayout()


class AttentionBackend(Protocol):
    """Single-token decode attention over a preallocated KV cache.

    Implementations must be pure jax-traceable callables so the serving
    engine can close over one instance inside its jitted ``decode_step``:
    the backend choice is static, ``cache_len`` is traced.  Caches arrive in
    the canonical :class:`KVCacheLayout` — ``[B, KV, S, D]`` with ``S``
    already padded per ``cache_layout(max_len)``.
    """

    name: str

    def cache_layout(self, max_len: int) -> KVCacheLayout:
        """Layout (padding rule) this backend needs for capacity ``max_len``."""
        ...

    def decode(
        self,
        q: Any,          # [B, 1, H, D] — one new token's query heads
        k_cache: Any,    # [B, KV, S, D] cache padded to capacity S
        v_cache: Any,    # [B, KV, S, D]
        cache_len: Any,  # valid prefix length (traced scalar or int)
    ) -> Any:
        """Returns attention output [B, 1, H, D] in ``q.dtype``."""
        ...

    def decode_partial(
        self, q: Any, k_cache: Any, v_cache: Any, cache_len: Any
    ) -> Any:
        """Split-KV form over a (possibly shard-local) cache slice: returns
        ``(out [B,1,H,D] fp32 normalized partial, lse [B,1,H] fp32)`` for the
        cross-shard ``combine_split_kv`` merge."""
        ...


class DenseRefAttention:
    """``decode_attention_dense`` — the parity oracle for the registry.

    No chunking: the scores einsum contracts the full (masked) cache, which
    is also the sequence-shardable formulation under pjit (split-KV chosen by
    the compiler).
    """

    name = "dense-ref"

    @property
    def state_key(self) -> str:
        return self.name

    def cache_layout(self, max_len: int) -> KVCacheLayout:
        return KVCacheLayout(block_k=1)

    def decode(self, q, k_cache, v_cache, cache_len):
        from repro.models.attention import decode_attention_dense

        return decode_attention_dense(q, k_cache, v_cache, cache_len)

    def decode_partial(self, q, k_cache, v_cache, cache_len):
        from repro.models.attention import decode_attention_dense

        return decode_attention_dense(q, k_cache, v_cache, cache_len,
                                      return_lse=True)


class ChunkedLseAttention:
    """Streaming KV-chunk scan with running (max, sum, acc) — bounded memory
    for very long caches; chunk size is a numerics-invariant tile knob
    (property-tested in ``tests/test_attention_backends.py``)."""

    name = "chunked-lse"

    def __init__(self, kv_chunk: int = 2048):
        self.kv_chunk = kv_chunk

    @property
    def state_key(self) -> str:
        return f"{self.name}:kc{self.kv_chunk}"

    def cache_layout(self, max_len: int) -> KVCacheLayout:
        return KVCacheLayout(block_k=1)

    def decode(self, q, k_cache, v_cache, cache_len):
        from repro.models.attention import decode_attention

        return decode_attention(
            q, k_cache, v_cache, cache_len=cache_len, kv_chunk=self.kv_chunk
        ).astype(q.dtype)

    def decode_partial(self, q, k_cache, v_cache, cache_len):
        from repro.models.attention import decode_attention

        return decode_attention(
            q, k_cache, v_cache, cache_len=cache_len, kv_chunk=self.kv_chunk,
            return_lse=True,
        )


# (padded cache length upper bound, block_k) — smallest block that keeps the
# kv sweep ≥ a few blocks deep without padding tiny caches to 512.
SPLITK_BLOCK_K_TABLE: Tuple[Tuple[Optional[int], int], ...] = (
    (256, 64),
    (1024, 128),
    (4096, 256),
    (None, 512),
)


class PallasSplitKAttention:
    """Split-KV flash-decode Pallas kernel via the jit-cached ``decode_mha``.

    The cache arrives **already kernel-native**: ``[B, KV, S, D]`` with ``S``
    a ``block_k`` multiple (the layout :meth:`cache_layout` asks prefill to
    allocate), so the dispatch is a straight ``decode_mha`` call — the old
    per-step ``moveaxis``+``pad`` re-layout is gone (jaxpr-asserted in
    ``tests/test_sharded_decode.py``).  Padded positions sit beyond
    ``cache_len`` so the in-kernel mask zeroes them.  ``block_k`` comes from
    :data:`SPLITK_BLOCK_K_TABLE` unless pinned, and ``interpret=None`` defers
    to the platform default (compiled on TPU, interpreter elsewhere).  Since
    ``S`` is fixed for the lifetime of a cache, the jit cache is hit on every
    step while ``cache_len`` grows (asserted in the parity harness).
    """

    name = "pallas-splitk"

    def __init__(self, block_k: Optional[int] = None,
                 interpret: Optional[bool] = None):
        import jax  # gate the optional accelerator dep at construction time

        del jax
        self.block_k = block_k
        self.interpret = interpret

    @property
    def state_key(self) -> str:
        return f"{self.name}:bk{self.block_k}:i{self.interpret}"

    def block_k_for(self, seq_cap: int) -> int:
        if self.block_k is not None:
            return self.block_k
        for bound, bk in SPLITK_BLOCK_K_TABLE:
            if bound is None or seq_cap <= bound:
                return bk
        raise AssertionError("unreachable")  # pragma: no cover

    def cache_layout(self, max_len: int) -> KVCacheLayout:
        # The autotune table is bucketed on bounds that are multiples of
        # their own block_k, so padded_len never crosses into a bucket with
        # a different block size: block_k_for(padded) == block_k_for(max_len).
        return KVCacheLayout(block_k=self.block_k_for(max(int(max_len), 1)))

    def decode(self, q, k_cache, v_cache, cache_len):
        out, _ = self.decode_partial(q, k_cache, v_cache, cache_len)
        return out.astype(q.dtype)

    def decode_partial(self, q, k_cache, v_cache, cache_len):
        import jax.numpy as jnp

        from repro.kernels.decode_attention.ops import decode_mha

        S = k_cache.shape[2]
        self.cache_layout(S).check_capacity(S)  # no silent per-step re-pad
        bk = self.block_k_for(S)
        B, _, H, D = q.shape
        out, lse = decode_mha(
            q.reshape(B, H, D), k_cache, v_cache,
            jnp.asarray(cache_len, jnp.int32),
            block_k=bk, interpret=self.interpret,
        )
        return out[:, None], lse[:, None]


# ---------------------------------------------------------------------------
# unified registry
# ---------------------------------------------------------------------------


_REGISTRY: Dict[str, type] = {
    NumpyCsrBackend.name: NumpyCsrBackend,
    NumpyFastBackend.name: NumpyFastBackend,
    PallasBsrBackend.name: PallasBsrBackend,
    PallasBsrShardedBackend.name: PallasBsrShardedBackend,
}
BACKEND_NAMES = tuple(_REGISTRY)

_ATTENTION_REGISTRY: Dict[str, type] = {
    DenseRefAttention.name: DenseRefAttention,
    ChunkedLseAttention.name: ChunkedLseAttention,
    PallasSplitKAttention.name: PallasSplitKAttention,
}
ATTENTION_BACKEND_NAMES = tuple(_ATTENTION_REGISTRY)

# kind → (registry, default name, label, duck-type method an instance of the
# kind must expose — catches a wrong-kind instance at resolution time instead
# of an AttributeError deep inside a jit trace)
_KINDS = {
    "compute": (_REGISTRY, "numpy-fast", "compute backend", "apply"),
    "attention": (_ATTENTION_REGISTRY, "dense-ref", "attention backend",
                  "decode"),
}

_LEGACY = object()  # sentinel: one-argument get_backend(name) = compute


def get_backend(kind, name=_LEGACY):
    """Resolve a backend by ``(kind, name)`` — the single entry point for
    both registries.

    ``get_backend("compute", "numpy-fast")`` / ``get_backend("attention",
    "pallas-splitk")``.  ``name=None`` resolves to the kind's default
    (``numpy-fast`` — the default since PR 1 — and ``dense-ref``, the
    oracle).  Instances pass through unchanged, so callers can hand in a
    pre-configured backend (e.g. ``ChunkedLseAttention(kv_chunk=256)``).

    The legacy one-argument form ``get_backend(name_or_instance)`` still
    means a compute backend (every PR 1 call site).
    """
    if name is _LEGACY:
        kind, name = "compute", kind
    if kind not in _KINDS:
        raise ValueError(
            f"unknown backend kind {kind!r}; options: {tuple(_KINDS)}"
        )
    registry, default, label, duck_method = _KINDS[kind]
    if name is None:
        name = default
    if not isinstance(name, str):
        if not callable(getattr(name, duck_method, None)):
            raise TypeError(
                f"{name!r} is not a {label}: missing .{duck_method}()"
            )
        return name
    try:
        return registry[name]()
    except KeyError:
        raise ValueError(
            f"unknown {label} {name!r}; options: {tuple(registry)}"
        ) from None
    except ImportError as e:  # pallas-* without jax installed
        raise ImportError(
            f"backend {name!r} needs jax; install it or use {default!r}"
        ) from e
