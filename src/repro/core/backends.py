"""Pluggable worker compute backends for the FSI per-layer SpMM hot path.

Every simulated Lambda executes the same inner loop per layer: a sparse
matrix–panel product ``z = W_local @ x_buf`` followed by the GraphChallenge
epilogue ``y = clip(relu(z + bias), 0, 32)``.  The *billed* cost of that work
is fixed by :class:`repro.faas.worker.ComputeModel` (FLOPs → Lambda-seconds),
but the *host* wall-clock of the simulator is whatever backend actually runs
the numbers.  This module makes that choice pluggable:

* ``numpy-csr``  — the seed's ``np.add.at`` scatter-add CSR SpMM, kept
  verbatim as the bit-exact oracle.
* ``numpy-fast`` — segment formulation (uniform-row batched matmul with a
  ``np.add.reduceat`` ragged fallback); same math, 5-30x faster on
  GraphChallenge shapes.
* ``pallas-bsr`` — the MXU-tiled Pallas kernel in ``kernels/bsr_spmm``:
  offline ``bsr_from_csr(pad=True)`` + ``padded()`` artifact prep per
  worker-layer, jit-cached fused bias+ReLU+clip dispatch, and a fleet mode
  that stacks every worker's panel so ONE vmapped device dispatch serves the
  whole simulated fleet per layer.

Backends only change how the arithmetic is executed — FLOP charging, message
accounting and memory high-water marks are computed by the caller from the
CSR shard itself, so billed cost is identical across backends by
construction (asserted in ``tests/test_backends.py``).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Sequence, Tuple, Union

import numpy as np

from repro.core.sparse import CSRMatrix, bsr_from_csr
from repro.data.graphchallenge import ACTIVATION_CLIP, relu_bias_threshold

__all__ = [
    "ComputeBackend",
    "NumpyCsrBackend",
    "NumpyFastBackend",
    "PallasBsrBackend",
    "BACKEND_NAMES",
    "get_backend",
]


class ComputeBackend(Protocol):
    """One worker-layer SpMM + fused epilogue, with optional fleet batching."""

    name: str

    def prepare(self, W: CSRMatrix) -> Any:
        """Offline per-worker-layer artifact prep (unbilled, like the paper's
        a-priori partitioning/map construction)."""
        ...

    def apply(self, state: Any, x: np.ndarray, bias: float) -> np.ndarray:
        """``clip(relu(W @ x + bias), 0, 32)`` for one worker."""
        ...

    def fleet_prepare_all(
        self, layer_states: Sequence[Sequence[Any]]
    ) -> Optional[List[Any]]:
        """Optional: stack per-layer states [layer][worker] into one batched
        panel per layer.  ``None`` means no fleet mode (per-worker apply)."""
        ...

    def fleet_apply(
        self, fleet_state: Any, xs: Sequence[np.ndarray], bias: float
    ) -> List[np.ndarray]:
        """One dispatch for the whole fleet's layer-k panels."""
        ...


class _NumpyBackend:
    @property
    def state_key(self) -> str:
        return self.name

    def prepare(self, W: CSRMatrix) -> CSRMatrix:
        return W

    def fleet_prepare_all(self, layer_states):
        return None

    def fleet_apply(self, fleet_state, xs, bias):  # pragma: no cover
        raise NotImplementedError(f"{self.name} has no fleet mode")


class NumpyCsrBackend(_NumpyBackend):
    """Seed behavior: scatter-add CSR SpMM (the parity oracle)."""

    name = "numpy-csr"

    def apply(self, state: CSRMatrix, x: np.ndarray, bias: float) -> np.ndarray:
        return relu_bias_threshold(state.matmul_dense_scatter(x), bias)


class NumpyFastBackend(_NumpyBackend):
    """Segment-reduce CSR SpMM — no ``np.add.at``."""

    name = "numpy-fast"

    def apply(self, state: CSRMatrix, x: np.ndarray, bias: float) -> np.ndarray:
        return relu_bias_threshold(state.matmul_dense_fast(x), bias)


@dataclasses.dataclass
class _PallasLayerState:
    """Offline-prepared padded-BSR operands for one worker-layer shard."""

    blocks: np.ndarray      # f32[NBR, K, bm, bn]
    cols: np.ndarray        # i32[NBR, K]
    m: int                  # true output rows (unpadded)
    n: int                  # true input rows (unpadded)
    n_pad: int              # padded input height = NBC * bn


@dataclasses.dataclass
class _PallasFleetState:
    """One layer's fleet panel: every worker's operands padded to common
    [P, NBRmax, Kmax, bm, bn] so a single vmapped dispatch covers the fleet."""

    blocks: Any             # device f32[P, NBR, K, bm, bn]
    cols: Any               # device i32[P, NBR, K]
    m: List[int]
    n: List[int]
    n_pad: int


class PallasBsrBackend:
    """MXU-tiled BSR SpMM via ``kernels/bsr_spmm`` (fused bias+ReLU+clip).

    ``interpret=True`` (the default) runs the Pallas kernel through the
    interpreter, which works on CPU-only hosts; on a real TPU pass
    ``interpret=False`` for compiled MXU dispatch.
    """

    name = "pallas-bsr"

    def __init__(
        self,
        block_shape: Tuple[int, int] = (32, 32),
        batch_block: int = 128,
        interpret: bool = True,
        clip: float = ACTIVATION_CLIP,
    ):
        import jax  # gate the optional accelerator dep at construction time

        del jax
        self.block_shape = block_shape
        self.batch_block = batch_block
        self.interpret = interpret
        self.clip = clip

    @property
    def state_key(self) -> str:
        bm, bn = self.block_shape
        return f"{self.name}:{bm}x{bn}:bb{self.batch_block}:i{self.interpret}:c{self.clip}"

    # -- shape helpers -------------------------------------------------------

    def _bb(self, batch: int) -> int:
        """Largest legal batch panel: the kernel requires bb | batch."""
        return self.batch_block if batch % self.batch_block == 0 else batch

    # -- per-worker path -----------------------------------------------------

    def prepare(self, W: CSRMatrix) -> _PallasLayerState:
        bsr = bsr_from_csr(W, self.block_shape, pad=True)
        blocks, cols, _ = bsr.padded()
        return _PallasLayerState(
            blocks=blocks.astype(np.float32),
            cols=cols,
            m=W.nrows,
            n=W.ncols,
            n_pad=bsr.shape[1],
        )

    def apply(self, state: _PallasLayerState, x: np.ndarray, bias: float) -> np.ndarray:
        import jax.numpy as jnp

        from repro.kernels.bsr_spmm.ops import bsr_spmm

        batch = x.shape[1]
        if state.m == 0 or batch == 0:
            return np.zeros((state.m, batch), dtype=np.float32)
        xp = np.zeros((state.n_pad, batch), dtype=np.float32)
        xp[: state.n] = x
        y = bsr_spmm(
            jnp.asarray(state.blocks),
            jnp.asarray(state.cols),
            jnp.asarray(xp),
            bias=float(bias),
            clip=self.clip,
            batch_block=self._bb(batch),
            interpret=self.interpret,
        )
        return np.asarray(y)[: state.m]

    # -- fleet path ----------------------------------------------------------

    def fleet_prepare_all(
        self, layer_states: Sequence[Sequence[_PallasLayerState]]
    ) -> List[_PallasFleetState]:
        """Pad every worker-layer operand to the fleet-and-depth-global maxima
        so each layer's dispatch shares one jit-compiled shape."""
        import jax.numpy as jnp

        all_states = [s for layer in layer_states for s in layer]
        if not all_states:
            return []
        bm, bn = self.block_shape
        nbr_max = max(1, max(s.blocks.shape[0] for s in all_states))
        k_max = max(1, max(s.blocks.shape[1] for s in all_states))
        n_pad_max = max(bn, max(s.n_pad for s in all_states))
        out: List[_PallasFleetState] = []
        for states in layer_states:
            P = len(states)
            blocks = np.zeros((P, nbr_max, k_max, bm, bn), dtype=np.float32)
            cols = np.zeros((P, nbr_max, k_max), dtype=np.int32)
            for i, s in enumerate(states):
                nbr, k = s.blocks.shape[:2]
                blocks[i, :nbr, :k] = s.blocks
                cols[i, :nbr, :k] = s.cols
            out.append(
                _PallasFleetState(
                    blocks=jnp.asarray(blocks),
                    cols=jnp.asarray(cols),
                    m=[s.m for s in states],
                    n=[s.n for s in states],
                    n_pad=n_pad_max,
                )
            )
        return out

    def fleet_apply(
        self, fleet_state: _PallasFleetState, xs: Sequence[np.ndarray], bias: float
    ) -> List[np.ndarray]:
        import jax.numpy as jnp

        from repro.kernels.bsr_spmm.ops import bsr_spmm_fleet

        P = len(xs)
        batch = xs[0].shape[1]
        X = np.zeros((P, fleet_state.n_pad, batch), dtype=np.float32)
        for i, x in enumerate(xs):
            X[i, : x.shape[0]] = x
        y = np.asarray(
            bsr_spmm_fleet(
                fleet_state.blocks,
                fleet_state.cols,
                jnp.asarray(X),
                bias=float(bias),
                clip=self.clip,
                batch_block=self._bb(batch),
                interpret=self.interpret,
            )
        )
        return [y[i, : fleet_state.m[i]] for i in range(P)]


_REGISTRY: Dict[str, type] = {
    NumpyCsrBackend.name: NumpyCsrBackend,
    NumpyFastBackend.name: NumpyFastBackend,
    PallasBsrBackend.name: PallasBsrBackend,
}
BACKEND_NAMES = tuple(_REGISTRY)


def get_backend(backend: Union[str, ComputeBackend, None]) -> ComputeBackend:
    """Resolve a backend name (or pass an instance through).

    ``None`` resolves to ``numpy-fast``, the default since PR 1.
    """
    if backend is None:
        backend = "numpy-fast"
    if isinstance(backend, str):
        try:
            return _REGISTRY[backend]()
        except KeyError:
            raise ValueError(
                f"unknown compute backend {backend!r}; options: {BACKEND_NAMES}"
            ) from None
        except ImportError as e:  # pallas-bsr without jax installed
            raise ImportError(
                f"backend {backend!r} needs jax; install it or use "
                f"'numpy-fast'"
            ) from e
    return backend
