"""Sharded, async, integrity-checked checkpointing.

Layout: ``<dir>/step_<N>/`` with one ``.npy`` per pytree leaf (path-encoded
file names) plus ``manifest.json`` holding the tree structure, shapes,
dtypes, per-leaf CRC32s, the step and a config fingerprint.

Key properties for the fault-tolerance story (DESIGN.md §6):

* **restart** — ``restore`` rebuilds the exact pytree; together with the
  step-keyed data pipeline, training resumes bit-identically (tested);
* **elastic resharding** — restore takes a ``shardings`` pytree, so a
  checkpoint written on mesh A loads onto mesh B (device_put does the
  resharding);
* **async** — ``save_async`` snapshots to host memory synchronously (cheap)
  and writes files on a background thread, overlapping IO with the next
  training steps;
* **integrity** — CRC32 per leaf, verified on restore.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import zlib
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np

PyTree = Any

_SEP = "__"

# numpy can't serialize ml_dtypes custom dtypes — store a same-width integer
# view and record the logical dtype in the manifest
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_saveable(arr: np.ndarray):
    name = str(arr.dtype)
    if name in _VIEW_AS:
        return arr.view(_VIEW_AS[name]), name
    return arr, name


def _from_saved(arr: np.ndarray, logical_dtype: str) -> np.ndarray:
    if logical_dtype in _VIEW_AS:
        return arr.view(getattr(ml_dtypes, logical_dtype))
    return arr


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(e))
        flat[_SEP.join(keys)] = np.asarray(leaf)
    return flat


def save(ckpt_dir: str, step: int, tree: PyTree, meta: Optional[dict] = None) -> str:
    """Synchronous save; returns the step directory."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "meta": meta or {}, "leaves": {}}
    for name, arr in flat.items():
        fname = f"{zlib.crc32(name.encode()):08x}.npy"
        saveable, logical = _to_saveable(arr)
        np.save(os.path.join(tmp, fname), saveable)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical,
            "crc32": zlib.crc32(np.ascontiguousarray(saveable).tobytes()),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(out):
        shutil.rmtree(out)
    os.replace(tmp, out)  # atomic publish
    return out


class AsyncCheckpointer:
    """Snapshot-to-host then write on a worker thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None

    def save_async(self, step: int, tree: PyTree, meta: Optional[dict] = None):
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_tree, meta)

    def _write(self, step, host_tree, meta):
        path = save(self.ckpt_dir, step, host_tree, meta)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(latest_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None


def latest_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def restore(
    ckpt_dir: str,
    tree_like: PyTree,
    step: Optional[int] = None,
    shardings: Optional[PyTree] = None,
    verify: bool = True,
) -> tuple[PyTree, int]:
    """Load a checkpoint into the structure of ``tree_like``.

    ``shardings`` (a NamedSharding pytree for a possibly *different* mesh)
    reshards on load — the elastic-scaling path.
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    step = steps[-1] if step is None else step
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(tree_like)
    missing = set(flat_like) - set(manifest["leaves"])
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]} …")

    arrays: Dict[str, np.ndarray] = {}
    for name in flat_like:
        entry = manifest["leaves"][name]
        arr = np.load(os.path.join(src, entry["file"]))
        if verify:
            crc = zlib.crc32(np.ascontiguousarray(arr).tobytes())
            if crc != entry["crc32"]:
                raise IOError(f"CRC mismatch for {name} in {src}")
        arrays[name] = _from_saved(arr, entry["dtype"])

    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: hasattr(x, "device_set") or x is None
        )[0]
    out_leaves = []
    for i, (path, like) in enumerate(leaves_paths):
        keys = []
        for e in path:
            if isinstance(e, jax.tree_util.DictKey):
                keys.append(str(e.key))
            elif isinstance(e, jax.tree_util.SequenceKey):
                keys.append(str(e.idx))
            else:
                keys.append(str(e))
        arr = arrays[_SEP.join(keys)]
        if shard_flat is not None and shard_flat[i] is not None:
            out_leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            out_leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), step
