"""Training loop with checkpoint/restart, deterministic data, and optional
gradient compression — the fault-tolerance substrate (DESIGN.md §6).

``Trainer.fit`` runs steps from the last checkpoint (or 0) to ``total_steps``.
Restartability contract: (params, opt_state) from the checkpoint + the
step-keyed pipeline ⇒ resuming after a crash reproduces the exact same
parameter trajectory (tested in tests/test_fault_tolerance.py, including
crash-mid-run and elastic-mesh restore).
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.data.pipeline import PipelineSpec
from repro.distributed.compression import Int8Compressor
from repro.models.registry import get_model
from repro.training import checkpoint as ckpt
from repro.training.optimizer import get_optimizer
from repro.training.train_state import make_train_step

PyTree = Any


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 20
    ckpt_every: int = 5
    ckpt_dir: Optional[str] = None
    base_lr: float = 3e-4
    warmup: int = 2
    microbatches: int = 1
    compress_grads: bool = False
    log_every: int = 1
    async_ckpt: bool = False
    stop_after: int = 0          # crash simulation: stop early (0 = run all)


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 tcfg: TrainerConfig, seed: int = 0):
        self.cfg = cfg
        self.shape = shape
        self.tcfg = tcfg
        self.model = get_model(cfg)
        self.pipeline = PipelineSpec(cfg, shape, seed=seed)
        self.optimizer = get_optimizer(cfg, total_steps=tcfg.total_steps,
                                       base_lr=tcfg.base_lr, warmup=tcfg.warmup)
        self.compressor = Int8Compressor() if tcfg.compress_grads else None
        self.seed = seed
        self._build_step()

    def _build_step(self):
        loss_fn = self.model.loss_fn
        if self.compressor is not None:
            comp = self.compressor

            def step(params, opt_state, error, batch):
                loss, grads = jax.value_and_grad(loss_fn)(params, batch)
                quant, error = comp.compress(grads, error)
                grads = comp.decompress(quant)
                new_p, new_o, metrics = self.optimizer.update(
                    grads, opt_state, params)
                metrics = dict(metrics)
                metrics["loss"] = loss
                return new_p, new_o, error, metrics

            self.train_step = jax.jit(step)
        else:
            base = make_train_step(loss_fn, self.optimizer,
                                   microbatches=self.tcfg.microbatches)
            self.train_step = jax.jit(base)

    def init_state(self):
        params = self.model.init(jax.random.key(self.seed))
        opt_state = self.optimizer.init(params)
        error = self.compressor.init(params) if self.compressor else None
        return params, opt_state, error

    def fit(self, resume: bool = True) -> Dict[str, list]:
        params, opt_state, error = self.init_state()
        start_step = 0
        saver = None
        if self.tcfg.ckpt_dir:
            os.makedirs(self.tcfg.ckpt_dir, exist_ok=True)
            if resume and ckpt.latest_steps(self.tcfg.ckpt_dir):
                state, start_step = ckpt.restore(
                    self.tcfg.ckpt_dir,
                    {"params": params, "opt": opt_state},
                )
                params, opt_state = state["params"], state["opt"]
            if self.tcfg.async_ckpt:
                saver = ckpt.AsyncCheckpointer(self.tcfg.ckpt_dir)

        history: Dict[str, list] = {"step": [], "loss": []}
        stop = self.tcfg.stop_after or self.tcfg.total_steps
        for step in range(start_step, min(stop, self.tcfg.total_steps)):
            batch = self.pipeline.device_batch(step)
            if self.compressor is not None:
                params, opt_state, error, metrics = self.train_step(
                    params, opt_state, error, batch)
            else:
                params, opt_state, metrics = self.train_step(
                    params, opt_state, batch)
            loss = float(metrics["loss"])
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            history["step"].append(step)
            history["loss"].append(loss)
            done = step + 1
            if self.tcfg.ckpt_dir and (done % self.tcfg.ckpt_every == 0
                                       or done == self.tcfg.total_steps):
                tree = {"params": params, "opt": opt_state}
                if saver is not None:
                    saver.save_async(done, tree)
                else:
                    ckpt.save(self.tcfg.ckpt_dir, done, tree)
        if saver is not None:
            saver.wait()
        self.params = params
        self.opt_state = opt_state
        return history
