"""Optimizers + LR schedules, pure JAX.

* AdamW — fp32 moments, decoupled weight decay, global-norm clipping.
* Adafactor — factored second moments (no first moment): the 1T-param MoE's
  optimizer states must fit in HBM alongside bf16 params+grads (DESIGN.md §6).
* Schedules: linear-warmup cosine, and WSD (warmup-stable-decay) for
  minicpm [arXiv:2404.06395].

Each optimizer is an (init, update) pair over pytrees, plus ``state_pspecs``
deriving optimizer-state PartitionSpecs from the parameter specs (states
shard exactly like their parameters; factored states drop the corresponding
dim).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable[[jnp.ndarray], jnp.ndarray]:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        t = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1,
                 min_frac: float = 0.01) -> Callable[[jnp.ndarray], jnp.ndarray]:
    """Warmup-Stable-Decay (minicpm): flat plateau, short final decay."""
    decay_start = int(total * (1.0 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        t = jnp.clip((step - decay_start) / jnp.maximum(1.0, total - decay_start),
                     0.0, 1.0)
        decay = base_lr * jnp.exp(jnp.log(jnp.maximum(min_frac, 1e-8)) * t)
        out = jnp.where(step < warmup, warm, base_lr)
        return jnp.where(step >= decay_start, decay, out)
    return lr


def get_schedule(name: str, base_lr: float, warmup: int, total: int):
    if name == "wsd":
        return wsd_schedule(base_lr, warmup, total)
    return cosine_schedule(base_lr, warmup, total)


# ---------------------------------------------------------------------------
# common utilities
# ---------------------------------------------------------------------------


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        tree), norm


def _is_matrix(x) -> bool:
    return x.ndim >= 2


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AdamW:
    schedule: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params: PyTree) -> PyTree:
        zeros = lambda x: jnp.zeros(x.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: PyTree, state: PyTree, params: PyTree):
        grads, gnorm = clip_by_global_norm(grads, self.clip_norm)
        step = state["step"] + 1
        lr = self.schedule(step)
        b1, b2 = self.b1, self.b2

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v2 / (1 - b2 ** step.astype(jnp.float32))
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, {
            "grad_norm": gnorm, "lr": lr,
        }

    def state_pspecs(self, param_specs: PyTree, params_shape: PyTree) -> PyTree:
        return {
            "m": param_specs,
            "v": param_specs,
            "step": P(),
        }


# ---------------------------------------------------------------------------
# Adafactor (factored second moments, no momentum)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Adafactor:
    schedule: Callable
    decay: float = 0.99
    eps: float = 1e-30
    clip_threshold: float = 1.0
    weight_decay: float = 0.0

    def init(self, params: PyTree) -> PyTree:
        def vr(x):
            if _is_matrix(x):
                return jnp.zeros(x.shape[:-1], jnp.float32)
            return jnp.zeros(x.shape, jnp.float32)

        def vc(x):
            if _is_matrix(x):
                return jnp.zeros(x.shape[:-2] + x.shape[-1:], jnp.float32)
            return jnp.zeros((0,), jnp.float32)

        return {
            "vr": jax.tree.map(vr, params),
            "vc": jax.tree.map(vc, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads: PyTree, state: PyTree, params: PyTree):
        step = state["step"] + 1
        lr = self.schedule(step)
        d = self.decay

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if _is_matrix(g):
                vr2 = d * vr + (1 - d) * g2.mean(axis=-1)
                vc2 = d * vc + (1 - d) * g2.mean(axis=-2)
                # factored precondition: g / sqrt(outer(vr, vc) / mean(vr))
                u = g * jax.lax.rsqrt(
                    jnp.einsum("...r,...c->...rc", vr2, vc2)
                    / jnp.maximum(vr2.mean(axis=-1)[..., None, None], self.eps)
                    + self.eps
                )
            else:
                vr2 = d * vr + (1 - d) * g2
                vc2 = vc
                u = g * jax.lax.rsqrt(vr2 + self.eps)
            # update clipping (RMS ≤ threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            if self.weight_decay and p.ndim >= 2:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr2, vc2

        out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"vr": pick(1), "vc": pick(2), "step": step}, {"lr": lr}

    def state_pspecs(self, param_specs: PyTree, params_shape: PyTree) -> PyTree:
        def pad(spec, ndim):
            t = tuple(spec)
            return (None,) * (ndim - len(t)) + t

        def vr_spec(spec, shp):
            nd = len(shp.shape)
            if nd >= 2:
                return P(*pad(spec, nd)[:-1])
            return P(*pad(spec, nd))

        def vc_spec(spec, shp):
            nd = len(shp.shape)
            if nd >= 2:
                s = pad(spec, nd)
                return P(*(s[:-2] + (s[-1],)))
            return P()

        is_p = lambda x: isinstance(x, P)
        return {
            "vr": jax.tree.map(vr_spec, param_specs, params_shape, is_leaf=is_p),
            "vc": jax.tree.map(vc_spec, param_specs, params_shape, is_leaf=is_p),
            "step": P(),
        }


def get_optimizer(cfg, total_steps: int = 10_000, base_lr: float = 3e-4,
                  warmup: int = 200):
    sched = get_schedule(cfg.lr_schedule, base_lr, warmup, total_steps)
    if cfg.optimizer == "adafactor":
        return Adafactor(schedule=sched)
    return AdamW(schedule=sched)
