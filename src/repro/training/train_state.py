"""Train state + train-step factory with microbatched gradient accumulation."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def make_train_step(
    loss_fn: Callable[[PyTree, PyTree], jnp.ndarray],
    optimizer,
    microbatches: int = 1,
    grad_shardings: Optional[PyTree] = None,
) -> Callable:
    """Returns ``train_step(params, opt_state, batch) -> (params, opt, metrics)``.

    ``microbatches > 1`` splits the global batch along dim 0 and accumulates
    gradients with a ``lax.scan`` — activation memory scales with the
    microbatch, enabling the 1T-param cells (DESIGN.md §6).

    ``grad_shardings`` (NamedSharding pytree matching params) pins the
    accumulator's layout — without it the scan carry can end up replicated,
    multiplying temp memory by the model-axis size.
    """

    def pin(tree: PyTree) -> PyTree:
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params: PyTree, opt_state: PyTree, batch: PyTree):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = pin(grads)
        else:
            def reshape(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb_batch = jax.tree.map(reshape, batch)
            zero = pin(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

            def accum(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = pin(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads))
                return (loss_acc + loss, grad_acc), None

            (loss, grads), _ = jax.lax.scan(accum, (jnp.zeros(()), zero), mb_batch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt, metrics = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step
