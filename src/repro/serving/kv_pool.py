"""Block-granular paged KV pool for the continuous-batching scheduler.

The PR 4 :class:`repro.core.backends.KVCacheLayout` already pads every cache
capacity to a ``block_k`` multiple at prefill, so the kernel-native
``[..., B, KV, S, D]`` buffers are born block-aligned — paging falls out of
the existing blocks.  This module turns that alignment into an allocator:

* :class:`BlockAllocator` — host-side free-list over ``num_blocks`` physical
  pages.  Requests allocate ``layout.blocks_for(prompt + max_new)`` pages at
  admission and free them at retirement; pages are reused defrag-free (a
  block table makes any scatter of physical pages look contiguous to the
  decode step).
* :class:`KVBlockPool` — the device side: one buffer per *growing* KV leaf
  of the family cache (``ModelApi.cache_seq_axes`` classifies leaves), laid
  out ``[num_blocks, block_k, *rest, D]`` where the per-slot leaf is
  ``[*rest, S, D]``.  ``gather`` rebuilds contiguous per-slot caches from
  block tables inside the jitted decode step; ``scatter_token`` writes each
  slot's newly decoded KV chunk back to its physical page.

Two physical pages are reserved:

* block 0 — **null**: pads short block tables to the fixed table width.  It
  is never allocated and never written, so it stays zero; reads of it land
  at positions ≥ the request's ``length`` and are exactly masked out by the
  decode attention (score → -1e30 → probability exactly 0.0).
* block 1 — **sink**: inactive slots' per-step writes are redirected here so
  a retired slot can never corrupt a page that was freed and re-allocated to
  a live request.  Its content is garbage by design and never read by an
  active slot.

Bitwise note: the differential suite (``tests/test_continuous_batching.py``)
holds the scheduler to *bitwise* logit equality with the solo static oracle.
That is only possible because masked positions contribute exactly +0.0 to
the attention sum regardless of the stale values a reused page holds — the
mask is applied to scores before the softmax, so stale K produces a -1e30
score (probability exactly 0.0) and stale V is multiplied by that exact
zero.  Freed-page reuse therefore needs no zeroing.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import KVCacheLayout

PyTree = Any

NULL_BLOCK = 0
SINK_BLOCK = 1
RESERVED_BLOCKS = 2

__all__ = ["BlockAllocator", "KVBlockPool", "PoolExhausted",
           "NULL_BLOCK", "SINK_BLOCK", "RESERVED_BLOCKS",
           "split_cache", "merge_cache"]


class PoolExhausted(RuntimeError):
    """Raised when an admission asks for more pages than are free."""


class BlockAllocator:
    """Host-side free-list over the pool's physical pages.

    Invariants (property-tested in ``tests/test_continuous_batching.py``):
    a page is never handed out twice while live, ``free`` rejects pages that
    are not live, and after every request retires the pool is back to fully
    free.  Reserved pages (null/sink) are never allocated.
    """

    def __init__(self, num_blocks: int, reserved: int = RESERVED_BLOCKS):
        if num_blocks <= reserved:
            raise ValueError(
                f"pool needs more than the {reserved} reserved blocks, "
                f"got num_blocks={num_blocks}")
        self.num_blocks = int(num_blocks)
        self.reserved = int(reserved)
        # LIFO free-list, seeded so pages are first handed out in ascending
        # id order (makes failures reproducible).
        self._free: List[int] = list(range(num_blocks - 1, reserved - 1, -1))
        self._live: set = set()

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        return len(self._live)

    def alloc(self, n: int) -> List[int]:
        if n <= 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, only {len(self._free)} free "
                f"(pool={self.num_blocks}, live={len(self._live)})")
        ids = [self._free.pop() for _ in range(n)]
        self._live.update(ids)
        return ids

    def free(self, ids: Sequence[int]) -> None:
        for b in ids:
            if b not in self._live:
                raise ValueError(
                    f"double free / free of unallocated block {b}")
            self._live.discard(b)
            self._free.append(b)


def _is_none(x) -> bool:
    return x is None


def split_cache(cache: PyTree, seq_axes: PyTree) -> Tuple[PyTree, PyTree]:
    """Split a family cache into (paged, slot_resident) by ``seq_axes``.

    Both halves keep the full tree structure; the complementary leaves are
    ``None`` (vmap-in_axes convention — traverse with ``is_leaf``)."""
    paged = jax.tree_util.tree_map(
        lambda ax, leaf: leaf if ax is not None else None,
        seq_axes, cache, is_leaf=_is_none)
    resident = jax.tree_util.tree_map(
        lambda ax, leaf: None if ax is not None else leaf,
        seq_axes, cache, is_leaf=_is_none)
    return paged, resident


def merge_cache(paged: PyTree, resident: PyTree, seq_axes: PyTree) -> PyTree:
    """Inverse of :func:`split_cache`."""
    return jax.tree_util.tree_map(
        lambda ax, p, r: p if ax is not None else r,
        seq_axes, paged, resident, is_leaf=_is_none)


@dataclasses.dataclass
class KVBlockPool:
    """Device-side paged storage for the growing KV leaves of one family.

    ``buffers`` mirrors the cache tree structure with ``None`` at
    slot-resident leaves; each paged leaf is ``[num_blocks, block_k, *rest,
    D]`` for a per-slot leaf of shape ``[*rest, S, D]`` (seq axis -2).
    ``table_width`` fixes the block-table width (`S_slot = table_width *
    block_k` is the static capacity every gathered per-slot cache has), so
    admission/retirement never changes a traced shape.
    """

    layout: KVCacheLayout
    num_blocks: int
    table_width: int
    seq_axes: PyTree
    buffers: PyTree
    allocator: BlockAllocator

    @classmethod
    def build(cls, slot_cache_template: PyTree, seq_axes: PyTree,
              layout: KVCacheLayout, num_blocks: int) -> "KVBlockPool":
        """Allocate pool buffers for one slot's cache template (a B=1 cache
        pytree or ShapeDtypeStructs) whose paged leaves have the pool's slot
        capacity ``S_slot`` at axis -2."""
        bk = max(1, int(layout.block_k))
        widths = set()

        def mk(ax, leaf):
            if ax is None:
                return None
            s = leaf.shape[-2]
            layout.check_capacity(s)
            widths.add(s // bk)
            rest = leaf.shape[:-2] + leaf.shape[-1:]
            return jnp.zeros((num_blocks, bk) + rest, leaf.dtype)

        buffers = jax.tree_util.tree_map(mk, seq_axes, slot_cache_template,
                                         is_leaf=_is_none)
        if len(widths) > 1:
            raise ValueError(
                f"paged leaves disagree on capacity: {sorted(widths)} blocks")
        # Attention-free families (ssm) have no growing KV: a zero-width
        # pool whose admit/retire/gather/scatter degrade to no-ops.
        width = widths.pop() if widths else 0
        return cls(layout=layout, num_blocks=num_blocks,
                   table_width=width, seq_axes=seq_axes,
                   buffers=buffers,
                   allocator=BlockAllocator(num_blocks))

    # -- host-side admission/retirement -----------------------------------

    def admit(self, cache: PyTree, max_len: int) -> np.ndarray:
        """Allocate pages for a request needing capacity ``max_len`` and copy
        its prefilled KV into them.  Returns the request's block table
        (int32 ``[table_width]``, padded with the null block)."""
        if self.table_width == 0:
            return np.zeros((0,), np.int32)
        n = self.layout.blocks_for(max_len)
        if n > self.table_width:
            raise ValueError(
                f"request needs {n} pages but tables hold {self.table_width}")
        ids = self.allocator.alloc(n)
        bk = max(1, int(self.layout.block_k))
        idx = jnp.asarray(ids, jnp.int32)

        def write(ax, buf, leaf):
            if ax is None:
                return buf
            # [*rest, S, D] → per-page chunks [n, bk, *rest, D]
            x = jnp.moveaxis(leaf, -2, 0)[: n * bk]
            x = x.reshape((n, bk) + x.shape[1:])
            return buf.at[idx].set(x.astype(buf.dtype))

        self.buffers = jax.tree_util.tree_map(
            write, self.seq_axes, self.buffers, cache, is_leaf=_is_none)
        table = np.full((self.table_width,), NULL_BLOCK, np.int32)
        table[:n] = ids
        return table

    def retire(self, table: np.ndarray, n_blocks: int) -> None:
        """Free a retired request's pages (the first ``n_blocks`` table
        entries; the rest are null padding)."""
        self.allocator.free([int(b) for b in table[:n_blocks]])

    # -- jit-side gather / scatter ----------------------------------------

    def gather(self, buffers: PyTree, tables: jnp.ndarray) -> PyTree:
        """Rebuild contiguous per-slot caches from block tables.

        ``tables``: int32 ``[slots, table_width]``.  Returns the paged half
        of the cache tree with a leading slot axis: ``[slots, *rest, S_slot,
        D]`` per leaf.  Pure gather — safe inside jit/vmap tracing.
        """

        def g(ax, buf):
            if ax is None:
                return None
            x = buf[tables]                      # [slots, W, bk, *rest, D]
            s = x.shape[0]
            x = x.reshape((s, x.shape[1] * x.shape[2]) + x.shape[3:])
            return jnp.moveaxis(x, 1, -2)        # [slots, *rest, S, D]

        return jax.tree_util.tree_map(g, self.seq_axes, buffers,
                                      is_leaf=_is_none)

    def scatter_token(self, buffers: PyTree, chunks: PyTree,
                      tables: jnp.ndarray, positions: jnp.ndarray,
                      active: jnp.ndarray) -> PyTree:
        """Write each slot's newly decoded KV chunk to its physical page.

        ``chunks``: paged tree with per-slot leaves ``[slots, *rest, D]``
        (the decode step's write at ``positions[slot]``, already extracted
        from the gathered cache).  Inactive slots are redirected to the sink
        page so they can never touch a re-allocated one.  Two active slots
        never collide (they own disjoint pages); sink collisions are
        harmless because the sink is never read.
        """
        if self.table_width == 0:
            return buffers
        bk = max(1, int(self.layout.block_k))
        slot_ix = jnp.arange(tables.shape[0])
        # Clip so a long-vacant slot's (discarded) position can't index past
        # the table; active positions are < capacity by allocation.
        block_ix = jnp.clip(positions // bk, 0, tables.shape[1] - 1)
        page = tables[slot_ix, block_ix]
        page = jnp.where(active, page, SINK_BLOCK)
        off = positions % bk

        def s(ax, buf, chunk):
            if ax is None:
                return buf
            return buf.at[page, off].set(chunk.astype(buf.dtype))

        return jax.tree_util.tree_map(s, self.seq_axes, buffers, chunks,
                                      is_leaf=_is_none)
