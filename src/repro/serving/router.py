"""Cost-model-driven execution routing (paper §IV-C made executable).

Given a request profile (model bytes, expected exchange volume, latency
priority) the router picks:

* on the serverless substrate: Serial vs FSD-Inf-Queue vs FSD-Inf-Object and
  the worker count P — directly via ``core.cost_model.recommend_configuration``;
* on the TPU substrate: the slice size (how many chips to dedicate) by the
  same logic transposed to time-cost — smallest slice whose HBM fits the
  model + cache with the latency target met, preferring fewer chips (the
  'Serial' analogue) until memory or latency forces scale-out.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Tuple

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.backends import KVCacheLayout, cache_layout_for, get_backend
from repro.core.cost_model import TPU_V5E, recommend_configuration

Channel = Literal["serial", "queue", "object"]


@dataclasses.dataclass
class ServerlessRoute:
    channel: Channel
    workers: int


@dataclasses.dataclass
class DecodePlan:
    """A routed decode configuration: which attention backend runs the
    per-step hot path, and the :class:`KVCacheLayout` its caches must be
    allocated with (kernel-native [B, KV, S, D], capacity padded to the
    backend's block_k) — resolved once per serving configuration and
    threaded ``ServingEngine`` → ``get_model`` → family prefill/decode.

    ``cache_layout`` is ``None`` when the plan was routed without a
    ``max_len`` hint: the capacity is not known yet, and baking in a
    placeholder would pin ``block_k`` from the wrong autotune bucket (a
    capacity-1 layout chooses block_k=64; a real 2k-token cache needs 256 —
    ``pallas-splitk`` then rejects the cache at the first decode step).
    Resolve it at first use with :meth:`layout_for` once the prefill length
    is known."""

    attn_backend: str
    cache_layout: Optional[KVCacheLayout] = None

    def layout_for(self, max_len: int) -> KVCacheLayout:
        """The layout for a now-known capacity: the routed one if it was
        resolved with a hint, else derived from the backend's autotune
        table for the actual ``max_len``."""
        if self.cache_layout is not None:
            return self.cache_layout
        backend = get_backend("attention", self.attn_backend)
        return cache_layout_for(backend, max_len)


@dataclasses.dataclass
class ServingPlan:
    """A routed continuous-batching configuration for ``generate_stream``.

    ``slot_capacity`` is the static per-slot KV capacity — the longest
    request the stream may carry (prompt + new tokens + frontend), padded to
    the routed backend's ``block_k``; every admission prefills at this
    capacity so the jitted decode step's shapes never change.
    ``num_blocks`` sizes the :class:`repro.serving.kv_pool.KVBlockPool` for
    full slot occupancy plus the reserved null/sink pages."""

    decode: DecodePlan
    num_slots: int
    slot_capacity: int
    num_blocks: int

    @property
    def layout(self) -> KVCacheLayout:
        return self.decode.layout_for(self.slot_capacity)


def route_serving_plan(cfg: ModelConfig, max_request_len: int,
                       num_slots: int = 4,
                       platform: Optional[str] = None) -> ServingPlan:
    """Slot/bucket policy for the continuous-batching scheduler: route the
    decode backend for the capacity, pad the capacity to its block size, and
    size the pool so ``num_slots`` maximal requests fit simultaneously."""
    from repro.serving.kv_pool import RESERVED_BLOCKS

    decode = route_decode_plan(cfg, max_len=max_request_len,
                               platform=platform)
    layout = decode.layout_for(max_request_len)
    cap = layout.padded_len(max_request_len)
    blocks = RESERVED_BLOCKS + num_slots * layout.blocks_for(cap)
    return ServingPlan(decode=decode, num_slots=num_slots,
                       slot_capacity=cap, num_blocks=blocks)


@dataclasses.dataclass
class TpuRoute:
    chips: int
    reason: str


def route_serverless(model_bytes: int, per_layer_exchange_bytes: float,
                     n_layers: int, memory_mb: int = 4000) -> ServerlessRoute:
    ch, p, _ = recommend_configuration(
        model_bytes, per_layer_exchange_bytes, n_layers,
        memory_mb_per_worker=memory_mb,
    )
    return ServerlessRoute(channel=ch, workers=p)


def route_attention_backend(cfg: ModelConfig, max_len: Optional[int] = None,
                            platform: Optional[str] = None) -> str:
    """Pick the decode-attention backend for a serving configuration.

    The same smallest-thing-that-meets-the-profile logic as the channel /
    slice choices, applied to the per-step attention dispatch:

    * TPU → ``pallas-splitk`` (compiled split-KV kernel, MXU dispatch);
    * long caches off-TPU → ``chunked-lse`` (the dense oracle materializes a
      [B, H, S] score row per step; the streaming scan bounds that);
    * otherwise → ``dense-ref`` (cheapest to trace, oracle-exact).

    ``platform`` defaults to ``jax.default_backend()``; SSM families have no
    decode attention and always get the oracle (unused).
    """
    if cfg.is_attention_free:
        return "dense-ref"
    if platform is None:
        import jax

        platform = jax.default_backend()
    if platform == "tpu":
        return "pallas-splitk"
    if max_len is not None and max_len > 4096:
        return "chunked-lse"
    return "dense-ref"


def route_decode_plan(cfg: ModelConfig, max_len: Optional[int] = None,
                      platform: Optional[str] = None) -> DecodePlan:
    """Backend choice + the cache layout it implies, in one decision.

    ``pallas-splitk`` pins ``block_k`` from its autotune table for the
    expected capacity (so prefill pads the cache once and decode never
    re-lays it out); the view-based backends get the identity layout.
    Without a ``max_len`` hint the layout stays unresolved (``None``) —
    callers derive it from the first request's prefill length via
    :meth:`DecodePlan.layout_for` instead of inheriting a capacity-1
    placeholder from the wrong ``block_k`` bucket.
    """
    name = route_attention_backend(cfg, max_len=max_len, platform=platform)
    if max_len is None:
        return DecodePlan(attn_backend=name, cache_layout=None)
    backend = get_backend("attention", name)
    return DecodePlan(
        attn_backend=name,
        cache_layout=cache_layout_for(backend, max_len),
    )


def route_tpu(cfg: ModelConfig, shape: ShapeConfig,
              bytes_per_param: float = 2.0,
              target_step_latency_s: float = 0.1) -> TpuRoute:
    params_b = cfg.param_count() * bytes_per_param
    cache_b = 0.0
    if shape.kind == "decode":
        cache_b = (2 * (cfg.n_layers + cfg.n_encoder_layers)
                   * shape.global_batch * shape.seq_len
                   * cfg.eff_kv_heads * cfg.d_head * 2.0)
        if cfg.family == "ssm":
            cache_b = (cfg.n_layers * shape.global_batch * cfg.ssm_heads
                       * cfg.ssm_head_dim * cfg.ssm_state * 4.0)
    flops = 2.0 * cfg.active_param_count() * max(1, shape.tokens
                                                 if shape.kind != "decode"
                                                 else shape.global_batch)
    chips = 1
    for candidate in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512):
        chips = candidate
        fits = (params_b + cache_b) / candidate <= 0.85 * TPU_V5E.hbm_bytes
        fast = flops / (candidate * TPU_V5E.peak_bf16_flops) <= target_step_latency_s
        if fits and fast:
            return TpuRoute(chips=candidate,
                            reason=f"fits at {candidate} chips "
                                   f"({(params_b + cache_b)/candidate/1e9:.1f}GB/chip)")
    return TpuRoute(chips=chips, reason="requires the full 512-chip mesh")
