"""Continuous-batching request scheduler over a paged KV pool.

The static ``ServingEngine.generate`` pads one batch to one length and shares
one ``cache_len`` across every request — the ragged-``cache_len`` gap noted
since PR 4.  This module replaces that posture with real admission control
(the granularity Barrak & Ksontini show dominates serverless batch cost, and
the paper's §V-B buffering made per-request):

* a fixed-slot decode batch (``num_slots``): one jitted, donated decode step
  whose shapes never change, so admitting or retiring a request is a pure
  array update — **zero retraces** (gated by the retrace-counter test);
* per-slot caches rebuilt each step from the :class:`KVBlockPool` via block
  tables, so a request's pages are scattered physically but contiguous
  logically (defrag-free reuse);
* per-slot ``length`` — the vmap over slots turns every family's scalar
  ``length`` into one length per request *without touching family decode
  signatures*, which is what closes the shared-``cache_len`` gap;
* requests admitted mid-decode as slots free up, retired the step their
  token budget completes; admission order is FIFO over (arrival, rid).

Bitwise contract: each request's tokens and final-step logits are bitwise
equal (fp32 cache math) to the same request served alone through the static
``generate`` oracle at equal cache capacity — vmap-of-B=1 decode is
bit-identical to solo B=1 decode on XLA, and masked positions contribute
exactly +0.0 regardless of stale pool-page contents (see ``kv_pool.py``).
``tests/test_continuous_batching.py`` holds this across backends × families
× arrival orders.

The sequence-sharded variant wraps the same per-slot body in ``shard_map``
over the paged leaves' S axis, reusing the PR 4 ``decode_partial`` +
``combine_split_kv`` machinery (``seq_shard_axes``) the sharded-decode suite
already gates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.backends import KVCacheLayout
from repro.serving.kv_pool import (
    KVBlockPool, RESERVED_BLOCKS, merge_cache, split_cache)

PyTree = Any


@dataclasses.dataclass
class Request:
    """One generation request in the stream."""

    rid: int
    prompt: np.ndarray                 # [S_prompt] int32
    max_new_tokens: int
    extra: Optional[Dict[str, np.ndarray]] = None  # vlm embeds / encdec frames
    arrival: int = 0                   # earliest scheduler step for admission


@dataclasses.dataclass
class RequestResult:
    """Per-request output, directly comparable to the static oracle:
    ``tokens`` matches ``GenerationResult.tokens[r]`` and ``final_logits``
    matches ``GenerationResult.prefill_logits[r]`` (the last decode step's
    logits, the field the static path reports)."""

    rid: int
    tokens: np.ndarray                 # [max_new_tokens] int32
    final_logits: np.ndarray           # [vocab] — last decode step's logits
    prompt_len: int
    admitted_step: int
    finished_step: int


@dataclasses.dataclass
class _Slot:
    request: Request
    table: np.ndarray
    n_blocks: int
    tokens: List[int]
    admitted_step: int


class RequestScheduler:
    """Continuous batching over ``num_slots`` fixed decode slots.

    ``model`` is a :class:`repro.models.registry.ModelApi`; ``prefill_fn``
    is a jitted ``(params, batch, max_len_static) -> (logits, cache)`` (the
    engine shares its own).  ``slot_capacity`` is the static per-slot cache
    capacity — every admitted request prefills at this capacity so gathered
    shapes are constant; it must be a ``layout.block_k`` multiple.
    ``num_blocks=None`` sizes the pool for full occupancy (every slot
    holding a maximal request) plus the two reserved pages.

    ``mesh``/``axis_name`` switch the decode step to the sequence-sharded
    variant (shard_map over the paged leaves' S axis).
    """

    def __init__(self, model, params: PyTree, prefill_fn: Callable,
                 num_slots: int, slot_capacity: int,
                 layout: Optional[KVCacheLayout] = None,
                 num_blocks: Optional[int] = None,
                 mesh=None, axis_name: str = "seq"):
        self.model = model
        self.params = params
        self._prefill = prefill_fn
        self.num_slots = int(num_slots)
        self.layout = layout or KVCacheLayout()
        self.layout.check_capacity(slot_capacity)
        self.slot_capacity = int(slot_capacity)
        if num_blocks is None:
            num_blocks = (RESERVED_BLOCKS + self.num_slots
                          * self.layout.blocks_for(slot_capacity))
        self.mesh = mesh
        self.axis_name = axis_name

        if model.cache_seq_axes is None:
            raise ValueError(
                f"family {model.cfg.family!r} exposes no cache_seq_axes")

        # Build the pool + stacked slot state from one template prefill
        # (shapes only matter; a 1-token prompt is the cheapest trace).
        template = self._template_cache()
        self.seq_axes = model.cache_seq_axes(template)
        self.pool = KVBlockPool.build(template, self.seq_axes, self.layout,
                                      num_blocks)
        self._resident = jax.tree_util.tree_map(
            lambda ax, leaf: (None if ax is not None else
                              jnp.zeros((self.num_slots,) + leaf.shape,
                                        leaf.dtype)),
            self.seq_axes, template, is_leaf=lambda x: x is None)
        # [slots, 1, 1]: vmap strips the slot axis, leaving each family the
        # [B=1, 1] token shape its decode_step expects.
        self._tokens = jnp.zeros((self.num_slots, 1, 1), jnp.int32)
        self._tables = np.zeros((self.num_slots, self.pool.table_width),
                                np.int32)
        self._active = np.zeros((self.num_slots,), bool)
        # Device copies of the host-authoritative tables/active mask: only
        # admission/retirement changes them, so steady-state decode steps
        # reuse the same device buffers instead of re-uploading every step.
        self._tables_dev = jnp.asarray(self._tables)
        self._active_dev = jnp.asarray(self._active)
        self._slots: List[Optional[_Slot]] = [None] * self.num_slots
        self._step_fn = self._build_step()
        self.steps_run = 0          # decode steps executed (bench: utilization)
        self.tokens_emitted = 0

    # ------------------------------------------------------------------ #

    def _template_cache(self) -> PyTree:
        batch = {"tokens": jnp.zeros((1, 1), jnp.int32)}
        batch.update(self._template_extra())
        _, cache = self._prefill(self.params, batch, self.slot_capacity)
        return cache

    def _template_extra(self) -> Dict[str, jnp.ndarray]:
        cfg = self.model.cfg
        if cfg.family == "vlm":
            return {"extra_embeds": jnp.zeros(
                (1, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "encdec":
            return {"frames": jnp.zeros(
                (1, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)}
        return {}

    def _build_step(self):
        model, pool, seq_axes = self.model, self.pool, self.seq_axes
        mesh, axis = self.mesh, self.axis_name

        def chunks_at(paged: PyTree, positions: jnp.ndarray) -> PyTree:
            """Per-slot KV written this step: slice seq position p from each
            paged leaf ([slots, *rest, S, D] → [slots, *rest, D])."""
            def one(ax, leaf):
                if ax is None:
                    return None
                def slot_slice(x, p):
                    sl = jax.lax.dynamic_slice_in_dim(x, p, 1, axis=-2)
                    return jnp.squeeze(sl, axis=-2)
                return jax.vmap(slot_slice)(leaf, positions)
            return jax.tree_util.tree_map(one, seq_axes, paged,
                                          is_leaf=lambda x: x is None)

        def step(params, tokens, resident, buffers, tables, active):
            positions = resident["length"]                    # [slots]
            paged = pool.gather(buffers, tables)

            def per_slot(tok, res, pg, **kw):
                cache = merge_cache(pg, res, seq_axes)
                logits, new_cache = model.decode_step(params, tok, cache,
                                                      **kw)
                new_pg, new_res = split_cache(new_cache, seq_axes)
                return logits, new_res, new_pg

            if mesh is None:
                logits, new_res, new_paged = jax.vmap(per_slot)(
                    tokens, resident, paged)
            else:
                from jax.sharding import PartitionSpec as P

                from repro.distributed.sharding import shard_map_compat

                def pspec(ax, leaf):
                    if ax is None:
                        return P()
                    nd = leaf.ndim                # [slots, *rest, S, D]
                    return P(*([None] * (nd - 2)), axis, None)

                paged_specs = jax.tree_util.tree_map(
                    pspec, seq_axes, paged, is_leaf=lambda x: x is None)
                res_specs = jax.tree_util.tree_map(lambda _: P(), resident)

                body = shard_map_compat(
                    lambda p, t, r, g: jax.vmap(
                        lambda tok, res, pg: per_slot(
                            tok, res, pg, seq_shard_axes=axis))(t, r, g),
                    mesh=mesh,
                    in_specs=(P(), P(), res_specs, paged_specs),
                    out_specs=(P(), res_specs, paged_specs),
                )
                logits, new_res, new_paged = body(params, tokens, resident,
                                                  paged)

            chunks = chunks_at(new_paged, positions)
            buffers = pool.scatter_token(buffers, chunks,
                                         tables, positions, active)
            # logits: [slots, 1, 1, V].  The greedy argmax matches the static
            # path's per-request `argmax(logits[:, -1:], -1)` elementwise.
            next_tok = jnp.argmax(logits[..., -1:, :], axis=-1) \
                .astype(jnp.int32)                       # [slots, 1, 1]
            return logits[:, 0, -1], next_tok, new_res, buffers

        # Donate the big rotating state: slot-resident stacks + pool pages.
        return jax.jit(step, donate_argnums=(2, 3))

    # ------------------------------------------------------------------ #
    # host-side admission / retirement

    def _admit(self, req: Request, step_idx: int) -> None:
        free = [i for i in range(self.num_slots) if not self._active[i]]
        slot = free[0]
        prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
        batch: Dict[str, Any] = {"tokens": jnp.asarray(prompt)}
        if req.extra:
            batch.update({k: jnp.asarray(v) for k, v in req.extra.items()})
        logits, cache = self._prefill(self.params, batch, self.slot_capacity)
        need = (prompt.shape[1] + req.max_new_tokens
                + (self.model.cfg.frontend_tokens or 0))
        n_blocks = (self.layout.blocks_for(need)
                    if self.pool.table_width else 0)
        paged, resident = split_cache(cache, self.seq_axes)
        table = self.pool.admit(paged, need)     # may raise PoolExhausted
        self._tables[slot] = table
        self._resident = jax.tree_util.tree_map(
            lambda ax, st, leaf: (st if ax is not None else
                                  st.at[slot].set(leaf.astype(st.dtype))),
            self.seq_axes, self._resident, cache,
            is_leaf=lambda x: x is None)
        first = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)  # [1,1]
        self._tokens = self._tokens.at[slot].set(first)
        self._active[slot] = True
        self._tables_dev = jnp.asarray(self._tables)
        self._active_dev = jnp.asarray(self._active)
        self._slots[slot] = _Slot(request=req, table=table,
                                  n_blocks=n_blocks, tokens=[],
                                  admitted_step=step_idx)

    def _can_admit(self, req: Request) -> bool:
        if not (~self._active).any():
            return False
        need = (len(np.asarray(req.prompt).reshape(-1)) + req.max_new_tokens
                + (self.model.cfg.frontend_tokens or 0))
        return self.layout.blocks_for(need) <= self.pool.allocator.free_blocks

    def _retire(self, slot: int, final_logits: np.ndarray,
                step_idx: int, results: List[RequestResult]) -> None:
        st = self._slots[slot]
        self.pool.retire(st.table, st.n_blocks)
        results.append(RequestResult(
            rid=st.request.rid,
            tokens=np.asarray(st.tokens, np.int32),
            final_logits=np.asarray(final_logits),
            prompt_len=int(np.asarray(st.request.prompt).reshape(-1).shape[0]),
            admitted_step=st.admitted_step,
            finished_step=step_idx,
        ))
        self._active[slot] = False
        self._active_dev = jnp.asarray(self._active)
        self._slots[slot] = None
        # Park the vacant slot at length 0 so its (discarded) decode work
        # stays in-bounds no matter how long it idles.
        self._resident = {**self._resident,
                          "length": self._resident["length"].at[slot].set(0)}

    # ------------------------------------------------------------------ #

    def run(self, requests: Sequence[Request],
            max_steps: Optional[int] = None) -> List[RequestResult]:
        """Serve the whole stream; returns results ordered by completion."""
        queue = sorted(requests, key=lambda r: (r.arrival, r.rid))
        for r in queue:
            need = (len(np.asarray(r.prompt).reshape(-1)) + r.max_new_tokens
                    + (self.model.cfg.frontend_tokens or 0))
            if need > self.slot_capacity:
                raise ValueError(
                    f"request {r.rid} needs capacity {need} > slot_capacity "
                    f"{self.slot_capacity}; raise max_request_len")
        results: List[RequestResult] = []
        step_idx = 0
        budget = max_steps if max_steps is not None else (
            sum(r.max_new_tokens for r in queue) + len(queue)
            + max((r.arrival for r in queue), default=0) + 8)
        while queue or self._active.any():
            if step_idx > budget:
                raise RuntimeError(
                    f"scheduler exceeded {budget} steps "
                    f"({len(results)}/{len(queue) + len(results)} done)")
            # FIFO admission of every arrived request that fits right now.
            while queue and queue[0].arrival <= step_idx \
                    and self._can_admit(queue[0]):
                self._admit(queue.pop(0), step_idx)
            if not self._active.any():
                step_idx += 1           # idle tick: waiting on a future arrival
                continue
            input_tokens = np.asarray(self._tokens)[:, 0, 0]
            logits, next_tok, self._resident, self.pool.buffers = \
                self._step_fn(self.params, self._tokens, self._resident,
                              self.pool.buffers, self._tables_dev,
                              self._active_dev)
            self._tokens = next_tok
            self.steps_run += 1
            logits_np = None
            for slot in range(self.num_slots):
                st = self._slots[slot]
                if st is None:
                    continue
                st.tokens.append(int(input_tokens[slot]))
                self.tokens_emitted += 1
                if len(st.tokens) == st.request.max_new_tokens:
                    if logits_np is None:
                        logits_np = np.asarray(logits)
                    self._retire(slot, logits_np[slot], step_idx, results)
            step_idx += 1
        return results
