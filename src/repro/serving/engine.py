"""Batched serving engine: prefill + decode loop over any assigned arch.

The engine mirrors the paper's batch-inference posture (§V-B: requests are
buffered and batched upstream; FSD processes the batch): prompts are padded
to a bucket, prefilled once, then decoded step-by-step with the KV/SSM cache.
Greedy sampling keeps tests deterministic.

``router.py`` decides the execution configuration (the paper's
Serial/Queue/Object choice, mapped to TPU slice sizing) before the engine
runs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.backends import KVCacheLayout, cache_layout_for, get_backend
from repro.models.registry import get_model

PyTree = Any


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray           # [B, max_new]
    prefill_logits: np.ndarray   # [B, vocab]
    steps: int
    # Set by the fabric engine: the full LmPipelineResult (billing stats,
    # dual-clock makespans, wire volumes).  None on the device path.
    fabric: Optional[Any] = None


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params: Optional[PyTree] = None,
                 seed: int = 0, attn_backend=None, max_len_hint: int = 0,
                 engine: str = "device", pipeline_P: int = 2,
                 pipeline_channel: str = "queue"):
        """``attn_backend``: decode-attention backend name/instance routed to
        every model family's decode step (``repro.core.backends``).  ``None``
        keeps the ``dense-ref`` oracle; ``"auto"`` asks the router for a
        :class:`repro.serving.router.DecodePlan` — backend plus the
        :class:`KVCacheLayout` its kernel-native caches need — from the
        platform and ``max_len_hint`` (expected cache capacity).

        ``engine="fabric"`` serves over the serverless pipeline instead of
        on-device: the layer stack splits into ``pipeline_P`` stages whose
        activations travel the ``pipeline_channel`` fabric
        (:func:`repro.faas.lm_pipeline.run_lm_pipeline`); results carry the
        billing/clock telemetry in ``GenerationResult.fabric``."""
        self.cfg = cfg
        if attn_backend == "auto":
            from repro.serving.router import route_decode_plan

            attn_backend = route_decode_plan(
                cfg, max_len=max_len_hint or None).attn_backend
        if engine not in ("device", "fabric"):
            raise ValueError(f"unknown engine {engine!r}")
        self.engine = engine
        self.pipeline_P = pipeline_P
        self.pipeline_channel = pipeline_channel
        self._stage_executors: Optional[list] = None
        self.attn_backend = get_backend("attention", attn_backend)
        self.model = get_model(cfg, attn_backend=self.attn_backend)
        self.params = params if params is not None else self.model.init(
            jax.random.key(seed))
        self._prefill = jax.jit(self.model.prefill, static_argnums=(2,))
        self._decode = jax.jit(self.model.decode_step)

    def cache_layout(self, max_len: int) -> KVCacheLayout:
        """The layout the engine's caches use for a given capacity: prefill
        (via the ``get_model`` closure) allocates
        ``[B, KV, padded_len(max_len), D]`` buffers with it."""
        return cache_layout_for(self.attn_backend, max_len)

    def generate(
        self,
        prompts: np.ndarray,            # [B, S_prompt] int32
        max_new_tokens: int = 8,
        extra: Optional[Dict[str, np.ndarray]] = None,
        max_len: Optional[int] = None,
    ) -> GenerationResult:
        """``max_len`` overrides the cache capacity (default: exactly what
        the batch needs).  The continuous-batching differential suite pins
        it to the scheduler's slot capacity so the solo oracle and the
        scheduler run bitwise-identical reduction shapes."""
        B, S = prompts.shape
        if self.engine == "fabric":
            return self._generate_fabric(prompts, max_new_tokens, extra)
        if max_len is None:
            max_len = S + max_new_tokens + (self.cfg.frontend_tokens or 0)
        batch: Dict[str, Any] = {"tokens": jnp.asarray(prompts, jnp.int32)}
        if extra:
            batch.update({k: jnp.asarray(v) for k, v in extra.items()})
        logits, cache = self._prefill(self.params, batch, max_len)
        out_tokens = []
        token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(max_new_tokens):
            out_tokens.append(np.asarray(token)[:, 0])
            logits, cache = self._decode(self.params, token, cache)
            token = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return GenerationResult(
            tokens=np.stack(out_tokens, axis=1),
            prefill_logits=np.asarray(logits[:, 0]),
            steps=max_new_tokens,
        )

    def generate_stream(
        self,
        requests,                        # Sequence[scheduler.Request]
        num_slots: int = 4,
        max_request_len: Optional[int] = None,
        mesh=None,
        axis_name: str = "seq",
    ):
        """Serve a mixed-length request stream with continuous batching.

        Requests are admitted into ``num_slots`` fixed decode slots as they
        arrive and retired the step their token budget completes; KV lives
        in a block-granular paged pool (``serving/kv_pool.py``) so slots are
        reused defrag-free mid-decode.  Returns a list of
        :class:`repro.serving.scheduler.RequestResult`, each bitwise-equal
        (fp32 cache) to serving that request alone through :meth:`generate`
        at ``max_len=slot_capacity``.

        ``max_request_len`` bounds prompt+new+frontend over the stream
        (default: measured from ``requests``); ``mesh`` switches the decode
        step to the sequence-sharded shard_map variant over ``axis_name``.

        The fabric engine has no mid-batch admission point (stage workers
        hold per-batch KV), so it degrades to per-request static pipeline
        generates behind the same API.
        """
        from repro.serving.scheduler import RequestScheduler

        requests = list(requests)
        if self.engine == "fabric":
            return self._stream_fabric(requests)
        if max_request_len is None:
            max_request_len = max(
                (np.asarray(r.prompt).reshape(-1).shape[0]
                 + r.max_new_tokens + (self.cfg.frontend_tokens or 0))
                for r in requests)
        # The pool is sized exactly like route_serving_plan's policy, but
        # from the engine's *own* backend layout (the plan re-routes the
        # backend; an explicitly constructed engine must not switch).
        layout = self.cache_layout(max_request_len)
        cap = layout.padded_len(max_request_len)
        sched = RequestScheduler(
            self.model, self.params, self._prefill,
            num_slots=num_slots, slot_capacity=cap, layout=layout,
            mesh=mesh, axis_name=axis_name)
        return sched.run(requests)

    def _stream_fabric(self, requests):
        from repro.serving.scheduler import RequestResult

        results = []
        for step, req in enumerate(sorted(requests,
                                          key=lambda r: (r.arrival, r.rid))):
            prompt = np.asarray(req.prompt, np.int32).reshape(1, -1)
            res = self._generate_fabric(prompt, req.max_new_tokens, req.extra)
            results.append(RequestResult(
                rid=req.rid, tokens=res.tokens[0],
                final_logits=res.prefill_logits[0],
                prompt_len=prompt.shape[1],
                admitted_step=step, finished_step=step))
        return results

    def _generate_fabric(
        self,
        prompts: np.ndarray,
        max_new_tokens: int,
        extra: Optional[Dict[str, np.ndarray]],
    ) -> GenerationResult:
        # Lazy import: lm_pipeline pulls the FaaS stack; the device path
        # must not depend on it.
        from repro.faas.lm_pipeline import build_stage_executors, run_lm_pipeline

        if self._stage_executors is None:
            self._stage_executors = build_stage_executors(
                self.cfg, self.params, self.pipeline_P,
                attn_backend=self.attn_backend)
        res = run_lm_pipeline(
            self.cfg, prompts, self.params,
            max_new_tokens=max_new_tokens, P=self.pipeline_P,
            channel=self.pipeline_channel, attn_backend=self.attn_backend,
            extra=extra, executors=self._stage_executors,
        )
        return GenerationResult(tokens=res.tokens, prefill_logits=res.logits,
                                steps=max_new_tokens, fabric=res)
