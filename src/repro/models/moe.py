"""Mixture-of-Experts decoder (kimi-k2-1t, deepseek-moe-16b).

Routing: top-k with normalized gate weights over the selected experts
(DeepSeek-style), optional shared experts always active, and a dense first
layer (``cfg.first_dense_layers``).

Dispatch is **grouped sort-based with static capacity** — the TPU-native
adaptation of the paper's send-only-needed-rows insight (DESIGN.md §3):

* tokens are split into ``dp_groups`` groups aligned with the data-parallel
  sharding, so the sort that ranks tokens within each expert never crosses a
  shard boundary;
* each expert accepts at most ``C = ceil(T_group·k/E · capacity_factor)``
  tokens per group (overflow drops, standard capacity-based MoE);
* expert compute is a dense einsum over the [E, C, d] dispatch buffer, which
  shards cleanly over the ``model`` (expert) axis; the gather/scatter between
  token space and expert space is where XLA inserts the all-to-all — the
  collective analogue of the FSI point-to-point exchange.

Aux: load-balance loss (Switch-style) returned in metrics.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.backends import KVCacheLayout, get_backend
from repro.models import layers as L
from repro.models.attention import chunked_causal_attention
from repro.models.kvcache import pad_kv_to_layout
from repro.models import transformer as TF

PyTree = Any
ACC = jnp.float32

# MoE decode holds the latent/KV cache at fp32 (the PR 2 bisect: kimi-k2's
# decode-vs-teacher-forcing drift came entirely from bf16 rounding of cached
# K/V — the probability row is rounded against the cache dtype, and the MoE
# router amplifies the rounding into ~2.5e-2 logit error on worst-case rows;
# with an fp32 cache all attention backends produce bitwise-identical logits).
# Costs 2× decode-cache memory for the MoE family only; the numerics story is
# documented in docs/ARCHITECTURE.md §Numerics.
DECODE_CACHE_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_moe_ffn(key, cfg: ModelConfig) -> PyTree:
    d, f, E = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, E), dtype=jnp.float32),
        "w_gate": L.dense_init(ks[1], (E, d, f)),
        "w_up": L.dense_init(ks[2], (E, d, f)),
        "w_down": L.dense_init(ks[3], (E, f, d), in_axis_size=f),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], d, f * cfg.n_shared_experts)
    return p


MOE_AXES = {
    "router": ("embed", "experts_unsharded"),
    "w_gate": ("experts", "embed", "expert_ffn"),
    "w_up": ("experts", "embed", "expert_ffn"),
    "w_down": ("experts", "expert_ffn", "embed"),
}


def init_block(key, cfg: ModelConfig, dense: bool) -> PyTree:
    k1, k2 = jax.random.split(key)
    blk = {
        "ln_attn": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.d_head,
            qkv_bias=cfg.qkv_bias,
        ),
        "ln_mlp": L.init_rms_norm(cfg.d_model),
    }
    if dense:
        blk["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff)
    else:
        blk["moe"] = init_moe_ffn(k2, cfg)
    return blk


def init(key, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_layers + 2)
    dense_blocks = [
        init_block(keys[i], cfg, dense=True) for i in range(cfg.first_dense_layers)
    ]
    moe_blocks = [
        init_block(keys[i], cfg, dense=False)
        for i in range(cfg.first_dense_layers, cfg.n_layers)
    ]
    params = {
        "embed": L.init_embedding(keys[-2], cfg.padded_vocab(), cfg.d_model),
        "dense_blocks": (
            jax.tree.map(lambda *xs: jnp.stack(xs), *dense_blocks)
            if dense_blocks else None
        ),
        "moe_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *moe_blocks),
        "ln_f": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(keys[-1], cfg.padded_vocab(), cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# routing + dispatch
# ---------------------------------------------------------------------------


def route_topk(logits: jnp.ndarray, k: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[T, E] → (weights [T, k] normalized, idx [T, k])."""
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals.astype(ACC), axis=-1)
    return w, idx


def _dispatch_tables(e_flat: jnp.ndarray, E: int, C: int):
    """Sort-based capacity dispatch within one token group.

    e_flat: [A] expert id per assignment (A = T_group·k).
    Returns (slot_token [E, C] indices into A, slot_valid [E, C]).
    """
    A = e_flat.shape[0]
    order = jnp.argsort(e_flat)                      # stable-ish grouping
    sorted_e = e_flat[order]
    # rank of each sorted entry within its expert segment
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")
    rank = jnp.arange(A) - seg_start[sorted_e]
    keep = rank < C
    # scatter sorted assignment positions into the [E, C] table; dropped
    # entries aim at column C, which is out of bounds → mode="drop"
    table = jnp.full((E, C), A, dtype=jnp.int32)     # A = sentinel (invalid)
    table = table.at[sorted_e, jnp.where(keep, rank, C)].set(
        order.astype(jnp.int32), mode="drop"
    )
    valid = table < A
    return jnp.where(valid, table, 0), valid


def moe_ffn(
    p: PyTree, x: jnp.ndarray, cfg: ModelConfig, dp_groups: int = 1,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x [B, S, d] → (out [B, S, d], metrics)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(B * S, d)
    T = B * S
    dp_groups = max(1, min(dp_groups, T))
    while T % dp_groups:
        dp_groups -= 1
    Tg = T // dp_groups
    C = max(1, int(-(-Tg * k // E) * cfg.moe_capacity_factor))

    logits = jnp.einsum("td,de->te", xf.astype(ACC), p["router"],
                        preferred_element_type=ACC)
    w, idx = route_topk(logits, k)                   # [T,k]

    # group-local dispatch (vmapped over dp groups — sort never crosses the
    # data-parallel shard boundary)
    idx_g = idx.reshape(dp_groups, Tg * k)
    tables, valids = jax.vmap(lambda e: _dispatch_tables(e, E, C))(idx_g)
    # translate group-local assignment position → global token id + gate w
    w_g = w.reshape(dp_groups, Tg * k)
    token_of_assign = (
        jnp.arange(dp_groups * Tg * k, dtype=jnp.int32).reshape(dp_groups, Tg * k)
        // k
    )
    slot_token = jnp.take_along_axis(
        token_of_assign, tables.reshape(dp_groups, E * C), axis=1
    ).reshape(dp_groups, E, C)
    slot_w = jnp.take_along_axis(
        w_g, tables.reshape(dp_groups, E * C), axis=1
    ).reshape(dp_groups, E, C)
    slot_w = jnp.where(valids, slot_w, 0.0)

    # gather tokens → [E, G·C, d] so the expert einsum shards over E; the
    # token→expert gather (and the scatter back) is where the partitioner
    # emits the all-to-all — the collective analogue of the FSI exchange
    xe = xf[slot_token.transpose(1, 0, 2).reshape(E, dp_groups * C)]
    xe = L.constrain(xe, "model", None, None)
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"], preferred_element_type=ACC)
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"], preferred_element_type=ACC)
    h = L.constrain((jax.nn.silu(gate) * up).astype(x.dtype), "model", None, None)
    oe = jnp.einsum("ecf,efd->ecd", h, p["w_down"],
                    preferred_element_type=L.TP_PSUM_DTYPE)
    oe = L.constrain(oe, "model", None, None)

    wts = slot_w.transpose(1, 0, 2).reshape(E, dp_groups * C)
    out = jnp.zeros((T, d), ACC).at[
        slot_token.transpose(1, 0, 2).reshape(E, dp_groups * C)
    ].add(oe * wts[..., None])
    out = L.constrain(out, "dp", None)

    if cfg.n_shared_experts:
        out = out + L.mlp(p["shared"], x).reshape(T, d).astype(ACC)

    # Switch-style load-balance loss (scatter-add, no [T,k,E] one-hot)
    probs = jax.nn.softmax(logits, axis=-1)
    counts = jnp.zeros((E,), ACC).at[idx.reshape(-1)].add(1.0)
    frac_tokens = counts / (T * k)
    frac_probs = probs.mean(axis=0)
    lb_loss = E * jnp.sum(frac_tokens * frac_probs)
    drop_frac = 1.0 - valids.mean()
    return out.reshape(B, S, d).astype(x.dtype), {
        "lb_loss": lb_loss, "drop_frac": drop_frac,
    }


# ---------------------------------------------------------------------------
# shard_map expert-parallel dispatch (§Perf structural optimization)
# ---------------------------------------------------------------------------

# Opt-in: EXPERIMENTS.md §Perf B5 showed XLA's propagation cannot infer a
# comm-minimal expert schedule from pjit specs.  This variant states it
# explicitly with shard_map: activations are replicated over the model axis
# (as TP already leaves them), every rank routes identically, gathers ONLY
# its local experts' tokens (a pure-local gather — the paper's "send only
# the rows the owner needs"), computes, and a single psum of the [T, d]
# output is the only cross-device traffic — one all-reduce per MoE layer,
# same as a dense TP block.
MOE_EP_SHARDMAP = False


def set_moe_ep_shardmap(on: bool) -> None:
    global MOE_EP_SHARDMAP
    MOE_EP_SHARDMAP = on


def _moe_ffn_local(p_local, x, cfg: ModelConfig, e0, E_local: int):
    """Route against all E experts; evaluate only experts [e0, e0+E_local)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    xf = x.reshape(B * S, d)
    T = B * S
    C = max(1, int(-(-T * k // E) * cfg.moe_capacity_factor))

    logits = jnp.einsum("td,de->te", xf.astype(ACC), p_local["router"],
                        preferred_element_type=ACC)
    w, idx = route_topk(logits, k)

    # keep only assignments owned by this rank; foreign ones → sentinel
    e_rel = idx.reshape(-1) - e0
    mine = (e_rel >= 0) & (e_rel < E_local)
    e_flat = jnp.where(mine, e_rel, E_local).astype(jnp.int32)
    table, valid = _dispatch_tables(e_flat, E_local + 1, C)
    table, valid = table[:E_local], valid[:E_local]

    token_of_assign = jnp.arange(T * k, dtype=jnp.int32) // k
    slot_token = jnp.where(valid, token_of_assign[table], 0)
    slot_w = jnp.where(valid, w.reshape(-1)[table], 0.0)

    xe = xf[slot_token]                              # local gather only
    gate = jnp.einsum("ecd,edf->ecf", xe, p_local["w_gate"],
                      preferred_element_type=ACC)
    up = jnp.einsum("ecd,edf->ecf", xe, p_local["w_up"],
                    preferred_element_type=ACC)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    oe = jnp.einsum("ecf,efd->ecd", h, p_local["w_down"],
                    preferred_element_type=ACC)
    out = jnp.zeros((T, d), ACC).at[slot_token].add(oe * slot_w[..., None])

    counts = jnp.zeros((E,), ACC).at[idx.reshape(-1)].add(1.0)
    lb = E * jnp.sum((counts / (T * k)) * jax.nn.softmax(logits, -1).mean(0))
    return out.reshape(B, S, d), lb


def moe_ffn_shardmap(p: PyTree, x: jnp.ndarray, cfg: ModelConfig):
    """Explicit EP schedule via shard_map (requires an active shard ctx)."""
    from jax.sharding import PartitionSpec as P

    ctx = L.shard_ctx()
    mesh, dp, model_axis = ctx["mesh"], ctx["dp"], ctx["model"]
    msize = mesh.shape[model_axis]
    E_local = cfg.n_experts // msize
    dp_spec = tuple(dp) if dp else None

    def body(x_loc, router, w_gate, w_up, w_down):
        e0 = jax.lax.axis_index(model_axis) * E_local
        out, lb = _moe_ffn_local(
            {"router": router, "w_gate": w_gate, "w_up": w_up,
             "w_down": w_down},
            x_loc, cfg, e0, E_local,
        )
        out = jax.lax.psum(out.astype(ACC), model_axis)
        return out.astype(x_loc.dtype), lb

    from repro.distributed.sharding import shard_map_compat

    out, lb = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(dp_spec, None, None), P(), P(model_axis, None, None),
                  P(model_axis, None, None), P(model_axis, None, None)),
        out_specs=(P(dp_spec, None, None), P()),
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    if cfg.n_shared_experts:  # shared experts stay on the dense TP path
        out = out + L.mlp(p["shared"], x)
    return out, {"lb_loss": lb, "drop_frac": jnp.zeros(())}


def moe_ffn_dispatch(p, x, cfg: ModelConfig, dp_groups: int = 1):
    ctx = L.shard_ctx()
    if (MOE_EP_SHARDMAP and ctx["mesh"] is not None and ctx["model"]
            and cfg.n_experts % ctx["mesh"].shape[ctx["model"]] == 0):
        return moe_ffn_shardmap(p, x, cfg)
    return moe_ffn(p, x, cfg, dp_groups)


# ---------------------------------------------------------------------------
# blocks / forward / loss
# ---------------------------------------------------------------------------


def block_train(block: PyTree, x, cfg: ModelConfig, positions, dp_groups: int):
    x = TF._attn_train(block, x, cfg, positions)
    h = L.rms_norm(x, block["ln_mlp"], cfg.norm_eps)
    if "mlp" in block and block.get("mlp") is not None:
        return x + L.mlp(block["mlp"], h), jnp.zeros((), ACC)
    out, metrics = moe_ffn_dispatch(block["moe"], h, cfg, dp_groups)
    return x + out, metrics["lb_loss"]


def forward(
    params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig,
    dp_groups: int = 1,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    x = L.embed_tokens(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    lb_total = jnp.zeros((), ACC)

    if params.get("dense_blocks") is not None:
        def dense_body(h, blk):
            h2, _ = block_train(blk, h, cfg, positions, dp_groups)
            return h2, None
        if cfg.remat:
            dense_body = jax.checkpoint(dense_body, prevent_cse=False)
        x, _ = jax.lax.scan(dense_body, x, params["dense_blocks"])

    def moe_body(carry, blk):
        h, lb = carry
        h2, lb2 = block_train(blk, h, cfg, positions, dp_groups)
        return (h2, lb + lb2), None

    if cfg.remat:
        moe_body = jax.checkpoint(moe_body, prevent_cse=False)
    (x, lb_total), _ = jax.lax.scan(moe_body, (x, lb_total), params["moe_blocks"])

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table), lb_total


def loss_fn(params: PyTree, batch, cfg: ModelConfig, dp_groups: int = 1,
            lb_coeff: float = 0.01) -> jnp.ndarray:
    logits, lb = forward(params, batch["tokens"], cfg, dp_groups)
    ce = L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                              batch.get("mask"))
    n_moe = cfg.n_layers - cfg.first_dense_layers
    return ce + lb_coeff * lb / max(1, n_moe)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def _stacked_blocks(params):
    """(blocks pytree, n) iterator helper over dense+moe stacks."""
    out = []
    if params.get("dense_blocks") is not None:
        out.append(params["dense_blocks"])
    out.append(params["moe_blocks"])
    return out


def prefill(params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: int, dp_groups: int = 1,
            layout: KVCacheLayout = KVCacheLayout()) -> Tuple[jnp.ndarray, PyTree]:
    x = L.embed_tokens(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    caches = []

    for blocks in _stacked_blocks(params):
        def body(h, blk):
            hn = L.rms_norm(h, blk["ln_attn"], cfg.norm_eps)
            q, k, v = L.qkv_project(blk["attn"], hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            o = chunked_causal_attention(q, k, v)
            h = h + L.out_project(blk["attn"], o, h.dtype)
            hm = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
            if blk.get("mlp") is not None:
                h = h + L.mlp(blk["mlp"], hm)
            else:
                out, _ = moe_ffn_dispatch(blk["moe"], hm, cfg, dp_groups)
                h = h + out
            k_pad = pad_kv_to_layout(k, max_len, layout)
            v_pad = pad_kv_to_layout(v, max_len, layout)
            return h, (k_pad.astype(DECODE_CACHE_DTYPE),
                       v_pad.astype(DECODE_CACHE_DTYPE))

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (ks, vs) = jax.lax.scan(body, x, blocks)
        caches.append({"k": ks, "v": vs})

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x[:, -1:], table)
    cache = {"stacks": caches, "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params: PyTree, token: jnp.ndarray, cache: PyTree,
                cfg: ModelConfig, dp_groups: int = 1,
                attn_backend=None, seq_shard_axes=None,
                layout: Optional[KVCacheLayout] = None) -> Tuple[jnp.ndarray, PyTree]:
    attn = get_backend("attention", attn_backend)
    if layout is not None:
        layout.check_capacity(int(cache["stacks"][-1]["k"].shape[3]))
    x = L.embed_tokens(params["embed"], token)
    B = x.shape[0]
    pos = cache["length"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    new_stacks = []

    for blocks, kv in zip(_stacked_blocks(params), cache["stacks"]):
        def body(h, inp):
            blk, k_cache, v_cache = inp
            hn = L.rms_norm(h, blk["ln_attn"], cfg.norm_eps)
            q, k, v = L.qkv_project(blk["attn"], hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            o, k_cache, v_cache = TF._decode_attn(
                attn, q, k, v, k_cache, v_cache, pos, seq_shard_axes)
            h = h + L.out_project(blk["attn"], o.astype(h.dtype), h.dtype)
            hm = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
            if blk.get("mlp") is not None:
                h = h + L.mlp(blk["mlp"], hm)
            else:
                out, _ = moe_ffn_dispatch(blk["moe"], hm, cfg, dp_groups)
                h = h + out
            return h, (k_cache, v_cache)

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, kv["k"], kv["v"]))
        new_stacks.append({"k": ks, "v": vs})

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x, table)
    return logits, {"stacks": new_stacks, "length": pos + 1}


# ---------------------------------------------------------------------------
# pipeline stages (serverless LM executor)
# ---------------------------------------------------------------------------
#
# Global layer index ``l`` maps to ``dense_blocks[l]`` for
# ``l < cfg.first_dense_layers`` and ``moe_blocks[l - first_dense_layers]``
# otherwise.  A stage slices each stack it straddles; running the full scans
# as consecutive sub-scans over contiguous slices executes the same per-layer
# ops in the same order, so chained stages reproduce the monolithic numerics.


def _stage_stacks(cfg: ModelConfig, start: int, stop: int):
    """(dense_range, moe_range) a [start, stop) slice covers — either may be
    empty.  The moe range is stack-local (offset by first_dense_layers)."""
    fd = cfg.first_dense_layers
    dense = (start, min(stop, fd))
    m = (max(start, fd) - fd, stop - fd)
    return (dense if dense[1] > dense[0] else None,
            m if m[1] > m[0] else None)


def slice_stage_params(params: PyTree, spec, cfg: ModelConfig) -> PyTree:
    dense_r, moe_r = _stage_stacks(cfg, spec.start, spec.stop)
    out: Dict[str, Any] = {
        "dense_blocks": (
            jax.tree.map(lambda a: a[dense_r[0]:dense_r[1]],
                         params["dense_blocks"]) if dense_r else None
        ),
        "moe_blocks": (
            jax.tree.map(lambda a: a[moe_r[0]:moe_r[1]], params["moe_blocks"])
            if moe_r else None
        ),
    }
    if spec.has_embed:
        out["embed"] = params["embed"]
    if spec.has_head:
        out["ln_f"] = params["ln_f"]
        if "unembed" in params:
            out["unembed"] = params["unembed"]
        elif not spec.has_embed:
            out["embed"] = params["embed"]  # tied head needs the table
    return out


def _present_stacks(sp: PyTree):
    out = []
    if sp.get("dense_blocks") is not None:
        out.append(sp["dense_blocks"])
    if sp.get("moe_blocks") is not None:
        out.append(sp["moe_blocks"])
    return out


def stage_prefill(
    sp: PyTree, spec, x_in: jnp.ndarray, cfg: ModelConfig, max_len: int,
    dp_groups: int = 1,
    layout: KVCacheLayout = KVCacheLayout(),
) -> Tuple[jnp.ndarray, PyTree]:
    """One stage of ``prefill`` — token ids [B, S] in on the embedding stage,
    hidden states [B, S, d] otherwise; logits [B, 1, V] out on the head
    stage.  The stage's KV stacks stay resident in its cache."""
    if spec.has_embed:
        x = L.embed_tokens(sp["embed"], x_in)
    else:
        x = x_in
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    caches = []

    for blocks in _present_stacks(sp):
        def body(h, blk):
            hn = L.rms_norm(h, blk["ln_attn"], cfg.norm_eps)
            q, k, v = L.qkv_project(blk["attn"], hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            o = chunked_causal_attention(q, k, v)
            h = h + L.out_project(blk["attn"], o, h.dtype)
            hm = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
            if blk.get("mlp") is not None:
                h = h + L.mlp(blk["mlp"], hm)
            else:
                out, _ = moe_ffn_dispatch(blk["moe"], hm, cfg, dp_groups)
                h = h + out
            k_pad = pad_kv_to_layout(k, max_len, layout)
            v_pad = pad_kv_to_layout(v, max_len, layout)
            return h, (k_pad.astype(DECODE_CACHE_DTYPE),
                       v_pad.astype(DECODE_CACHE_DTYPE))

        if cfg.remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, (ks, vs) = jax.lax.scan(body, x, blocks)
        caches.append({"k": ks, "v": vs})

    cache = {"stacks": caches, "length": jnp.asarray(S, jnp.int32)}
    if spec.has_head:
        x = L.rms_norm(x[:, -1:], sp["ln_f"], cfg.norm_eps)
        table = sp["embed"] if cfg.tie_embeddings else sp["unembed"]
        return L.unembed(x, table), cache
    return x, cache


def stage_decode_step(
    sp: PyTree, spec, x_in: jnp.ndarray, cache: PyTree, cfg: ModelConfig,
    dp_groups: int = 1, *, attn_backend=None,
) -> Tuple[jnp.ndarray, PyTree]:
    """One stage of ``decode_step`` — token [B, 1] in on the embedding stage,
    hidden [B, 1, d] otherwise; logits [B, 1, V] out on the head stage."""
    attn = get_backend("attention", attn_backend)
    x = L.embed_tokens(sp["embed"], x_in) if spec.has_embed else x_in
    B = x.shape[0]
    pos = cache["length"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)
    new_stacks = []

    for blocks, kv in zip(_present_stacks(sp), cache["stacks"]):
        def body(h, inp):
            blk, k_cache, v_cache = inp
            hn = L.rms_norm(h, blk["ln_attn"], cfg.norm_eps)
            q, k, v = L.qkv_project(blk["attn"], hn)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k = L.apply_rope(k, positions, cfg.rope_theta)
            o, k_cache, v_cache = TF._decode_attn(
                attn, q, k, v, k_cache, v_cache, pos, None)
            h = h + L.out_project(blk["attn"], o.astype(h.dtype), h.dtype)
            hm = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
            if blk.get("mlp") is not None:
                h = h + L.mlp(blk["mlp"], hm)
            else:
                out, _ = moe_ffn_dispatch(blk["moe"], hm, cfg, dp_groups)
                h = h + out
            return h, (k_cache, v_cache)

        x, (ks, vs) = jax.lax.scan(body, x, (blocks, kv["k"], kv["v"]))
        new_stacks.append({"k": ks, "v": vs})

    new_cache = {"stacks": new_stacks, "length": pos + 1}
    if spec.has_head:
        x = L.rms_norm(x, sp["ln_f"], cfg.norm_eps)
        table = sp["embed"] if cfg.tie_embeddings else sp["unembed"]
        return L.unembed(x, table), new_cache
    return x, new_cache


def cache_seq_axes(cache):
    """Growing-KV sequence axes: every ``k``/``v`` leaf inside ``stacks``
    pages into the KV pool (seq axis -2); ``length`` stays slot-resident.
    See :func:`repro.models.kvcache.seq_axis_tree`."""
    from repro.models.kvcache import seq_axis_tree

    return seq_axis_tree(cache)
