"""Attention score computation: chunked (flash-style) softmax streaming.

Three entry points:

* :func:`chunked_causal_attention` — training/prefill.  Never materializes
  the full [Sq, Sk] score matrix: scans KV chunks with running (max, sum,
  acc) — the pure-jnp flash algorithm, and the oracle for the Pallas
  ``flash_attention`` kernel.
* :func:`decode_attention` — single-query attention against a KV cache,
  scanning KV chunks (the oracle for the ``decode_attention`` kernel).  When
  the cache is sequence-sharded across devices, partial (acc, lse) pairs are
  psum-combined by the caller (split-KV / flash-decoding).
* :func:`full_attention` — naive reference for tests.

Decode caches use the **kernel-native** layout ``[B, KV, S, D]`` (the
``kernels/decode_attention`` block layout) end-to-end: every decode entry
point here consumes that layout directly, so the Pallas split-KV kernel, the
dense oracle and the chunked scan all read the same buffers without a
per-step re-layout (prefill writes the cache in this layout once).

All math accumulates in fp32.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

ACC = jnp.float32
NEG_INF = -1e30


def _gqa_scores(q, k):
    """q [B,Sq,H,D], k [B,Sk,KV,D] → scores [B,KV,G,Sq,Sk] (H = KV·G)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=ACC)


def full_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, q_offset: int = 0,
) -> jnp.ndarray:
    """Naive reference (materializes scores) — test oracle only."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    scores = _gqa_scores(q, k) / jnp.sqrt(D).astype(ACC)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=ACC)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_causal_attention(
    q: jnp.ndarray,             # [B, Sq, H, D]
    k: jnp.ndarray,             # [B, Sk, KV, D]
    v: jnp.ndarray,             # [B, Sk, KV, D]
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    causal: bool = True,
    q_offset: int = 0,          # global position of q[0] (prefill continuation)
) -> jnp.ndarray:
    """Flash-style attention: O(Sq·Sk) compute, O(chunk²) memory."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = -(-Sq // q_chunk)
    n_k = -(-Sk // kv_chunk)
    # pad to whole chunks
    q_pad = n_q * q_chunk - Sq
    k_pad = n_k * kv_chunk - Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    qs = q.reshape(B, n_q, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)

    kv_valid = (jnp.arange(n_k * kv_chunk) < Sk).reshape(n_k, kv_chunk)

    def q_body(qi, q_blk):
        # q_blk [B, KV, G, q_chunk, D]
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, ACC)
        l0 = jnp.zeros((B, KV, G, q_chunk), ACC)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), ACC)

        def kv_body(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk, valid = inp
            s = jnp.einsum("bkgqd,bksd->bkgqs", q_blk, k_blk,
                           preferred_element_type=ACC) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
                kpos = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = (qpos[:, None] >= kpos[None, :]) & valid[None, :]
            else:
                mask = jnp.broadcast_to(valid[None, :], (q_chunk, kv_chunk))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=ACC,
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(n_k), ks, vs, kv_valid)
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]

    # Checkpoint per q-chunk: naive autodiff through the kv scan would stash
    # every chunk's probability block — O(Sq·Sk) residuals, exactly what
    # flash attention exists to avoid.  Rematerializing per q-chunk bounds
    # backward residuals to one chunk row.
    q_body = jax.checkpoint(q_body, prevent_cse=False)
    outs = jax.lax.map(lambda args: q_body(*args), (jnp.arange(n_q), qs))
    # outs [n_q, B, KV, G, q_chunk, D] → [B, Sq, H, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_q * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,             # [B, 1, H, D] — one new token
    k_cache: jnp.ndarray,       # [B, KV, S, D] (local shard if seq-sharded)
    v_cache: jnp.ndarray,       # [B, KV, S, D]
    cache_len: Optional[jnp.ndarray] = None,  # valid prefix length (≤ S)
    kv_chunk: int = 2048,
    return_lse: bool = False,
) -> jnp.ndarray | Tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming single-token attention over the kernel-native KV cache.

    With ``return_lse=True`` returns the *normalized* partial output plus its
    logsumexp, so a sequence-sharded caller combines partials across devices
    as an lse-weighted average:
        w_i = exp(lse_i - max_i lse_i);  out = psum(w_i·out_i) / psum(w_i)
    — the split-KV / flash-decoding scheme.
    """
    B, _, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    kv_chunk = min(kv_chunk, S)
    n_k = -(-S // kv_chunk)
    pad = n_k * kv_chunk - S
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
    ks = k_cache.reshape(B, KV, n_k, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vs = v_cache.reshape(B, KV, n_k, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    qg = q.reshape(B, KV, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)
    if cache_len is None:
        cache_len = jnp.asarray(S, jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        kj, k_blk, v_blk = inp
        s = jnp.einsum("bkgd,bksd->bkgs", qg, k_blk,
                       preferred_element_type=ACC) * scale
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)
        valid = kpos < cache_len
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgs,bksd->bkgd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=ACC,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G), NEG_INF, ACC)
    l0 = jnp.zeros((B, KV, G), ACC)
    a0 = jnp.zeros((B, KV, G, D), ACC)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(n_k), ks, vs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    if return_lse:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out.reshape(B, 1, H, D), lse.reshape(B, 1, H)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_dense(
    q: jnp.ndarray,             # [B, 1, H, D]
    k_cache: jnp.ndarray,       # [B, KV, S, D]
    v_cache: jnp.ndarray,       # [B, KV, S, D]
    cache_len,                  # valid prefix length
    return_lse: bool = False,
) -> jnp.ndarray | Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token attention over the full cache, no chunking.

    Under pjit this is the *sequence-shardable* decode path: the scores
    einsum contracts the sharded S dim, so the partitioner emits masked
    partial softmax + all-reduce — exactly split-KV decode, chosen by the
    compiler instead of hand-written scans (which would reshape the sharded
    dim and force all-gathers).  Memory is fine because Sq = 1.

    ``return_lse=True`` returns ``(out [B,1,H,D] fp32 normalized partial,
    lse [B,1,H])`` for the explicit shard_map split-KV combine
    (:func:`combine_split_kv`).
    """
    B, _, H, D = q.shape
    KV, S = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)
    s = jnp.einsum("bqkgd,bksd->bkgqs", qg, k_cache,
                   preferred_element_type=ACC) * scale
    valid = jnp.arange(S) < cache_len
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bksd->bqkgd", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                     v_cache, preferred_element_type=ACC)
    if return_lse:
        lse = (m + jnp.log(jnp.maximum(l, 1e-30)))[..., 0, 0]  # [B, KV, G]
        return out.reshape(B, 1, H, D), lse.reshape(B, 1, H)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def combine_split_kv(
    out: jnp.ndarray,           # [B, 1, H, D] normalized local partial
    lse: jnp.ndarray,           # [B, 1, H] local logsumexp
    axis_names,
) -> jnp.ndarray:
    """Cross-device combine for sequence-sharded decode (inside shard_map).

    Shards with no valid positions contribute ``lse ≈ -inf`` → weight 0, so
    ragged ``cache_len`` never poisons the merge.  The combine is associative
    in exact arithmetic; shard-count invariance in fp32 is property-tested in
    ``tests/test_sharded_decode.py``.
    """
    m = jax.lax.pmax(lse, axis_names)
    w = jnp.exp(lse - m)
    num = jax.lax.psum(out * w[..., None], axis_names)
    den = jax.lax.psum(w, axis_names)
    return num / jnp.maximum(den[..., None], 1e-30)


def combine_split_kv_stacked(outs: jnp.ndarray, lses: jnp.ndarray) -> jnp.ndarray:
    """Host-side mirror of :func:`combine_split_kv` over a leading shard
    axis: ``outs [n, B, 1, H, D]``, ``lses [n, B, 1, H]`` → ``[B, 1, H, D]``.
    Used by the shard-count-invariance property tests and single-process
    split-KV emulation (the math is identical; ``psum``/``pmax`` become
    ``sum``/``max`` over axis 0)."""
    m = lses.max(axis=0)
    w = jnp.exp(lses - m)
    num = (outs * w[..., None]).sum(axis=0)
    den = w.sum(axis=0)
    return num / jnp.maximum(den[..., None], 1e-30)


def seq_shard_bounds(axis_names, s_local: int):
    """(offset, shard index) of this device's KV-cache sequence slice.

    Valid only inside a ``shard_map``/manual region where ``axis_names`` are
    bound.  Multiple axes compose row-major (the order the cache's S dim was
    sharded over), matching ``PartitionSpec((a, b))`` layout.
    """
    names = (axis_names if isinstance(axis_names, (tuple, list))
             else (axis_names,))
    idx = jnp.zeros((), jnp.int32)
    for a in names:
        idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
    return idx * s_local, idx


def insert_kv_local(cache: jnp.ndarray, update: jnp.ndarray, local_pos,
                    owned) -> jnp.ndarray:
    """Write a one-token KV update into a shard-local ``[B, KV, S_loc, D]``
    cache at ``local_pos``, as a no-op on shards that don't own the global
    position (``owned`` False): the surrounding values are read back and
    re-written so the buffer is bit-unchanged."""
    start = (0, 0, jnp.asarray(local_pos, jnp.int32), 0)
    cur = jax.lax.dynamic_slice(cache, start, update.shape)
    return jax.lax.dynamic_update_slice(
        cache, jnp.where(owned, update, cur), start)


def sharded_decode_attend(attn, q, k_new, v_new, k_cache, v_cache, pos,
                          axis_names):
    """The sequence-sharded decode op, start to finish (inside shard_map):
    insert the new token's ``[B, KV, 1, D]`` KV on the shard owning global
    position ``pos``, run the backend's split-KV form over the local slice
    with the shard-local valid prefix, and lse-combine partials across
    ``axis_names``.  Returns ``(o [B,1,H,D] fp32, k_cache, v_cache)``.
    This is THE hot-path recipe — the model families, the op-level parity
    tests and the ``decode_sharded_*`` bench all call it, so they can never
    drift apart."""
    s_local = k_cache.shape[2]
    offset, _ = seq_shard_bounds(axis_names, s_local)
    local_pos = jnp.clip(pos - offset, 0, s_local - 1)
    owned = (pos >= offset) & (pos - offset < s_local)
    k_cache = insert_kv_local(k_cache, k_new, local_pos, owned)
    v_cache = insert_kv_local(v_cache, v_new, local_pos, owned)
    local_len = jnp.clip(pos + 1 - offset, 0, s_local)
    o, lse = attn.decode_partial(q, k_cache, v_cache, cache_len=local_len)
    return combine_split_kv(o, lse, axis_names), k_cache, v_cache
