"""Attention score computation: chunked (flash-style) softmax streaming.

Three entry points:

* :func:`chunked_causal_attention` — training/prefill.  Never materializes
  the full [Sq, Sk] score matrix: scans KV chunks with running (max, sum,
  acc) — the pure-jnp flash algorithm, and the oracle for the Pallas
  ``flash_attention`` kernel.
* :func:`decode_attention` — single-query attention against a KV cache,
  scanning KV chunks (the oracle for the ``decode_attention`` kernel).  When
  the cache is sequence-sharded across devices, partial (acc, lse) pairs are
  psum-combined by the caller (split-KV / flash-decoding).
* :func:`full_attention` — naive reference for tests.

All math accumulates in fp32.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

ACC = jnp.float32
NEG_INF = -1e30


def _gqa_scores(q, k):
    """q [B,Sq,H,D], k [B,Sk,KV,D] → scores [B,KV,G,Sq,Sk] (H = KV·G)."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, D)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k, preferred_element_type=ACC)


def full_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
    causal: bool = True, q_offset: int = 0,
) -> jnp.ndarray:
    """Naive reference (materializes scores) — test oracle only."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    Sk = k.shape[1]
    scores = _gqa_scores(q, k) / jnp.sqrt(D).astype(ACC)
    if causal:
        qpos = jnp.arange(Sq) + q_offset
        kpos = jnp.arange(Sk)
        mask = qpos[:, None] >= kpos[None, :]
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w.astype(v.dtype), v,
                     preferred_element_type=ACC)
    return out.reshape(B, Sq, H, D).astype(q.dtype)


def chunked_causal_attention(
    q: jnp.ndarray,             # [B, Sq, H, D]
    k: jnp.ndarray,             # [B, Sk, KV, D]
    v: jnp.ndarray,             # [B, Sk, KV, D]
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    causal: bool = True,
    q_offset: int = 0,          # global position of q[0] (prefill continuation)
) -> jnp.ndarray:
    """Flash-style attention: O(Sq·Sk) compute, O(chunk²) memory."""
    B, Sq, H, D = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    n_q = -(-Sq // q_chunk)
    n_k = -(-Sk // kv_chunk)
    # pad to whole chunks
    q_pad = n_q * q_chunk - Sq
    k_pad = n_k * kv_chunk - Sk
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    if k_pad:
        k = jnp.pad(k, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, k_pad), (0, 0), (0, 0)))
    qs = q.reshape(B, n_q, q_chunk, KV, G, D).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)

    kv_valid = (jnp.arange(n_k * kv_chunk) < Sk).reshape(n_k, kv_chunk)

    def q_body(qi, q_blk):
        # q_blk [B, KV, G, q_chunk, D]
        m0 = jnp.full((B, KV, G, q_chunk), NEG_INF, ACC)
        l0 = jnp.zeros((B, KV, G, q_chunk), ACC)
        a0 = jnp.zeros((B, KV, G, q_chunk, D), ACC)

        def kv_body(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk, valid = inp
            s = jnp.einsum("bkgqd,bksd->bkgqs", q_blk, k_blk,
                           preferred_element_type=ACC) * scale
            if causal:
                qpos = qi * q_chunk + jnp.arange(q_chunk) + q_offset
                kpos = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = (qpos[:, None] >= kpos[None, :]) & valid[None, :]
            else:
                mask = jnp.broadcast_to(valid[None, :], (q_chunk, kv_chunk))
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bksd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=ACC,
            )
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_body, (m0, l0, a0), (jnp.arange(n_k), ks, vs, kv_valid)
        )
        return acc / jnp.maximum(l, 1e-30)[..., None]

    # Checkpoint per q-chunk: naive autodiff through the kv scan would stash
    # every chunk's probability block — O(Sq·Sk) residuals, exactly what
    # flash attention exists to avoid.  Rematerializing per q-chunk bounds
    # backward residuals to one chunk row.
    q_body = jax.checkpoint(q_body, prevent_cse=False)
    outs = jax.lax.map(lambda args: q_body(*args), (jnp.arange(n_q), qs))
    # outs [n_q, B, KV, G, q_chunk, D] → [B, Sq, H, D]
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, n_q * q_chunk, H, D)
    return out[:, :Sq].astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,             # [B, 1, H, D] — one new token
    k_cache: jnp.ndarray,       # [B, S, KV, D] (local shard if seq-sharded)
    v_cache: jnp.ndarray,       # [B, S, KV, D]
    cache_len: Optional[jnp.ndarray] = None,  # valid prefix length (≤ S)
    kv_chunk: int = 2048,
    return_lse: bool = False,
) -> jnp.ndarray | Tuple[jnp.ndarray, jnp.ndarray]:
    """Streaming single-token attention over the KV cache.

    With ``return_lse=True`` returns the *normalized* partial output plus its
    logsumexp, so a sequence-sharded caller combines partials across devices
    as an lse-weighted average:
        w_i = exp(lse_i - max_i lse_i);  out = psum(w_i·out_i) / psum(w_i)
    — the split-KV / flash-decoding scheme.
    """
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    kv_chunk = min(kv_chunk, S)
    n_k = -(-S // kv_chunk)
    pad = n_k * kv_chunk - S
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
    ks = k_cache.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    vs = v_cache.reshape(B, n_k, kv_chunk, KV, D).transpose(1, 0, 3, 2, 4)
    qg = q.reshape(B, KV, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)
    if cache_len is None:
        cache_len = jnp.asarray(S, jnp.int32)

    def body(carry, inp):
        m, l, acc = carry
        kj, k_blk, v_blk = inp
        s = jnp.einsum("bkgd,bksd->bkgs", qg, k_blk,
                       preferred_element_type=ACC) * scale
        kpos = kj * kv_chunk + jnp.arange(kv_chunk)
        valid = kpos < cache_len
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgs,bksd->bkgd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=ACC,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, KV, G), NEG_INF, ACC)
    l0 = jnp.zeros((B, KV, G), ACC)
    a0 = jnp.zeros((B, KV, G, D), ACC)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (jnp.arange(n_k), ks, vs))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    if return_lse:
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out.reshape(B, 1, H, D), lse.reshape(B, 1, H)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def decode_attention_dense(
    q: jnp.ndarray,             # [B, 1, H, D]
    k_cache: jnp.ndarray,       # [B, S, KV, D]
    v_cache: jnp.ndarray,       # [B, S, KV, D]
    cache_len,                  # valid prefix length
) -> jnp.ndarray:
    """Single-token attention over the full cache, no chunking.

    Under pjit this is the *sequence-shardable* decode path: the scores
    einsum contracts the sharded S dim, so the partitioner emits masked
    partial softmax + all-reduce — exactly split-KV decode, chosen by the
    compiler instead of hand-written scans (which would reshape the sharded
    dim and force all-gathers).  Memory is fine because Sq = 1.
    """
    B, _, H, D = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qg = q.reshape(B, 1, KV, G, D)
    scale = 1.0 / jnp.sqrt(D).astype(ACC)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                   preferred_element_type=ACC) * scale
    valid = jnp.arange(S) < cache_len
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgqs,bskd->bqkgd", (p / jnp.maximum(l, 1e-30)).astype(v_cache.dtype),
                     v_cache, preferred_element_type=ACC)
    return out.reshape(B, 1, H, D).astype(q.dtype)


def combine_split_kv(
    out: jnp.ndarray,           # [B, 1, H, D] normalized local partial
    lse: jnp.ndarray,           # [B, 1, H] local logsumexp
    axis_names,
) -> jnp.ndarray:
    """Cross-device combine for sequence-sharded decode (inside shard_map)."""
    m = jax.lax.pmax(lse, axis_names)
    w = jnp.exp(lse - m)
    num = jax.lax.psum(out * w[..., None], axis_names)
    den = jax.lax.psum(w, axis_names)
    return num / jnp.maximum(den[..., None], 1e-30)
