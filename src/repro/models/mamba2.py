"""Mamba2 — SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD algorithm: the sequence splits into chunks of ``cfg.ssm_chunk``;
within a chunk the recurrence is evaluated as a (masked, decay-weighted)
matmul — MXU-friendly — and chunk-level states are carried by a short
``lax.scan``.  This is exactly the decomposition the paper's Listing 1 uses,
and it is the oracle for the ``ssd_scan`` Pallas kernel.

Decode is the O(1) recurrent update on the [B, H, P, N] state.

Sharding: heads (H) shard over the ``model`` axis; B/C groups are small and
stay replicated; in/out projections shard like MLP weights.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L

PyTree = Any
ACC = jnp.float32


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig) -> PyTree:
    """Projections are kept per-component (z, x, B, C, dt) instead of one
    fused in_proj: a fused output dim would shard across the component
    boundaries on the ``model`` axis, forcing XLA to reshard at every split.
    Separate weights let z/x (and the x-conv) shard head-aligned while the
    small B/C/dt projections stay replicated — the TPU-native layout."""
    d, di = cfg.d_model, cfg.d_inner
    H, N, G, K = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups, cfg.conv_kernel
    ks = jax.random.split(key, 9)
    return {
        "ln": L.init_rms_norm(d),
        "in_z": L.dense_init(ks[0], (d, di)),
        "in_x": L.dense_init(ks[1], (d, di)),
        "in_B": L.dense_init(ks[2], (d, G * N)),
        "in_C": L.dense_init(ks[3], (d, G * N)),
        "in_dt": L.dense_init(ks[4], (d, H)),
        "conv_x_w": L.dense_init(ks[5], (K, di), in_axis_size=K),
        "conv_x_b": jnp.zeros((di,), L.PARAM_DTYPE),
        "conv_B_w": L.dense_init(ks[6], (K, G * N), in_axis_size=K),
        "conv_B_b": jnp.zeros((G * N,), L.PARAM_DTYPE),
        "conv_C_w": L.dense_init(ks[7], (K, G * N), in_axis_size=K),
        "conv_C_b": jnp.zeros((G * N,), L.PARAM_DTYPE),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),   # softplus ≈ 0.12
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.init_rms_norm(di),
        "out_proj": L.dense_init(ks[8], (di, d), in_axis_size=di),
    }


def init(key, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": L.init_embedding(keys[-2], cfg.padded_vocab(), cfg.d_model),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "ln_f": L.init_rms_norm(cfg.d_model),
    }


# ---------------------------------------------------------------------------
# pieces
# ---------------------------------------------------------------------------


def causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv over [B, L, C]; returns (y, new_state[K-1])."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    L_ = x.shape[1]
    y = sum(
        xp[:, i : i + L_, :] * w[i].astype(ACC) for i in range(K)
    ) + b.astype(ACC)
    new_state = xp[:, L_ : L_ + K - 1, :] if K > 1 else xp[:, :0, :]
    return jax.nn.silu(y).astype(x.dtype), new_state


def ssd_chunked(
    x: jnp.ndarray,     # [B, L, H, P]
    dt: jnp.ndarray,    # [B, L, H]  (post-softplus)
    A: jnp.ndarray,     # [H] (negative)
    Bm: jnp.ndarray,    # [B, L, G, N]
    Cm: jnp.ndarray,    # [B, L, G, N]
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-parallel SSD scan.  Returns (y [B,L,H,P], final_state)."""
    B_, L_, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    pad = (-L_) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Lp = L_ + pad
    nc = Lp // chunk
    xc = x.reshape(B_, nc, chunk, H, P).astype(ACC)
    dtc = dt.reshape(B_, nc, chunk, H).astype(ACC)
    Bc = Bm.reshape(B_, nc, chunk, G, N).astype(ACC)
    Cc = Cm.reshape(B_, nc, chunk, G, N).astype(ACC)

    dA = dtc * A.astype(ACC)                      # [B,nc,Q,H] (≤0)
    l = jnp.cumsum(dA, axis=2)                    # within-chunk log-decay
    l_last = l[:, :, -1]                          # [B,nc,H]

    # Phase 1 (checkpointed map over chunks): per-chunk states.  Keeping the
    # O(Q²) decay/CB tensors inside a rematerialized chunk body bounds
    # backward residuals to ONE chunk instead of all of them.
    def chunk_state(args):
        x1, dt1, B1, l1, ll1 = args               # [B,Q,H,P], [B,Q,H], …
        w1 = jnp.exp(jnp.clip(ll1[:, None] - l1, -60.0, 0.0)) * dt1
        Bh1 = jnp.repeat(B1, rep, axis=2)         # [B,Q,H,N]
        return jnp.einsum("bsh,bshm,bshp->bhpm", w1, Bh1, x1,
                          preferred_element_type=ACC)

    chunk_state = jax.checkpoint(chunk_state, prevent_cse=False)
    S_chunk = jax.lax.map(
        chunk_state,
        (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3, 4), l.transpose(1, 0, 2, 3),
         l_last.transpose(1, 0, 2)),
    )                                             # [nc,B,H,P,N]

    # Phase 2: inter-chunk recurrence (tiny state carry).
    def scan_body(S_prev, inp):
        S_c, decay_c = inp                        # [B,H,P,N], [B,H]
        S_next = S_prev * jnp.exp(jnp.clip(decay_c, -60.0, 0.0))[..., None, None] + S_c
        return S_next, S_prev

    S0 = (jnp.zeros((B_, H, P, N), ACC) if init_state is None
          else init_state.astype(ACC))
    S_final, S_prevs = jax.lax.scan(
        scan_body, S0, (S_chunk, l_last.transpose(1, 0, 2))
    )                                             # S_prevs [nc,B,H,P,N]

    # Phase 3 (checkpointed map over chunks): outputs.
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))

    def chunk_output(args):
        x1, dt1, B1, C1, l1, Sp1 = args
        CB = jnp.einsum("btgm,bsgm->bgts", C1, B1,
                        preferred_element_type=ACC)
        CBh = jnp.repeat(CB, rep, axis=1)         # [B,H,Q,Q]
        lt = l1.transpose(0, 2, 1)                # [B,H,Q]
        decay = jnp.exp(jnp.clip(lt[..., :, None] - lt[..., None, :],
                                 -60.0, 0.0))
        M = jnp.where(causal, CBh * decay, 0.0)
        xdt = x1 * dt1[..., None]
        y_in = jnp.einsum("bhts,bshp->bthp", M, xdt,
                          preferred_element_type=ACC)
        Ch1 = jnp.repeat(C1, rep, axis=2)         # [B,Q,H,N]
        y_x = jnp.einsum("bthm,bhpm->bthp", Ch1, Sp1,
                         preferred_element_type=ACC)
        y_x = y_x * jnp.exp(jnp.clip(l1, -60.0, 0.0))[..., None]
        return y_in + y_x

    chunk_output = jax.checkpoint(chunk_output, prevent_cse=False)
    ys = jax.lax.map(
        chunk_output,
        (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
         Bc.transpose(1, 0, 2, 3, 4), Cc.transpose(1, 0, 2, 3, 4),
         l.transpose(1, 0, 2, 3), S_prevs),
    )                                             # [nc,B,Q,H,P]
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B_, Lp, H, P)[:, :L_]
    return y, S_final


def ssd_decode(
    x: jnp.ndarray,     # [B, 1, H, P]
    dt: jnp.ndarray,    # [B, 1, H]
    A: jnp.ndarray,     # [H]
    Bm: jnp.ndarray,    # [B, 1, G, N]
    Cm: jnp.ndarray,    # [B, 1, G, N]
    state: jnp.ndarray,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent update: S ← exp(dt·A)·S + dt·B⊗x;  y = C·S."""
    H = x.shape[2]
    G = Bm.shape[2]
    rep = H // G
    xf = x[:, 0].astype(ACC)                       # [B,H,P]
    dtf = dt[:, 0].astype(ACC)                     # [B,H]
    Bh = jnp.repeat(Bm[:, 0], rep, axis=1).astype(ACC)   # [B,H,N]
    Ch = jnp.repeat(Cm[:, 0], rep, axis=1).astype(ACC)   # [B,H,N]
    decay = jnp.exp(jnp.clip(dtf * A.astype(ACC), -60.0, 0.0))
    S_new = state.astype(ACC) * decay[..., None, None] + jnp.einsum(
        "bh,bhm,bhp->bhpm", dtf, Bh, xf, preferred_element_type=ACC
    )
    y = jnp.einsum("bhm,bhpm->bhp", Ch, S_new, preferred_element_type=ACC)
    return y[:, None], S_new


def block_apply(
    blk: PyTree, x: jnp.ndarray, cfg: ModelConfig,
    conv_state: Optional[jnp.ndarray] = None,
    ssm_state: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full mamba2 block on [B, L, d].  Returns (out, conv_states, ssm_state).

    ``conv_state``: None (prefill from scratch) or dict with "x"/"B"/"C"
    tails of the three causal convolutions.
    """
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.d_inner
    B_, L_, _ = x.shape
    h = L.rms_norm(x, blk["ln"], cfg.norm_eps)

    def proj(w):
        return jnp.einsum("bld,dk->blk", h, w,
                          preferred_element_type=ACC).astype(h.dtype)

    z = proj(blk["in_z"])
    xr = proj(blk["in_x"])
    Br = proj(blk["in_B"])
    Cr = proj(blk["in_C"])
    dt = proj(blk["in_dt"])
    cs = conv_state or {}
    xs, conv_x = causal_conv(xr, blk["conv_x_w"], blk["conv_x_b"], cs.get("x"))
    Bm, conv_B = causal_conv(Br, blk["conv_B_w"], blk["conv_B_b"], cs.get("B"))
    Cm, conv_C = causal_conv(Cr, blk["conv_C_w"], blk["conv_C_b"], cs.get("C"))
    new_conv = {"x": conv_x, "B": conv_B, "C": conv_C}
    xs = xs.reshape(B_, L_, H, P)
    Bm = Bm.reshape(B_, L_, G, N)
    Cm = Cm.reshape(B_, L_, G, N)
    dt_ = jax.nn.softplus(dt.astype(ACC) + blk["dt_bias"])
    A = -jnp.exp(blk["A_log"])
    if L_ == 1 and ssm_state is not None:
        y, S = ssd_decode(xs, dt_, A, Bm, Cm, ssm_state)
    else:
        y, S = ssd_chunked(xs, dt_, A, Bm, Cm, cfg.ssm_chunk, init_state=ssm_state)
    y = y + xs.astype(ACC) * blk["D"][None, None, :, None]
    y = y.reshape(B_, L_, di).astype(x.dtype)
    y = L.rms_norm(y * jax.nn.silu(z.astype(ACC)).astype(y.dtype), blk["norm"],
                   cfg.norm_eps)
    out = jnp.einsum("bld,dk->blk", y, blk["out_proj"],
                     preferred_element_type=L.TP_PSUM_DTYPE).astype(x.dtype)
    return x + out, new_conv, S


def decode_block(
    blk: PyTree, x: jnp.ndarray, cfg: ModelConfig,
    conv_state: jnp.ndarray, ssm_state: jnp.ndarray,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent step on [B, 1, d]."""
    return block_apply(blk, x, cfg, conv_state=conv_state, ssm_state=ssm_state)


# ---------------------------------------------------------------------------
# model-level
# ---------------------------------------------------------------------------


def forward(params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = L.embed_tokens(params["embed"], tokens)

    def body(h, blk):
        h2, _, _ = block_apply(blk, h, cfg)
        return h2, None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                batch.get("mask"))


def prefill(params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: int = 0) -> Tuple[jnp.ndarray, PyTree]:
    """SSM 'cache' is O(1): conv tail + state per layer (max_len unused)."""
    x = L.embed_tokens(params["embed"], tokens)

    def body(h, blk):
        h2, conv_s, ssm_s = block_apply(blk, h, cfg)
        return h2, (conv_s, ssm_s)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (conv_states, ssm_states) = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x[:, -1:], params["embed"])
    cache = {
        "conv": conv_states, "ssm": ssm_states,
        "length": jnp.asarray(tokens.shape[1], jnp.int32),
    }
    return logits, cache


def decode_step(params: PyTree, token: jnp.ndarray, cache: PyTree,
                cfg: ModelConfig) -> Tuple[jnp.ndarray, PyTree]:
    x = L.embed_tokens(params["embed"], token)

    def body(h, inp):
        blk, conv_s, ssm_s = inp
        h2, conv_n, ssm_n = decode_block(blk, h, cfg, conv_s, ssm_s)
        return h2, (conv_n, ssm_n)

    x, (conv_states, ssm_states) = jax.lax.scan(
        body, x, (params["blocks"], cache["conv"], cache["ssm"])
    )
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"])
    return logits, {
        "conv": conv_states, "ssm": ssm_states, "length": cache["length"] + 1,
    }


def cache_seq_axes(cache):
    """Attention-free family: no growing KV — every state leaf is
    slot-resident in the continuous-batching scheduler (all ``None``)."""
    import jax

    return jax.tree_util.tree_map(lambda _: None, cache)
