"""Dense decoder-only transformer (internlm2 / llama3.2 / minicpm / codeqwen,
and the LM backbone of internvl2).

Layer params are stacked along a leading ``layers`` axis and the blocks run
under ``jax.lax.scan`` — keeps the HLO size O(1) in depth, which matters when
compiling 61-81 layer models against a 512-device mesh.  Activation
rematerialization wraps the scan body (``cfg.remat``).

The vlm family reuses this module: ``extra_embeds`` (precomputed patch/frame
embeddings from the stub frontend) are prepended to the token embeddings.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.backends import KVCacheLayout, get_backend
from repro.models import layers as L
from repro.models.attention import (
    chunked_causal_attention,
    sharded_decode_attend,
)
from repro.models.kvcache import pad_kv_to_layout

PyTree = Any


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_block(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.eff_heads, cfg.eff_kv_heads, cfg.d_head,
            qkv_bias=cfg.qkv_bias,
        ),
        "ln_mlp": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def init(key, cfg: ModelConfig) -> PyTree:
    keys = jax.random.split(key, cfg.n_layers + 2)
    blocks = [init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
    params = {
        "embed": L.init_embedding(keys[-2], cfg.padded_vocab(), cfg.d_model),
        "blocks": stacked,
        "ln_f": L.init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_embedding(keys[-1], cfg.padded_vocab(), cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _attn_train(block: PyTree, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray) -> jnp.ndarray:
    h = L.rms_norm(x, block["ln_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(block["attn"], h)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = chunked_causal_attention(q, k, v)
    return x + L.out_project(block["attn"], o, x.dtype)


def _mlp_apply(block: PyTree, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = L.rms_norm(x, block["ln_mlp"], cfg.norm_eps)
    return x + L.mlp(block["mlp"], h)


def block_train(block: PyTree, x: jnp.ndarray, cfg: ModelConfig,
                positions: jnp.ndarray) -> jnp.ndarray:
    return _mlp_apply(block, _attn_train(block, x, cfg, positions), cfg)


# ---------------------------------------------------------------------------
# forward (teacher-forced) + loss
# ---------------------------------------------------------------------------


def forward(
    params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig,
    extra_embeds: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """tokens [B, S] (+ optional prepended embeddings) → logits [B, S', V]."""
    x = L.embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def body(h, block):
        return block_train(block, h, cfg, positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return L.unembed(x, table)


def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig) -> jnp.ndarray:
    logits = forward(params, batch["tokens"], cfg,
                     extra_embeds=batch.get("extra_embeds"))
    n_extra = batch["extra_embeds"].shape[1] if batch.get("extra_embeds") is not None else 0
    if n_extra:
        logits = logits[:, n_extra:]
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                batch.get("mask"))


# ---------------------------------------------------------------------------
# serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(
    params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig, max_len: int,
    extra_embeds: Optional[jnp.ndarray] = None,
    layout: KVCacheLayout = KVCacheLayout(),
) -> Tuple[jnp.ndarray, PyTree]:
    """Run the prompt, build the kernel-native [B, KV, S, D] KV cache with
    capacity ``layout.padded_len(max_len)`` (see ``models.kvcache``)."""
    x = L.embed_tokens(params["embed"], tokens)
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def body(h, block):
        hn = L.rms_norm(h, block["ln_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(block["attn"], hn)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = chunked_causal_attention(q, k, v)
        h = h + L.out_project(block["attn"], o, h.dtype)
        h = _mlp_apply(block, h, cfg)
        k_pad = pad_kv_to_layout(k, max_len, layout)
        v_pad = pad_kv_to_layout(v, max_len, layout)
        return h, (k_pad, v_pad)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x[:, -1:], table)
    cache = {"k": ks, "v": vs, "length": jnp.asarray(S, jnp.int32)}
    return logits, cache


def _decode_attn(attn, q, k, v, k_cache, v_cache, pos, seq_shard_axes):
    """Shared per-layer decode-attention step over the kernel-native cache.

    Inserts the new token's KV and dispatches the backend.  Replicated
    caches (``seq_shard_axes=None``) write at the global position and decode
    locally.  Sequence-sharded caches (inside a shard_map binding the named
    axes over the cache's S dim) write on the shard owning ``pos``, run the
    backend's split-KV form over the local slice with the shard-local valid
    prefix, and lse-combine partials across shards — so ``pallas-splitk``
    (and every other backend) serves sharded fleets, not just single-device
    decode.  Returns (o [B,1,H,D], k_cache, v_cache).
    """
    B, _, KV, D = k.shape
    kt = k.astype(k_cache.dtype).reshape(B, KV, 1, D)
    vt = v.astype(v_cache.dtype).reshape(B, KV, 1, D)
    if seq_shard_axes is None:
        k_cache = jax.lax.dynamic_update_slice(k_cache, kt, (0, 0, pos, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, vt, (0, 0, pos, 0))
        o = attn.decode(q, k_cache, v_cache, cache_len=pos + 1)
        return o, k_cache, v_cache
    return sharded_decode_attend(attn, q, kt, vt, k_cache, v_cache, pos,
                                 seq_shard_axes)


def decode_step(
    params: PyTree, token: jnp.ndarray, cache: PyTree, cfg: ModelConfig,
    *, seq_shard_axes=None, attn_backend=None,
    layout: Optional[KVCacheLayout] = None,
) -> Tuple[jnp.ndarray, PyTree]:
    """One decode step.  token [B, 1] → logits [B, 1, V].

    ``seq_shard_axes``: mesh axis name(s) the KV cache's sequence dim is
    sharded over — the new token's KV is inserted on the owning shard and
    partial attention outputs are lse-combined across the axes (split-KV
    decode).  None means the cache is sequence-replicated locally.

    ``attn_backend``: :class:`repro.core.backends.AttentionBackend` name or
    instance; ``None`` resolves to ``dense-ref``, the oracle.

    ``layout``: the :class:`KVCacheLayout` the cache was allocated with —
    when given, the (local) cache capacity is checked against its padding
    rule at trace time.
    """
    attn = get_backend("attention", attn_backend)
    if layout is not None:
        layout.check_capacity(int(cache["k"].shape[3]))
    x = L.embed_tokens(params["embed"], token)
    B = x.shape[0]
    pos = cache["length"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)

    def body(carry, inp):
        h = carry
        block, k_cache, v_cache = inp
        hn = L.rms_norm(h, block["ln_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(block["attn"], hn)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o, k_cache, v_cache = _decode_attn(
            attn, q, k, v, k_cache, v_cache, pos, seq_shard_axes)
        h = h + L.out_project(block["attn"], o.astype(h.dtype), h.dtype)
        h = _mlp_apply(block, h, cfg)
        return h, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = L.unembed(x, table)
    new_cache = {"k": ks, "v": vs, "length": cache["length"] + 1}
    return logits, new_cache


def param_count(cfg: ModelConfig) -> int:
    return cfg.param_count()


# ---------------------------------------------------------------------------
# pipeline stages (serverless LM executor)
# ---------------------------------------------------------------------------
#
# A stage is a contiguous slice ``[spec.start, spec.stop)`` of the stacked
# blocks, optionally with the embedding (first stage) and the final norm +
# unembed (last stage).  Running the full scan as consecutive sub-scans over
# contiguous slices executes the exact same per-layer ops in the exact same
# order, so the chained stages reproduce the monolithic model's numerics —
# the wire ships activations as float32, which round-trips bf16 exactly.


def slice_stage_params(params: PyTree, spec) -> PyTree:
    """Materialize the parameter subtree stage ``spec`` keeps resident."""
    out: Dict[str, Any] = {
        "blocks": jax.tree.map(lambda a: a[spec.start:spec.stop],
                               params["blocks"]),
    }
    if spec.has_embed:
        out["embed"] = params["embed"]
    if spec.has_head:
        out["ln_f"] = params["ln_f"]
        if "unembed" in params:
            out["unembed"] = params["unembed"]
        elif not spec.has_embed:
            out["embed"] = params["embed"]  # tied head needs the table
    return out


def _unembed_last(sp: PyTree, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = L.rms_norm(x, sp["ln_f"], cfg.norm_eps)
    table = sp["embed"] if cfg.tie_embeddings else sp["unembed"]
    return L.unembed(x, table)


def stage_prefill(
    sp: PyTree, spec, x_in: jnp.ndarray, cfg: ModelConfig, max_len: int,
    extra_embeds: Optional[jnp.ndarray] = None,
    layout: KVCacheLayout = KVCacheLayout(),
) -> Tuple[jnp.ndarray, PyTree]:
    """One stage of ``prefill``.  ``x_in`` is the token ids [B, S] on the
    embedding stage, the previous stage's hidden states [B, S, d] otherwise.
    Returns (hidden [B, S, d] — or last-position logits [B, 1, V] on the head
    stage) plus this stage's resident KV cache."""
    if spec.has_embed:
        x = L.embed_tokens(sp["embed"], x_in)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    else:
        x = x_in
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def body(h, block):
        hn = L.rms_norm(h, block["ln_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(block["attn"], hn)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = chunked_causal_attention(q, k, v)
        h = h + L.out_project(block["attn"], o, h.dtype)
        h = _mlp_apply(block, h, cfg)
        k_pad = pad_kv_to_layout(k, max_len, layout)
        v_pad = pad_kv_to_layout(v, max_len, layout)
        return h, (k_pad, v_pad)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs) = jax.lax.scan(body, x, sp["blocks"])
    cache = {"k": ks, "v": vs, "length": jnp.asarray(S, jnp.int32)}
    if spec.has_head:
        return _unembed_last(sp, x[:, -1:], cfg), cache
    return x, cache


def stage_decode_step(
    sp: PyTree, spec, x_in: jnp.ndarray, cache: PyTree, cfg: ModelConfig,
    *, attn_backend=None,
) -> Tuple[jnp.ndarray, PyTree]:
    """One stage of ``decode_step``.  ``x_in`` is the new token [B, 1] on the
    embedding stage, the previous stage's hidden state [B, 1, d] otherwise.
    Returns (hidden [B, 1, d] — or logits [B, 1, V] on the head stage) plus
    the updated stage cache."""
    attn = get_backend("attention", attn_backend)
    x = L.embed_tokens(sp["embed"], x_in) if spec.has_embed else x_in
    B = x.shape[0]
    pos = cache["length"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)

    def body(carry, inp):
        h = carry
        block, k_cache, v_cache = inp
        hn = L.rms_norm(h, block["ln_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(block["attn"], hn)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o, k_cache, v_cache = _decode_attn(
            attn, q, k, v, k_cache, v_cache, pos, None)
        h = h + L.out_project(block["attn"], o.astype(h.dtype), h.dtype)
        h = _mlp_apply(block, h, cfg)
        return h, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(body, x, (sp["blocks"], cache["k"], cache["v"]))
    new_cache = {"k": ks, "v": vs, "length": cache["length"] + 1}
    if spec.has_head:
        return _unembed_last(sp, x, cfg), new_cache
    return x, new_cache


def cache_seq_axes(cache):
    """Growing-KV sequence axes for the continuous-batching scheduler:
    ``k``/``v`` page into the KV pool (seq axis -2), ``length`` stays
    slot-resident.  See :func:`repro.models.kvcache.seq_axis_tree`."""
    from repro.models.kvcache import seq_axis_tree

    return seq_axis_tree(cache)
