"""Model zoo: pure-JAX functional modules (params are pytrees of arrays).

Every architecture exposes:

* ``init(rng, cfg) -> params``
* ``forward(params, batch, cfg, *, mesh_info=None) -> logits``  (teacher-forced)
* ``prefill(params, batch, cfg) -> (logits, cache)``
* ``decode_step(params, token, cache, cfg) -> (logits, cache)``

plus ``param_count(cfg)`` / ``active_param_count(cfg)`` used by the roofline's
MODEL_FLOPS = 6·N·D term.
"""
