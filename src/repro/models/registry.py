"""Uniform model API over all families.

``get_model(cfg)`` returns a :class:`ModelApi` with init / loss_fn / forward /
prefill / decode_step — the single entry point used by the trainer, the
serving engine and the dry-run.  ``input_specs`` builds either concrete
batches (smoke tests) or ShapeDtypeStructs (dry-run) per (arch × shape),
including the stub frontend embeddings for vlm/audio archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, hybrid, mamba2, moe, transformer

PyTree = Any


@dataclasses.dataclass
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., PyTree]
    loss_fn: Callable[..., jnp.ndarray]
    forward: Callable[..., jnp.ndarray]
    prefill: Callable[..., Tuple[jnp.ndarray, PyTree]]
    decode_step: Callable[..., Tuple[jnp.ndarray, PyTree]]
    # cache pytree → matching pytree of Optional[int]: the sequence axis of
    # every growing KV leaf (paged by serving/kv_pool.py), None for
    # slot-resident state.  vmap-in_axes convention: traverse the result with
    # is_leaf=lambda x: x is None.
    cache_seq_axes: Callable[[PyTree], PyTree] = None


def get_model(cfg: ModelConfig, attn_backend=None) -> ModelApi:
    """Build the family's :class:`ModelApi`.

    ``attn_backend`` — :class:`repro.core.backends.AttentionBackend` name or
    instance used by every decode step of the attention-bearing families
    (``None`` → ``dense-ref``, the oracle).  Resolved once here so all jitted
    decode closures share a single static instance.  The backend's
    :class:`KVCacheLayout` (the kernel-native [B, KV, S, D] cache layout +
    block_k padding rule) is derived from the static ``max_len`` at prefill
    trace time and threaded into every family's ``prefill``; decode closures
    accept the family's extra kwargs (``seq_shard_axes=...`` for the
    sequence-sharded split-KV branch) as pass-through.
    """
    from repro.core.backends import cache_layout_for, get_backend

    fam = cfg.family
    attn = get_backend("attention", attn_backend) if fam != "ssm" else None
    layout = lambda max_len: cache_layout_for(attn, max_len)
    if fam in ("dense",):
        return ModelApi(
            cfg=cfg,
            init=lambda key: transformer.init(key, cfg),
            loss_fn=lambda p, b: transformer.loss_fn(p, b, cfg),
            forward=lambda p, b: transformer.forward(p, b["tokens"], cfg),
            prefill=lambda p, b, max_len: transformer.prefill(
                p, b["tokens"], cfg, max_len, layout=layout(max_len)),
            decode_step=lambda p, t, c, **kw: transformer.decode_step(
                p, t, c, cfg, attn_backend=attn, **kw),
            cache_seq_axes=transformer.cache_seq_axes,
        )
    if fam == "vlm":
        return ModelApi(
            cfg=cfg,
            init=lambda key: transformer.init(key, cfg),
            loss_fn=lambda p, b: transformer.loss_fn(p, b, cfg),
            forward=lambda p, b: transformer.forward(
                p, b["tokens"], cfg, extra_embeds=b["extra_embeds"]),
            prefill=lambda p, b, max_len: transformer.prefill(
                p, b["tokens"], cfg, max_len, extra_embeds=b["extra_embeds"],
                layout=layout(max_len)),
            decode_step=lambda p, t, c, **kw: transformer.decode_step(
                p, t, c, cfg, attn_backend=attn, **kw),
            cache_seq_axes=transformer.cache_seq_axes,
        )
    if fam == "moe":
        return ModelApi(
            cfg=cfg,
            init=lambda key: moe.init(key, cfg),
            loss_fn=lambda p, b, dp_groups=1: moe.loss_fn(p, b, cfg, dp_groups),
            forward=lambda p, b, dp_groups=1: moe.forward(
                p, b["tokens"], cfg, dp_groups)[0],
            prefill=lambda p, b, max_len, dp_groups=1: moe.prefill(
                p, b["tokens"], cfg, max_len, dp_groups,
                layout=layout(max_len)),
            decode_step=lambda p, t, c, dp_groups=1, **kw: moe.decode_step(
                p, t, c, cfg, dp_groups, attn_backend=attn, **kw),
            cache_seq_axes=moe.cache_seq_axes,
        )
    if fam == "ssm":
        return ModelApi(
            cfg=cfg,
            init=lambda key: mamba2.init(key, cfg),
            loss_fn=lambda p, b: mamba2.loss_fn(p, b, cfg),
            forward=lambda p, b: mamba2.forward(p, b["tokens"], cfg),
            prefill=lambda p, b, max_len=0: mamba2.prefill(
                p, b["tokens"], cfg, max_len),
            decode_step=lambda p, t, c: mamba2.decode_step(p, t, c, cfg),
            cache_seq_axes=mamba2.cache_seq_axes,
        )
    if fam == "hybrid":
        return ModelApi(
            cfg=cfg,
            init=lambda key: hybrid.init(key, cfg),
            loss_fn=lambda p, b: hybrid.loss_fn(p, b, cfg),
            forward=lambda p, b: hybrid.forward(p, b["tokens"], cfg),
            prefill=lambda p, b, max_len: hybrid.prefill(
                p, b["tokens"], cfg, max_len, layout=layout(max_len)),
            decode_step=lambda p, t, c, **kw: hybrid.decode_step(
                p, t, c, cfg, attn_backend=attn, **kw),
            cache_seq_axes=hybrid.cache_seq_axes,
        )
    if fam == "encdec":
        return ModelApi(
            cfg=cfg,
            init=lambda key: encdec.init(key, cfg),
            loss_fn=lambda p, b: encdec.loss_fn(p, b, cfg),
            forward=lambda p, b: encdec.forward(p, b, cfg),
            prefill=lambda p, b, max_len: encdec.prefill(
                p, b, cfg, max_len, layout=layout(max_len)),
            decode_step=lambda p, t, c, **kw: encdec.decode_step(
                p, t, c, cfg, attn_backend=attn, **kw),
            cache_seq_axes=encdec.cache_seq_axes,
        )
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# pipeline stages — the serverless LM executor's per-stage API
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StageModel:
    """Per-stage functions for the pipeline-parallel serverless executor.

    ``slice_params(params, spec)`` materializes the subtree a
    :class:`repro.core.partitioner.StageSpec` keeps worker-resident;
    ``prefill(stage_params, spec, x_in, max_len)`` and
    ``decode_step(stage_params, spec, x_in, stage_cache)`` run one stage —
    token ids in on the embedding stage, the previous stage's hidden states
    otherwise; logits out on the head stage.  The stage's KV cache never
    crosses a stage boundary."""

    cfg: ModelConfig
    slice_params: Callable[..., PyTree]
    prefill: Callable[..., Tuple[jnp.ndarray, PyTree]]
    decode_step: Callable[..., Tuple[jnp.ndarray, PyTree]]


def get_stage_model(cfg: ModelConfig, attn_backend=None) -> StageModel:
    """Stage-executor functions for ``cfg``'s family.

    Supported families: ``dense``/``vlm`` (transformer) and ``moe``.  The
    recurrent families (ssm/hybrid) and the encoder-decoder keep state shapes
    that the contiguous-layer-slice planner does not cover yet."""
    from repro.core.backends import cache_layout_for, get_backend

    fam = cfg.family
    if fam not in ("dense", "vlm", "moe"):
        raise ValueError(
            f"pipeline stages are not supported for family {fam!r} "
            f"(supported: dense, vlm, moe)")
    attn = get_backend("attention", attn_backend)
    layout = lambda max_len: cache_layout_for(attn, max_len)
    if fam in ("dense", "vlm"):
        return StageModel(
            cfg=cfg,
            slice_params=lambda p, spec: transformer.slice_stage_params(p, spec),
            prefill=lambda sp, spec, x, max_len, extra=None:
                transformer.stage_prefill(
                    sp, spec, x, cfg, max_len, extra_embeds=extra,
                    layout=layout(max_len)),
            decode_step=lambda sp, spec, x, c:
                transformer.stage_decode_step(
                    sp, spec, x, c, cfg, attn_backend=attn),
        )
    return StageModel(
        cfg=cfg,
        slice_params=lambda p, spec: moe.slice_stage_params(p, spec, cfg),
        prefill=lambda sp, spec, x, max_len, extra=None:
            moe.stage_prefill(sp, spec, x, cfg, max_len, layout=layout(max_len)),
        decode_step=lambda sp, spec, x, c:
            moe.stage_decode_step(sp, spec, x, c, cfg, attn_backend=attn),
    )


# ---------------------------------------------------------------------------
# input specs — concrete batches or ShapeDtypeStructs per (arch × shape)
# ---------------------------------------------------------------------------


def input_specs(
    cfg: ModelConfig,
    shape: ShapeConfig,
    abstract: bool = True,
    seed: int = 0,
) -> Dict[str, Any]:
    """Batch stand-ins for a (arch × shape) cell.

    ``abstract=True`` → ShapeDtypeStructs (dry-run: weak-type-correct,
    shardable, no allocation).  ``abstract=False`` → concrete random arrays
    (smoke tests / examples).

    train:   {"tokens" [B,S], "labels" [B,S], (+frontend embeds)}
    prefill: {"tokens" [B,S], ...}
    decode:  {"token" [B,1]} — the KV cache of length seq_len is built
             separately by ``cache_specs``.
    """
    B, S = shape.global_batch, shape.seq_len
    tok_t = jnp.int32

    def arr(shp, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shp, dtype)
        rng = np.random.default_rng(seed)
        if dtype == jnp.int32:
            return jnp.asarray(
                rng.integers(0, max(2, cfg.vocab_size or 2), size=shp), dtype)
        return jnp.asarray(rng.standard_normal(shp), dtype)

    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": arr((B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16),
                "tokens": arr((B, S), tok_t),
                "labels": arr((B, S), tok_t),
            }
        batch = {"tokens": arr((B, S), tok_t), "labels": arr((B, S), tok_t)}
        if cfg.family == "vlm":
            batch["extra_embeds"] = arr(
                (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
        return batch

    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {
                "frames": arr((B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16),
                "tokens": arr((B, S), tok_t),
            }
        if cfg.family == "vlm":
            # image tokens occupy the front of the context window: the text
            # prompt shrinks so prefix+prompt == seq_len == cache capacity
            return {
                "tokens": arr((B, S - cfg.frontend_tokens), tok_t),
                "extra_embeds": arr(
                    (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16),
            }
        return {"tokens": arr((B, S), tok_t)}

    # decode: one new token against a seq_len cache
    return {"token": arr((B, 1), tok_t)}


def cache_specs(
    cfg: ModelConfig, shape: ShapeConfig, abstract: bool = True,
) -> PyTree:
    """KV/SSM cache stand-ins of capacity ``shape.seq_len`` for decode cells.

    Attention KV arrays use the kernel-native ``[..., B, KV, S, D]`` layout
    (``models.kvcache`` / ``repro.core.backends.KVCacheLayout``); the
    capacity here is exactly ``seq_len`` — the identity layout, since the
    dry-run decodes through the ``dense-ref`` oracle.
    """
    B, S = shape.global_batch, shape.seq_len
    kv_dt = jnp.bfloat16

    def arr(shp, dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shp, dtype)
        return jnp.zeros(shp, dtype)

    def scalar_len(fill=None):
        if abstract:
            return jax.ShapeDtypeStruct((), jnp.int32)
        return jnp.asarray(S - 1 if fill is None else fill, jnp.int32)

    if cfg.family in ("dense", "vlm"):
        Lr = cfg.n_layers
        return {
            "k": arr((Lr, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            "v": arr((Lr, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            "length": scalar_len(),
        }
    if cfg.family == "moe":
        stacks = []
        if cfg.first_dense_layers:
            stacks.append({
                "k": arr((cfg.first_dense_layers, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
                "v": arr((cfg.first_dense_layers, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            })
        n_moe = cfg.n_layers - cfg.first_dense_layers
        stacks.append({
            "k": arr((n_moe, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            "v": arr((n_moe, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
        })
        return {"stacks": stacks, "length": scalar_len()}
    if cfg.family == "ssm":
        Lr = cfg.n_layers
        gn = cfg.ssm_groups * cfg.ssm_state
        Km1 = cfg.conv_kernel - 1
        return {
            "conv": {
                "x": arr((Lr, B, Km1, cfg.d_inner), kv_dt),
                "B": arr((Lr, B, Km1, gn), kv_dt),
                "C": arr((Lr, B, Km1, gn), kv_dt),
            },
            "ssm": arr((Lr, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                       jnp.float32),
            "length": scalar_len(),
        }
    if cfg.family == "hybrid":
        from repro.models.hybrid import _group_sizes

        n_full, g, tail = _group_sizes(cfg)
        gn = cfg.ssm_groups * cfg.ssm_state
        Km1 = cfg.conv_kernel - 1

        def conv_dict(lead):
            return {
                "x": arr(lead + (B, Km1, cfg.d_inner), kv_dt),
                "B": arr(lead + (B, Km1, gn), kv_dt),
                "C": arr(lead + (B, Km1, gn), kv_dt),
            }

        kv = (
            arr((n_full, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            arr((n_full, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
        )
        states = (
            conv_dict((n_full, g)),
            arr((n_full, g, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                jnp.float32),
        )
        cache = {"kv": kv, "states": states, "length": scalar_len()}
        if tail:
            cache["tail_kv"] = (
                arr((B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
                arr((B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            )
            cache["tail_state"] = (
                conv_dict((tail,)),
                arr((tail, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                    jnp.float32),
            )
        else:
            cache["tail_kv"] = None
            cache["tail_state"] = None
        return cache
    if cfg.family == "encdec":
        Lr = cfg.n_layers
        Ssrc = cfg.frontend_tokens
        return {
            "k": arr((Lr, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            "v": arr((Lr, B, cfg.eff_kv_heads, S, cfg.d_head), kv_dt),
            "kc": arr((Lr, B, cfg.eff_kv_heads, Ssrc, cfg.d_head), kv_dt),
            "vc": arr((Lr, B, cfg.eff_kv_heads, Ssrc, cfg.d_head), kv_dt),
            "length": scalar_len(),
            "src_length": scalar_len(fill=Ssrc),
        }
    raise ValueError(cfg.family)
