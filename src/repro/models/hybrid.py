"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

The shared transformer block (a single parameter set) is applied before every
``cfg.shared_attn_every``-th mamba block, consuming ``concat(hidden,
embedding)`` — Zamba's trick for reusing one attention block across depth.
Per-application LoRA deltas are omitted (noted in DESIGN.md §5).

Layout: blocks are organized as ``n_groups`` groups of ``shared_attn_every``
mamba blocks, each group preceded by the shared block.  Groups run under a
``lax.scan`` over stacked group params; a trailing partial group handles
``n_layers % shared_attn_every``.

Decode carries: per-layer SSM/conv states + per-site KV caches (one per
shared-block application).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.backends import KVCacheLayout, get_backend
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models.attention import chunked_causal_attention
from repro.models.kvcache import pad_kv_to_layout
from repro.models.transformer import _decode_attn

PyTree = Any
ACC = jnp.float32


def n_shared_sites(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.shared_attn_every)


def _group_sizes(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_full_groups, group_len, tail_len)."""
    g = cfg.shared_attn_every
    return cfg.n_layers // g, g, cfg.n_layers % g


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_shared_block(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    d2 = 2 * cfg.d_model
    return {
        "ln_attn": L.init_rms_norm(d2),
        "attn": L.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head,
            q_in_dim=d2,
        ),
        "ln_mlp": L.init_rms_norm(d2),
        "mlp": {
            "wi_gate": L.dense_init(jax.random.fold_in(k2, 0), (d2, cfg.d_ff)),
            "wi_up": L.dense_init(jax.random.fold_in(k2, 1), (d2, cfg.d_ff)),
            "wo": L.dense_init(jax.random.fold_in(k2, 2), (cfg.d_ff, cfg.d_model),
                               in_axis_size=cfg.d_ff),
        },
    }


def init(key, cfg: ModelConfig) -> PyTree:
    n_full, g, tail = _group_sizes(cfg)
    keys = jax.random.split(key, cfg.n_layers + 3)
    blocks = [M2.init_block(keys[i], cfg) for i in range(cfg.n_layers)]
    grouped = blocks[: n_full * g]
    groups = [
        jax.tree.map(lambda *xs: jnp.stack(xs), *grouped[i * g : (i + 1) * g])
        for i in range(n_full)
    ]
    params = {
        "embed": L.init_embedding(keys[-3], cfg.padded_vocab(), cfg.d_model),
        "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *groups),
        "tail": (
            jax.tree.map(lambda *xs: jnp.stack(xs), *blocks[n_full * g :])
            if tail else None
        ),
        "shared": init_shared_block(keys[-2], cfg),
        "ln_f": L.init_rms_norm(cfg.d_model),
    }
    return params


# ---------------------------------------------------------------------------
# shared attention block
# ---------------------------------------------------------------------------


def shared_block_train(shared: PyTree, h: jnp.ndarray, emb: jnp.ndarray,
                       cfg: ModelConfig, positions) -> jnp.ndarray:
    xin = jnp.concatenate([h, emb], axis=-1)
    a = L.rms_norm(xin, shared["ln_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(shared["attn"], a)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = chunked_causal_attention(q, k, v)
    h = h + L.out_project(shared["attn"], o, h.dtype)
    m = L.rms_norm(jnp.concatenate([h, emb], axis=-1), shared["ln_mlp"],
                   cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_gate"],
                      preferred_element_type=ACC)
    up = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_up"],
                    preferred_element_type=ACC)
    hm = (jax.nn.silu(gate) * up).astype(h.dtype)
    out = jnp.einsum("bsf,fd->bsd", hm, shared["mlp"]["wo"],
                     preferred_element_type=ACC).astype(h.dtype)
    return h + out


def shared_block_prefill(shared, h, emb, cfg, positions, max_len,
                         layout: KVCacheLayout = KVCacheLayout()):
    xin = jnp.concatenate([h, emb], axis=-1)
    a = L.rms_norm(xin, shared["ln_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(shared["attn"], a)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = chunked_causal_attention(q, k, v)
    h2 = h + L.out_project(shared["attn"], o, h.dtype)
    m = L.rms_norm(jnp.concatenate([h2, emb], axis=-1), shared["ln_mlp"],
                   cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_gate"],
                      preferred_element_type=ACC)
    up = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_up"],
                    preferred_element_type=ACC)
    hm = (jax.nn.silu(gate) * up).astype(h.dtype)
    h2 = h2 + jnp.einsum("bsf,fd->bsd", hm, shared["mlp"]["wo"],
                         preferred_element_type=ACC).astype(h.dtype)
    k_pad = pad_kv_to_layout(k, max_len, layout)
    v_pad = pad_kv_to_layout(v, max_len, layout)
    return h2, (k_pad, v_pad)


def shared_block_decode(shared, h, emb, cfg, positions, k_cache, v_cache, pos,
                        attn=None, seq_shard_axes=None):
    attn = attn if attn is not None else get_backend("attention", None)
    xin = jnp.concatenate([h, emb], axis=-1)
    a = L.rms_norm(xin, shared["ln_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(shared["attn"], a)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o, k_cache, v_cache = _decode_attn(
        attn, q, k, v, k_cache, v_cache, pos, seq_shard_axes)
    h2 = h + L.out_project(shared["attn"], o.astype(h.dtype), h.dtype)
    m = L.rms_norm(jnp.concatenate([h2, emb], axis=-1), shared["ln_mlp"],
                   cfg.norm_eps)
    gate = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_gate"],
                      preferred_element_type=ACC)
    up = jnp.einsum("bsd,df->bsf", m, shared["mlp"]["wi_up"],
                    preferred_element_type=ACC)
    hm = (jax.nn.silu(gate) * up).astype(h.dtype)
    h2 = h2 + jnp.einsum("bsf,fd->bsd", hm, shared["mlp"]["wo"],
                         preferred_element_type=ACC).astype(h.dtype)
    return h2, (k_cache, v_cache)


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def forward(params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    emb = L.embed_tokens(params["embed"], tokens)
    x = emb
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def group_body(h, group_blocks):
        h = shared_block_train(params["shared"], h, emb, cfg, positions)

        def mamba_body(hh, blk):
            h2, _, _ = M2.block_apply(blk, hh, cfg)
            return h2, None

        h, _ = jax.lax.scan(mamba_body, h, group_blocks)
        return h, None

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x, params["groups"])

    if params.get("tail") is not None:
        x = shared_block_train(params["shared"], x, emb, cfg, positions)

        def mamba_body(hh, blk):
            h2, _, _ = M2.block_apply(blk, hh, cfg)
            return h2, None

        tail_body = mamba_body
        if cfg.remat:
            tail_body = jax.checkpoint(mamba_body, prevent_cse=False)
        x, _ = jax.lax.scan(tail_body, x, params["tail"])

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    logits = forward(params, batch["tokens"], cfg)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params: PyTree, tokens: jnp.ndarray, cfg: ModelConfig,
            max_len: int,
            layout: KVCacheLayout = KVCacheLayout()) -> Tuple[jnp.ndarray, PyTree]:
    emb = L.embed_tokens(params["embed"], tokens)
    x = emb
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def group_body(h, group_blocks):
        h, kv = shared_block_prefill(params["shared"], h, emb, cfg, positions,
                                     max_len, layout)

        def mamba_body(hh, blk):
            h2, conv_s, ssm_s = M2.block_apply(blk, hh, cfg)
            return h2, (conv_s, ssm_s)

        h, states = jax.lax.scan(mamba_body, h, group_blocks)
        return h, (kv, states)

    if cfg.remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, (kvs, group_states) = jax.lax.scan(group_body, x, params["groups"])

    tail_state = None
    tail_kv = None
    if params.get("tail") is not None:
        x, tail_kv = shared_block_prefill(params["shared"], x, emb, cfg,
                                          positions, max_len, layout)

        def mamba_body(hh, blk):
            h2, conv_s, ssm_s = M2.block_apply(blk, hh, cfg)
            return h2, (conv_s, ssm_s)

        x, tail_state = jax.lax.scan(mamba_body, x, params["tail"])

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x[:, -1:], params["embed"])
    cache = {
        "kv": kvs,                    # (k [Gsites,...], v) stacked over sites
        "states": group_states,       # (conv [G, g, ...], ssm [G, g, ...])
        "tail_kv": tail_kv,
        "tail_state": tail_state,
        "length": jnp.asarray(S, jnp.int32),
    }
    return logits, cache


def decode_step(params: PyTree, token: jnp.ndarray, cache: PyTree,
                cfg: ModelConfig, attn_backend=None, seq_shard_axes=None,
                layout: Optional[KVCacheLayout] = None) -> Tuple[jnp.ndarray, PyTree]:
    attn = get_backend("attention", attn_backend)
    if layout is not None:
        layout.check_capacity(int(cache["kv"][0].shape[3]))
    emb = L.embed_tokens(params["embed"], token)
    x = emb
    B = x.shape[0]
    pos = cache["length"]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)

    def group_body(h, inp):
        group_blocks, (kc, vc), (conv_s, ssm_s) = inp
        h, (kc, vc) = shared_block_decode(params["shared"], h, emb, cfg,
                                          positions, kc, vc, pos, attn=attn,
                                          seq_shard_axes=seq_shard_axes)

        def mamba_body(hh, blk_state):
            blk, cs, ss = blk_state
            h2, cn, sn = M2.block_apply(blk, hh, cfg, conv_state=cs, ssm_state=ss)
            return h2, (cn, sn)

        h, (conv_n, ssm_n) = jax.lax.scan(mamba_body, h, (group_blocks, conv_s, ssm_s))
        return h, ((kc, vc), (conv_n, ssm_n))

    kvs = cache["kv"]
    x, (new_kvs, new_states) = jax.lax.scan(
        group_body, x, (params["groups"], kvs, cache["states"])
    )

    tail_kv, tail_state = cache.get("tail_kv"), cache.get("tail_state")
    if params.get("tail") is not None:
        x, tail_kv = shared_block_decode(params["shared"], x, emb, cfg,
                                         positions, tail_kv[0], tail_kv[1], pos,
                                         attn=attn,
                                         seq_shard_axes=seq_shard_axes)

        def mamba_body(hh, blk_state):
            blk, cs, ss = blk_state
            h2, cn, sn = M2.block_apply(blk, hh, cfg, conv_state=cs, ssm_state=ss)
            return h2, (cn, sn)

        x, tail_state = jax.lax.scan(mamba_body, x, (params["tail"],) + tuple(tail_state))

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"])
    return logits, {
        "kv": new_kvs, "states": new_states,
        "tail_kv": tail_kv, "tail_state": tail_state,
        "length": pos + 1,
    }


def cache_seq_axes(cache):
    """Growing-KV sequence axes: the ``kv`` and ``tail_kv`` stacks page into
    the KV pool (seq axis -2); conv/SSM ``states``/``tail_state`` and
    ``length`` stay slot-resident.  See
    :func:`repro.models.kvcache.seq_axis_tree`."""
    from repro.models.kvcache import seq_axis_tree

    return seq_axis_tree(cache)
