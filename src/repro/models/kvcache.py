"""KV / SSM state caches for serving.

Layouts (kernel-native, PR 4):
* attention KV:   {"k": [L, B, KV, S_cap, D], "v": same, "length": scalar}
  — the ``kernels/decode_attention`` block layout, with the capacity
  ``S_cap`` padded to the attention backend's ``block_k`` multiple at
  prefill (:class:`repro.core.backends.KVCacheLayout`), so the per-step
  decode dispatch reads the buffers as-is: no ``moveaxis``/``pad``.
* mamba2 state:   {"ssm": [L, B, H, P, N], "conv": [L, B, K-1, C], "length"}
* zamba2 shared-attention sites get their own KV stack indexed by site.

``length`` is an int32 scalar tracking the valid prefix (same for the whole
batch in this engine; ragged batches live in serving/batching.py).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.backends import KVCacheLayout

PyTree = Dict[str, jnp.ndarray]

__all__ = ["KVCacheLayout", "init_attn_cache", "init_ssm_cache",
           "update_layer_kv", "pad_kv_to_layout", "seq_axis_tree"]

# Cache-dict keys whose subtrees hold *growing* self-attention KV (sequence
# axis at -2, one new position written per decode step) vs. state that is
# slot-resident in the continuous-batching scheduler (SSM/conv states, the
# encoder-decoder's static cross KV, scalars).
_GROWING_KV_KEYS = frozenset({"k", "v", "kv", "tail_kv"})
_STATIC_KEYS = frozenset({"kc", "vc", "conv", "ssm", "states", "tail_state",
                          "length", "src_length"})


def seq_axis_tree(cache: Any) -> Any:
    """Pytree (matching ``cache``) of ``Optional[int]``: the sequence axis of
    every *growing* KV leaf (always ``-2`` in the kernel-native layout), or
    ``None`` for slot-resident state.

    This is the single source of truth for which cache leaves the paged
    :class:`repro.serving.kv_pool.KVBlockPool` owns and which the scheduler
    keeps stacked per slot.  The classification is by dict key along the
    tree path: ``k``/``v``/``kv``/``tail_kv`` subtrees grow (excluding the
    encoder-decoder's ``kc``/``vc`` cross KV, which is written once at
    prefill), everything else is slot-resident.  Families re-export this as
    ``cache_seq_axes`` so the scheduler never pattern-matches shapes.
    """

    def classify(path, leaf) -> Optional[int]:
        keys = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        if any(k in _STATIC_KEYS for k in keys):
            return None
        if any(k in _GROWING_KV_KEYS for k in keys) and jnp.ndim(leaf) >= 4:
            return -2
        return None

    return jax.tree_util.tree_map_with_path(classify, cache)


def init_attn_cache(
    n_layers: int, batch: int, max_len: int, n_kv: int, d_head: int,
    dtype=jnp.bfloat16, layout: KVCacheLayout = KVCacheLayout(),
) -> PyTree:
    shape = (n_layers, batch, n_kv, layout.padded_len(max_len), d_head)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
        "length": jnp.zeros((), dtype=jnp.int32),
    }


def init_ssm_cache(
    n_layers: int, batch: int, n_heads: int, head_dim: int, state: int,
    conv_kernel: int, conv_channels: int, dtype=jnp.float32,
) -> PyTree:
    return {
        "ssm": jnp.zeros((n_layers, batch, n_heads, head_dim, state), dtype=dtype),
        "conv": jnp.zeros((n_layers, batch, conv_kernel - 1, conv_channels), dtype=dtype),
        "length": jnp.zeros((), dtype=jnp.int32),
    }


def pad_kv_to_layout(k: jnp.ndarray, max_len: int,
                     layout: KVCacheLayout = KVCacheLayout()) -> jnp.ndarray:
    """[B, S, KV, D] prefill projections → kernel-native [B, KV, S_cap, D].

    One transpose + pad at prefill buys a re-layout-free decode loop: the
    capacity is ``layout.padded_len(max_len)`` and positions ≥ the running
    ``length`` stay zero until decode writes them.
    """
    k = jnp.moveaxis(k, 1, 2)
    pad = layout.padded_len(max_len) - k.shape[2]
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    return k


def update_layer_kv(cache: PyTree, layer: int, k_new, v_new, position) -> PyTree:
    """Insert [B, S_new, KV, D] at sequence offset ``position`` of ``layer``."""
    import jax.lax as lax

    zeros = jnp.zeros((), jnp.int32)
    idx = (jnp.asarray(layer, jnp.int32), zeros, zeros,
           jnp.asarray(position, jnp.int32), zeros)
    k_new = jnp.moveaxis(k_new, 1, 2)[None].astype(cache["k"].dtype)
    v_new = jnp.moveaxis(v_new, 1, 2)[None].astype(cache["v"].dtype)
    return {
        **cache,
        "k": lax.dynamic_update_slice(cache["k"], k_new, idx),
        "v": lax.dynamic_update_slice(cache["v"], v_new, idx),
    }
