"""KV / SSM state caches for serving.

Layouts:
* attention KV:   {"k": [L, B, S_max, KV, D], "v": same, "length": scalar}
* mamba2 state:   {"ssm": [L, B, H, P, N], "conv": [L, B, K-1, C], "length"}
* zamba2 shared-attention sites get their own KV stack indexed by site.

``length`` is an int32 scalar tracking the valid prefix (same for the whole
batch in this engine; ragged batches live in serving/batching.py).
"""

from __future__ import annotations

from typing import Dict

import jax.numpy as jnp

PyTree = Dict[str, jnp.ndarray]


def init_attn_cache(
    n_layers: int, batch: int, max_len: int, n_kv: int, d_head: int,
    dtype=jnp.bfloat16,
) -> PyTree:
    shape = (n_layers, batch, max_len, n_kv, d_head)
    return {
        "k": jnp.zeros(shape, dtype=dtype),
        "v": jnp.zeros(shape, dtype=dtype),
        "length": jnp.zeros((), dtype=jnp.int32),
    }


def init_ssm_cache(
    n_layers: int, batch: int, n_heads: int, head_dim: int, state: int,
    conv_kernel: int, conv_channels: int, dtype=jnp.float32,
) -> PyTree:
    return {
        "ssm": jnp.zeros((n_layers, batch, n_heads, head_dim, state), dtype=dtype),
        "conv": jnp.zeros((n_layers, batch, conv_kernel - 1, conv_channels), dtype=dtype),
        "length": jnp.zeros((), dtype=jnp.int32),
    }


def update_layer_kv(cache: PyTree, layer: int, k_new, v_new, position) -> PyTree:
    """Insert [B, S_new, KV, D] at sequence offset ``position`` of ``layer``."""
    import jax.lax as lax

    zeros = jnp.zeros((), jnp.int32)
    idx = (jnp.asarray(layer, jnp.int32), zeros, jnp.asarray(position, jnp.int32),
           zeros, zeros)
    return {
        **cache,
        "k": lax.dynamic_update_slice(cache["k"], k_new[None].astype(cache["k"].dtype), idx),
        "v": lax.dynamic_update_slice(cache["v"], v_new[None].astype(cache["v"].dtype), idx),
    }
