"""Shared model layers, pure JAX.

Conventions:
* params are nested dicts of jnp arrays; every init takes an explicit key;
* activations flow as ``[batch, seq, d_model]`` in ``cfg.param_dtype`` (bf16
  by default) with fp32 accumulation inside matmuls/softmax
  (``preferred_element_type``);
* logical axis names annotate every parameter via ``AXES`` side-tables so the
  distribution layer can build PartitionSpecs without touching model code.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

PARAM_DTYPE = jnp.bfloat16
ACC_DTYPE = jnp.float32


# ---------------------------------------------------------------------------
# sharding-hint context (set by the distributed launcher; no-op on CPU tests)
# ---------------------------------------------------------------------------

_SHARD_CTX: Dict[str, Any] = {"mesh": None, "dp": (), "model": None}


def set_shard_ctx(mesh=None, dp=(), model=None) -> None:
    _SHARD_CTX.update(mesh=mesh, dp=tuple(dp), model=model)


def shard_ctx() -> Dict[str, Any]:
    return dict(_SHARD_CTX)


# Dtype of TP partial sums (the tensors the partitioner all-reduces across
# the model axis).  fp32 is the numerically conservative baseline; bf16
# halves the dominant collective volume (§Perf) at the cost of 16-way bf16
# accumulation — the industry-standard trade (Megatron trains with bf16
# grads/collectives).
TP_PSUM_DTYPE = ACC_DTYPE


def set_tp_psum_dtype(dtype) -> None:
    global TP_PSUM_DTYPE
    TP_PSUM_DTYPE = dtype


def constrain(x: "jnp.ndarray", *axes) -> "jnp.ndarray":
    """with_sharding_constraint via symbolic axes: "dp" | "model" | None.

    A no-op unless the launcher installed a mesh — model code stays mesh-free.
    Axes that do not divide the dimension are dropped.
    """
    mesh = _SHARD_CTX["mesh"]
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec

    def resolve(a):
        if a == "dp":
            return _SHARD_CTX["dp"] or None
        if a == "model":
            return _SHARD_CTX["model"]
        return a

    spec = []
    for dim, a in zip(x.shape, axes):
        r = resolve(a)
        if r is None:
            spec.append(None)
            continue
        names = r if isinstance(r, tuple) else (r,)
        size = 1
        for n in names:
            size *= mesh.shape[n]
        spec.append(r if dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(*spec))
    )


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=PARAM_DTYPE):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    scale = 1.0 / np.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype=PARAM_DTYPE):
    return (jax.random.normal(key, shape, dtype=jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(ACC_DTYPE)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(ACC_DTYPE)).astype(dtype)


def init_rms_norm(d: int, dtype=PARAM_DTYPE) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=ACC_DTYPE) / d_head))


def apply_rope(
    x: jnp.ndarray,             # [B, S, H, D]
    positions: jnp.ndarray,     # [B, S] or [S]
    theta: float,
) -> jnp.ndarray:
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                        # [D/2]
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(ACC_DTYPE) * freqs   # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(ACC_DTYPE), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# feed-forward (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=PARAM_DTYPE) -> Dict[str, jnp.ndarray]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "wi_up": dense_init(k2, (d_model, d_ff), dtype=dtype),
        "wo": dense_init(k3, (d_ff, d_model), in_axis_size=d_ff, dtype=dtype),
    }


MLP_AXES = {
    "wi_gate": ("embed", "ffn"),
    "wi_up": ("embed", "ffn"),
    "wo": ("ffn", "embed"),
}


def mlp(params: Dict[str, jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"],
                      preferred_element_type=TP_PSUM_DTYPE)
    up = jnp.einsum("bsd,df->bsf", x, params["wi_up"],
                    preferred_element_type=TP_PSUM_DTYPE)
    h = (jax.nn.silu(gate) * up).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"],
                      preferred_element_type=TP_PSUM_DTYPE).astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (GQA) — projections here; score computation in attention.py
# ---------------------------------------------------------------------------


def init_attention(
    key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
    qkv_bias: bool = False, q_in_dim: Optional[int] = None, dtype=PARAM_DTYPE,
) -> Dict[str, jnp.ndarray]:
    q_in = q_in_dim or d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (q_in, n_heads, d_head), in_axis_size=q_in, dtype=dtype),
        "wk": dense_init(ks[1], (q_in, n_kv_heads, d_head), in_axis_size=q_in, dtype=dtype),
        "wv": dense_init(ks[2], (q_in, n_kv_heads, d_head), in_axis_size=q_in, dtype=dtype),
        "wo": dense_init(ks[3], (n_heads, d_head, d_model),
                         in_axis_size=n_heads * d_head, dtype=dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads, d_head), dtype=dtype)
        p["bk"] = jnp.zeros((n_kv_heads, d_head), dtype=dtype)
        p["bv"] = jnp.zeros((n_kv_heads, d_head), dtype=dtype)
    return p


ATTN_AXES = {
    "wq": ("embed", "heads", "head_dim"),
    "wk": ("embed", "kv_heads", "head_dim"),
    "wv": ("embed", "kv_heads", "head_dim"),
    "wo": ("heads", "head_dim", "embed"),
    "bq": ("heads", "head_dim"),
    "bk": ("kv_heads", "head_dim"),
    "bv": ("kv_heads", "head_dim"),
}


def qkv_project(params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"],
                   preferred_element_type=TP_PSUM_DTYPE)
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"],
                   preferred_element_type=TP_PSUM_DTYPE)
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"],
                   preferred_element_type=TP_PSUM_DTYPE)
    if "bq" in params:
        q = q + params["bq"].astype(ACC_DTYPE)
        k = k + params["bk"].astype(ACC_DTYPE)
        v = v + params["bv"].astype(ACC_DTYPE)
    return q.astype(x.dtype), k.astype(x.dtype), v.astype(x.dtype)


def out_project(params, attn_out: jnp.ndarray, dtype) -> jnp.ndarray:
    return jnp.einsum("bshk,hkd->bsd", attn_out, params["wo"],
                      preferred_element_type=TP_PSUM_DTYPE).astype(dtype)


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype=PARAM_DTYPE):
    return embed_init(key, (vocab, d_model), dtype=dtype)


EMBED_AXES = ("vocab", "embed")


def embed_tokens(table: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def unembed(x: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    """Logits in fp32 (numerics) — [B, S, V], vocab-sharded over model."""
    logits = jnp.einsum("bsd,vd->bsv", x, table, preferred_element_type=ACC_DTYPE)
    return constrain(logits, "dp", None, "model")


def cross_entropy_loss(
    logits: jnp.ndarray,        # [B, S, V] fp32
    labels: jnp.ndarray,        # [B, S] int32
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
