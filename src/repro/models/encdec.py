"""Encoder-decoder backbone (seamless-m4t-medium).

Encoder: bidirectional transformer over precomputed *frame embeddings* (the
speech frontend is a stub per the assignment — ``input_specs()`` feeds
[B, frontend_tokens, d_model] directly).  Decoder: causal self-attention +
cross-attention over the encoder output.  Decode shapes exercise the decoder
with cached self-attn KV + precomputed cross-attn KV (standard enc-dec
serving); the encoder has no decode step.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.backends import KVCacheLayout, get_backend
from repro.models import layers as L
from repro.models.attention import chunked_causal_attention
from repro.models.kvcache import pad_kv_to_layout
from repro.models.transformer import _decode_attn

PyTree = Any
ACC = jnp.float32


def init_enc_block(key, cfg: ModelConfig) -> PyTree:
    k1, k2 = jax.random.split(key)
    return {
        "ln_attn": L.init_rms_norm(cfg.d_model),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                 cfg.d_head),
        "ln_mlp": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def init_dec_block(key, cfg: ModelConfig) -> PyTree:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln_self": L.init_rms_norm(cfg.d_model),
        "self_attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                      cfg.n_kv_heads, cfg.d_head),
        "ln_cross": L.init_rms_norm(cfg.d_model),
        "cross_attn": L.init_attention(k2, cfg.d_model, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.d_head),
        "ln_mlp": L.init_rms_norm(cfg.d_model),
        "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init(key, cfg: ModelConfig) -> PyTree:
    ke = jax.random.split(key, cfg.n_encoder_layers + cfg.n_layers + 2)
    enc = [init_enc_block(ke[i], cfg) for i in range(cfg.n_encoder_layers)]
    dec = [init_dec_block(ke[cfg.n_encoder_layers + i], cfg)
           for i in range(cfg.n_layers)]
    return {
        "embed": L.init_embedding(ke[-2], cfg.padded_vocab(), cfg.d_model),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "ln_enc": L.init_rms_norm(cfg.d_model),
        "ln_f": L.init_rms_norm(cfg.d_model),
    }


def encode(params: PyTree, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames [B, S_src, d_model] (stub frontend output) → memory."""
    x = frames.astype(L.PARAM_DTYPE)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)

    def body(h, blk):
        a = L.rms_norm(h, blk["ln_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["attn"], a)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = chunked_causal_attention(q, k, v, causal=False)
        h = h + L.out_project(blk["attn"], o, h.dtype)
        m = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
        return h + L.mlp(blk["mlp"], m), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["ln_enc"], cfg.norm_eps)


def _dec_block_train(blk, h, memory, cfg, positions, mem_positions):
    a = L.rms_norm(h, blk["ln_self"], cfg.norm_eps)
    q, k, v = L.qkv_project(blk["self_attn"], a)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    o = chunked_causal_attention(q, k, v)
    h = h + L.out_project(blk["self_attn"], o, h.dtype)
    c = L.rms_norm(h, blk["ln_cross"], cfg.norm_eps)
    qc = jnp.einsum("bsd,dhk->bshk", c, blk["cross_attn"]["wq"],
                    preferred_element_type=ACC).astype(h.dtype)
    kc = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wk"],
                    preferred_element_type=ACC).astype(h.dtype)
    vc = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wv"],
                    preferred_element_type=ACC).astype(h.dtype)
    oc = chunked_causal_attention(qc, kc, vc, causal=False)
    h = h + L.out_project(blk["cross_attn"], oc, h.dtype)
    m = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
    return h + L.mlp(blk["mlp"], m)


def forward(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """batch: {"frames": [B,S_src,d], "tokens": [B,S_tgt]} → logits."""
    memory = encode(params, batch["frames"], cfg)
    x = L.embed_tokens(params["embed"], batch["tokens"])
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    mem_positions = jnp.arange(memory.shape[1])[None, :].repeat(B, axis=0)

    def body(h, blk):
        return _dec_block_train(blk, h, memory, cfg, positions, mem_positions), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    return L.unembed(x, params["embed"])


def loss_fn(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    logits = forward(params, batch, cfg)
    return L.cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                                batch.get("mask"))


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------


def prefill(params: PyTree, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            max_len: int,
            layout: KVCacheLayout = KVCacheLayout()) -> Tuple[jnp.ndarray, PyTree]:
    """Encode source + run decoder prompt; cache self-KV + cross-KV, both in
    the kernel-native [B, KV, S, D] layout (cross capacity padded to the
    same ``layout`` quantum; its true length rides along as ``src_length``)."""
    memory = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    x = L.embed_tokens(params["embed"], tokens)
    B, S, _ = x.shape
    positions = jnp.arange(S)[None, :].repeat(B, axis=0)
    mem_positions = jnp.arange(memory.shape[1])[None, :].repeat(B, axis=0)
    s_src = memory.shape[1]

    def body(h, blk):
        a = L.rms_norm(h, blk["ln_self"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["self_attn"], a)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o = chunked_causal_attention(q, k, v)
        h = h + L.out_project(blk["self_attn"], o, h.dtype)
        c = L.rms_norm(h, blk["ln_cross"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", c, blk["cross_attn"]["wq"],
                        preferred_element_type=ACC).astype(h.dtype)
        kc = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wk"],
                        preferred_element_type=ACC).astype(h.dtype)
        vc = jnp.einsum("bsd,dhk->bshk", memory, blk["cross_attn"]["wv"],
                        preferred_element_type=ACC).astype(h.dtype)
        oc = chunked_causal_attention(qc, kc, vc, causal=False)
        h = h + L.out_project(blk["cross_attn"], oc, h.dtype)
        m = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
        h = h + L.mlp(blk["mlp"], m)
        k_pad = pad_kv_to_layout(k, max_len, layout)
        v_pad = pad_kv_to_layout(v, max_len, layout)
        kc_pad = pad_kv_to_layout(kc, s_src, layout)
        vc_pad = pad_kv_to_layout(vc, s_src, layout)
        return h, (k_pad, v_pad, kc_pad, vc_pad)

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, (ks, vs, kcs, vcs) = jax.lax.scan(body, x, params["dec_blocks"])
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x[:, -1:], params["embed"])
    cache = {"k": ks, "v": vs, "kc": kcs, "vc": vcs,
             "length": jnp.asarray(S, jnp.int32),
             "src_length": jnp.asarray(s_src, jnp.int32)}
    return logits, cache


def decode_step(params: PyTree, token: jnp.ndarray, cache: PyTree,
                cfg: ModelConfig, attn_backend=None, seq_shard_axes=None,
                layout: Optional[KVCacheLayout] = None) -> Tuple[jnp.ndarray, PyTree]:
    """Decoder step.  Only the growing self-attention cache participates in
    sequence sharding (``seq_shard_axes``); the precomputed cross-attention
    KV stays replicated and decodes locally against ``src_length`` valid
    positions (its capacity may be padded past the true source length)."""
    attn = get_backend("attention", attn_backend)
    if layout is not None:
        layout.check_capacity(int(cache["k"].shape[3]))
        layout.check_capacity(int(cache["kc"].shape[3]))
    x = L.embed_tokens(params["embed"], token)
    B = x.shape[0]
    pos = cache["length"]
    src_len = cache.get("src_length")
    if src_len is None:  # legacy caches: capacity == true source length
        src_len = cache["kc"].shape[3]
    positions = jnp.full((B, 1), pos, dtype=jnp.int32)

    def body(h, inp):
        blk, kc_self, vc_self, kc_cross, vc_cross = inp
        a = L.rms_norm(h, blk["ln_self"], cfg.norm_eps)
        q, k, v = L.qkv_project(blk["self_attn"], a)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        o, kc_self, vc_self = _decode_attn(
            attn, q, k, v, kc_self, vc_self, pos, seq_shard_axes)
        h = h + L.out_project(blk["self_attn"], o.astype(h.dtype), h.dtype)
        c = L.rms_norm(h, blk["ln_cross"], cfg.norm_eps)
        qc = jnp.einsum("bsd,dhk->bshk", c, blk["cross_attn"]["wq"],
                        preferred_element_type=ACC).astype(h.dtype)
        oc = attn.decode(qc, kc_cross, vc_cross, cache_len=src_len)
        h = h + L.out_project(blk["cross_attn"], oc.astype(h.dtype), h.dtype)
        m = L.rms_norm(h, blk["ln_mlp"], cfg.norm_eps)
        h = h + L.mlp(blk["mlp"], m)
        return h, (kc_self, vc_self)

    x, (ks, vs) = jax.lax.scan(
        body, x,
        (params["dec_blocks"], cache["k"], cache["v"], cache["kc"], cache["vc"]),
    )
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(x, params["embed"])
    return logits, {"k": ks, "v": vs, "kc": cache["kc"], "vc": cache["vc"],
                    "length": pos + 1,
                    "src_length": jnp.asarray(src_len, jnp.int32)}


def cache_seq_axes(cache):
    """Growing-KV sequence axes: decoder self-attention ``k``/``v`` page into
    the KV pool (seq axis -2); the cross-attention ``kc``/``vc`` are written
    once at prefill and stay slot-resident, as do ``length``/``src_length``.
    See :func:`repro.models.kvcache.seq_axis_tree`."""
    from repro.models.kvcache import seq_axis_tree

    return seq_axis_tree(cache)
