"""Paper §VI-F analogue: cost-model validation.

The paper captures fine-grained metrics, predicts costs, and compares with
actual AWS bills.  Here the simulator *is* the metered provider: we predict
costs from the analytic model fed with plan-level statistics, run the
simulator (which bills per-call), and compare — plus reproduce the paper's
published N=16384/P=20 dollar figures from its own reported workload stats."""

from __future__ import annotations

import math
from typing import List

from repro.core.cost_model import (
    AWS_PRICING,
    WorkloadStats,
    object_cost,
    queue_cost,
)
from repro.data.graphchallenge import make_inputs, make_sparse_dnn
from repro.faas.simulator import run_fsi


def run(neurons=512, layers=24, batch=64, P=8) -> List[dict]:
    net = make_sparse_dnn(neurons, n_layers=layers, seed=0)
    x0 = make_inputs(neurons, batch, seed=1)
    rows = []
    for ch, coster in (("queue", queue_cost), ("object", object_cost)):
        r = run_fsi(net, x0, P=P, channel=ch, memory_mb=4000)
        # "actual": simulator-metered quantities → cost model
        actual = r.cost
        # "predicted": re-billed from captured stats (same formulas → the
        # check is that the per-service meters are self-consistent)
        pred = coster(r.stats)
        rows.append(dict(
            name=f"costmodel_{ch}",
            predicted_usd=round(pred.total, 6),
            actual_usd=round(actual.total, 6),
            match=abs(pred.total - actual.total) < 1e-9,
        ))
    # paper-scale §VI-F figures from the paper's own workload statistics
    z = int(2.5e9)
    stats_q = WorkloadStats(
        P=20, mean_runtime_s=150.0, memory_mb=2000,
        publish_units=max(120 * 20, math.ceil(z / AWS_PRICING.publish_billing_unit)),
        bytes_sns_to_sqs=z,
        sqs_api_calls=120 * 20 * (2 + math.ceil(19 / 10)),
    )
    cq = queue_cost(stats_q)
    pairs = int(0.6 * 20 * 19)
    stats_o = WorkloadStats(
        P=20, mean_runtime_s=142.0, memory_mb=2000,
        s3_puts=120 * pairs, s3_gets=120 * pairs, s3_lists=120 * 20 * 3,
    )
    co = object_cost(stats_o)
    rows.append(dict(name="paper_vi_f_queue", predicted=round(cq.total, 2),
                     paper_predicted=0.35, paper_actual=0.35))
    rows.append(dict(name="paper_vi_f_object", predicted=round(co.total, 2),
                     paper_predicted=0.37, paper_actual=0.37))
    return rows
