"""Paper Table III analogue: HGP-DNN vs random partitioning (RP) —
communication volume, per-target rows, runtime."""

from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import partitioner as pt
from repro.data.graphchallenge import make_inputs, make_sparse_dnn
from repro.faas.simulator import run_fsi


def run(neurons=1024, layers=24, batch=32, P=16) -> List[dict]:
    net = make_sparse_dnn(neurons, n_layers=layers, seed=0)
    x0 = make_inputs(neurons, batch, seed=1)
    rows = []
    results = {}
    for method in ("hgp", "random", "block"):
        t0 = time.perf_counter()
        res = pt.partition_network(net.layers, P=P, method=method, seed=0)
        part_s = time.perf_counter() - t0
        rep = pt.measure_comm_volume(net.layers, res, bytes_per_row=4 * batch)
        r = run_fsi(net, x0, P=P, channel="object", partition=res,
                    memory_mb=4000)
        results[method] = rep.total_bytes_sent
        rows.append(dict(
            name=f"partition_{method}",
            data_volume_bytes=rep.total_bytes_sent,
            rows_per_target=round(rep.mean_rows_per_target, 1),
            per_sample_ms=r.per_sample_ms(batch),
            imbalance=round(res.imbalance(net.layers), 4),
            partition_s=round(part_s, 2),
        ))
    rows.append(dict(
        name="partition_rp_over_hgp_ratio",
        ratio=round(results["random"] / max(1, results["hgp"]), 2),
        paper_ratio=9.34,  # Table III: 36,374,240,000 / 3,895,079,200
    ))
    return rows
