"""Paper §III launch-mechanism claim: hierarchical tree vs centralized loop
vs Lambada-style two-level launch."""

from __future__ import annotations

from typing import List

from repro.faas.launch_tree import (
    central_launch_schedule,
    launch_schedule,
    two_level_launch_schedule,
)


def run() -> List[dict]:
    rows = []
    for P in (8, 20, 62, 256, 1000):
        rows.append(dict(
            name=f"launch_P{P}",
            tree_s=round(float(launch_schedule(P, branching=4).max()), 3),
            central_s=round(float(central_launch_schedule(P).max()), 3),
            two_level_s=round(float(two_level_launch_schedule(P).max()), 3),
        ))
    return rows
