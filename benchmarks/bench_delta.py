"""Perf-regression gate: diff a fresh ``BENCH_fsi.json`` against the
committed baseline and fail on >20% regression in the timing column of
named rows.

Only rows whose timing is **simulator-billed** (``per_sample_ms`` — derived
from the deterministic worker-clock model, identical on any host) are gated
by default, so the check is meaningful across CI machines.  Wall-clock
fields (``wall_s``, ``us_per_call`` host microbenches) are machine-dependent
and excluded unless rows are named explicitly via ``--rows``.

A named row missing from the *baseline* is skipped (new row, no trend yet);
missing from the *fresh* file it fails — a silently dropped benchmark is a
broken trajectory.  Placeholder timings (``""`` + note) are never silently
dropped either: a placeholder *baseline* is a loud ``SKIP`` on stderr, and a
numeric baseline whose *fresh* twin lost its numeric timing fails — a gated
benchmark going dark is indistinguishable from a regression.

A baseline whose ``meta.schema_version`` is missing or older than
``benchmarks.check_schema.SCHEMA_VERSION`` fails loudly (exit 2): a stale
committed artifact would silently skip every row added since it was
produced, which is exactly the silent-corruption mode this gate exists to
prevent.  Regenerate it (``make bench-paper``) and commit the result.

Usage::

    PYTHONPATH=src python -m benchmarks.bench_delta BASELINE.json FRESH.json \
        [--threshold 0.2] [--rows name1,name2,...]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Sequence

from benchmarks.check_schema import SCHEMA_VERSION

# Billed-time rows tracked across PRs: deterministic given the latency/cost
# model, so a >20% move is an algorithmic change, not machine noise.
# The ``*_overlap_*`` rows gate the double-buffered pipeline's billed
# per_sample_ms the same way; their ``wall_ms`` companion field is
# deliberately NOT in TIMING_FIELDS (host wall-clock, machine-dependent).
# The ``lm_pipeline_*`` rows gate the pipeline-parallel LM executor's billed
# per_token_ms across both channels and stage counts.
# The ``serving_cb_*`` rows gate continuous-batching scheduling efficiency:
# modeled per_token_ms from decode slot-step counts, static vs continuous.
# The ``fsi_*_eager_*`` rows gate eager-polling's billed per_sample_ms (with
# the lazy and phased clocks alongside), ``fsi_warm_P8`` the warm-pool run
# (its pre-request GB-seconds billed on warm_pool_usd), and
# ``lm_pipeline_auto_*`` the per-boundary channel autotuner.
DEFAULT_ROWS = (
    "fsi_serial",
    "fsi_queue_P2",
    "fsi_queue_P4",
    "fsi_queue_P8",
    "fsi_object_P2",
    "fsi_object_P4",
    "fsi_object_P8",
    "fsi_queue_overlap_P2",
    "fsi_queue_overlap_P4",
    "fsi_queue_overlap_P8",
    "fsi_object_overlap_P2",
    "fsi_object_overlap_P4",
    "fsi_object_overlap_P8",
    "fsi_sharded_P64_N1024",
    "fsi_sharded_fused_P64_N1024",
    "lm_pipeline_queue_P2",
    "lm_pipeline_queue_P4",
    "lm_pipeline_object_P2",
    "lm_pipeline_object_P4",
    "serving_cb_static_S2",
    "serving_cb_continuous_S2",
    "fsi_queue_eager_P2",
    "fsi_queue_eager_P4",
    "fsi_queue_eager_P8",
    "fsi_object_eager_P2",
    "fsi_object_eager_P4",
    "fsi_object_eager_P8",
    "fsi_warm_P8",
    "lm_pipeline_auto_P2",
    "lm_pipeline_auto_P4",
    "fsi_chaos_queue_P4",
    "fsi_chaos_object_P4",
    "fsi_recovery_overhead_P4",
)

TIMING_FIELDS = ("per_sample_ms", "per_token_ms", "us_per_call")


def _timing(row: dict):
    for f in TIMING_FIELDS:
        v = row.get(f)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            return f, float(v)
    return None, None


def compare(baseline: dict, fresh: dict, rows: Sequence[str] = DEFAULT_ROWS,
            threshold: float = 0.2,
            skipped: List[str] = None) -> List[str]:
    """Returns human-readable problems (empty == within budget).

    Non-numeric timing in a gated row is never silently dropped: a
    placeholder *baseline* (``""`` + note, the dependency-unavailable
    convention) is a loud skip via ``skipped``; a numeric baseline whose
    *fresh* twin lost its numeric timing is a problem — a gated benchmark
    that went dark is indistinguishable from a regression."""
    base_rows: Dict[str, dict] = {r.get("name"): r
                                  for r in baseline.get("rows", [])}
    new_rows: Dict[str, dict] = {r.get("name"): r
                                 for r in fresh.get("rows", [])}
    problems: List[str] = []
    for name in rows:
        base = base_rows.get(name)
        if base is None:
            continue  # no trend yet — nothing to regress against
        new = new_rows.get(name)
        if new is None:
            problems.append(f"{name}: present in baseline but missing from "
                            f"fresh rows (dropped benchmark?)")
            continue
        bf, bv = _timing(base)
        nf, nv = _timing(new)
        if bv is None:
            if skipped is not None:
                skipped.append(
                    f"{name}: baseline timing is a placeholder "
                    f"(note: {base.get('note') or 'none'}) — no trend to "
                    f"gate against")
            continue
        if nv is None:
            note = new.get("note")
            problems.append(
                f"{name}: baseline {bf}={bv:.4g} is numeric but the fresh "
                f"row carries no numeric timing"
                + (f" (note: {note})" if note else "")
                + " — gated benchmark went dark")
            continue
        if bv > 0 and nv > bv * (1.0 + threshold):
            problems.append(
                f"{name}: {nf} regressed {nv:.4g} vs baseline {bv:.4g} "
                f"(+{(nv / bv - 1) * 100:.1f}% > {threshold * 100:.0f}%)")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_fsi.json")
    ap.add_argument("fresh", help="freshly produced BENCH_fsi.json")
    ap.add_argument("--threshold", type=float, default=0.2,
                    help="max allowed relative regression (default 0.2)")
    ap.add_argument("--rows", default=None,
                    help="comma-separated row names (default: the billed-"
                         "time trajectory rows)")
    args = ap.parse_args(argv)
    payloads = []
    for path in (args.baseline, args.fresh):
        try:
            with open(path) as f:
                payloads.append(json.load(f))
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})", file=sys.stderr)
            return 2
    base_version = payloads[0].get("meta", {}).get("schema_version", 0)
    if not isinstance(base_version, int) or base_version < SCHEMA_VERSION:
        print(
            f"{args.baseline}: baseline schema_version="
            f"{base_version or 'missing'} is older than the current schema "
            f"v{SCHEMA_VERSION} — every row added since would be silently "
            f"skipped. Regenerate the committed baseline (make bench-paper) "
            f"and commit it.",
            file=sys.stderr)
        return 2
    rows = tuple(args.rows.split(",")) if args.rows else DEFAULT_ROWS
    skipped: List[str] = []
    problems = compare(payloads[0], payloads[1], rows=rows,
                       threshold=args.threshold, skipped=skipped)
    for s in skipped:
        print(f"bench-delta: SKIP {s}", file=sys.stderr)
    for p in problems:
        print(f"bench-delta: {p}", file=sys.stderr)
    if not problems:
        checked = sum(1 for n in rows
                      if n in {r.get('name') for r in payloads[0]['rows']})
        print(f"bench-delta: {checked} rows within "
              f"{args.threshold * 100:.0f}% of baseline")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
