"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV: ``us_per_call`` is the benchmark's
own wall time per simulated query/cell (µs) where meaningful, ``derived`` is
the table's headline quantity (cost, volume ratio, roofline term, …).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json [PATH]]

``--json`` additionally writes every row (plus run metadata) to
``BENCH_fsi.json`` — per-backend µs/query for the FSI channel and SpMM
roofline benches — so subsequent PRs have a perf trajectory to diff against.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time


def _emit(rows, sink=None):
    for row in rows:
        if sink is not None:
            sink.append(dict(row))
        row = dict(row)
        name = row.pop("name")
        us = row.pop("per_sample_ms", None)
        us = us * 1e3 if us is not None else row.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller configs (CI-sized)")
    ap.add_argument("--paper-scale", action="store_true",
                    help="add the P=64, N=65536 GraphChallenge sharded sweep "
                         "(vmap baseline + fused megakernel rows, with a "
                         "wall-clock budget recorded in the row)")
    ap.add_argument("--json", nargs="?", const="BENCH_fsi.json", default=None,
                    metavar="PATH",
                    help="also write all rows to PATH (default BENCH_fsi.json)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_cost_model,
        bench_fsi_channels,
        bench_launch,
        bench_partitioning,
        bench_roofline,
        bench_sporadic,
    )

    sink = [] if args.json else None
    print("name,us_per_call,derived")
    t0 = time.time()
    if args.quick:
        _emit(bench_fsi_channels.run(neurons=256, layers=12, batch=32,
                                     workers=(2, 4, 8),
                                     sharded_cases=((64, 1024, 4, 16),),
                                     paper_scale=args.paper_scale),
              sink)
        _emit(bench_partitioning.run(neurons=512, layers=12, batch=16, P=8), sink)
        _emit(bench_cost_model.run(neurons=256, layers=12, batch=32, P=4), sink)
        _emit(bench_sporadic.run(neurons=256, layers=12, batch=32), sink)
        _emit(bench_roofline.run(neurons=256, batch=32), sink)
    else:
        _emit(bench_fsi_channels.run(paper_scale=args.paper_scale), sink)
        _emit(bench_partitioning.run(), sink)
        _emit(bench_cost_model.run(), sink)
        _emit(bench_sporadic.run(), sink)
        _emit(bench_roofline.run(), sink)
    _emit(bench_launch.run(), sink)
    wall = time.time() - t0
    if args.json:
        from benchmarks.check_schema import SCHEMA_VERSION

        payload = {
            "meta": {
                "schema_version": SCHEMA_VERSION,
                "quick": args.quick,
                "paper_scale": args.paper_scale,
                "wall_s": round(wall, 2),
                "python": platform.python_version(),
                "machine": platform.machine(),
            },
            "rows": sink,
        }
        with open(args.json, "w") as f:
            # numpy scalars → native JSON numbers (not strings), so future
            # PRs can diff the trajectory numerically
            json.dump(payload, f, indent=1,
                      default=lambda o: o.item() if hasattr(o, "item") else str(o))
        print(f"# wrote {len(sink)} rows to {args.json}", file=sys.stderr)
    print(f"# total benchmark wall time: {wall:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
