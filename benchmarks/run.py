"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV: ``us_per_call`` is the benchmark's
own wall time per simulated query/cell (µs) where meaningful, ``derived`` is
the table's headline quantity (cost, volume ratio, roofline term, …).

    PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import sys
import time


def _emit(rows, default_metric=None):
    for row in rows:
        name = row.pop("name")
        us = row.pop("per_sample_ms", None)
        us = us * 1e3 if us is not None else row.pop("us_per_call", "")
        derived = ";".join(f"{k}={v}" for k, v in row.items())
        print(f"{name},{us},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller configs (CI-sized)")
    args = ap.parse_args(argv)

    from benchmarks import (
        bench_cost_model,
        bench_fsi_channels,
        bench_launch,
        bench_partitioning,
        bench_roofline,
        bench_sporadic,
    )

    print("name,us_per_call,derived")
    t0 = time.time()
    if args.quick:
        _emit(bench_fsi_channels.run(neurons=256, layers=12, batch=32,
                                     workers=(2, 4, 8)))
        _emit(bench_partitioning.run(neurons=512, layers=12, batch=16, P=8))
        _emit(bench_cost_model.run(neurons=256, layers=12, batch=32, P=4))
        _emit(bench_sporadic.run(neurons=256, layers=12, batch=32))
    else:
        _emit(bench_fsi_channels.run())
        _emit(bench_partitioning.run())
        _emit(bench_cost_model.run())
        _emit(bench_sporadic.run())
    _emit(bench_launch.run())
    _emit(bench_roofline.run())
    print(f"# total benchmark wall time: {time.time() - t0:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
