"""Paper Fig. 4/5 analogue: sporadic inference workloads — daily cost of
FSD-Inference vs Server-Always-On vs Server-Job-Scoped across query volumes,
and query latency per deployment.

Server baselines are modeled with the paper's instance sizing (§VI-A2):
c5.12xlarge always-on ×2 (redundancy), right-sized job-scoped instances with
startup latency; FSD costs come from the simulator's per-query bills."""

from __future__ import annotations

from typing import List

import numpy as np

from repro.data.graphchallenge import make_inputs, make_sparse_dnn
from repro.faas.simulator import run_fsi

# EC2 on-demand $/h (us-east-1): c5.2xlarge, c5.9xlarge, c5.12xlarge
C5_2X, C5_9X, C5_12X = 0.34, 1.53, 2.04
JOB_SCOPED_STARTUP_S = 150.0   # several minutes of provisioning (paper §I)


def run(neurons=512, layers=24, batch=64) -> List[dict]:
    net = make_sparse_dnn(neurons, n_layers=layers, seed=0)
    x0 = make_inputs(neurons, batch, seed=1)
    q = run_fsi(net, x0, P=8, channel="queue", memory_mb=4000)
    per_query_cost = q.cost.total
    per_query_latency = q.makespan

    rows = []
    always_on_daily = 2 * C5_12X * 24.0
    for queries_per_day in (10, 100, 1_000, 10_000, 100_000):
        fsd = per_query_cost * queries_per_day
        job_scoped = (per_query_latency + JOB_SCOPED_STARTUP_S) / 3600.0 * C5_2X \
            * queries_per_day
        rows.append(dict(
            name=f"sporadic_q{queries_per_day}",
            fsd_daily_usd=round(fsd, 2),
            always_on_daily_usd=round(always_on_daily, 2),
            job_scoped_daily_usd=round(job_scoped, 2),
            fsd_cheaper_than_always_on=fsd < always_on_daily,
        ))
    rows.append(dict(
        name="sporadic_latency_s",
        fsd=round(per_query_latency, 2),
        job_scoped=round(per_query_latency + JOB_SCOPED_STARTUP_S, 2),
        always_on_hot=round(per_query_latency * 0.5, 2),  # weights resident
    ))
    return rows
