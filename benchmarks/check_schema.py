"""Schema check for ``BENCH_fsi.json`` — the perf-trajectory artifact.

Trajectory tooling diffs rows across PRs by ``name`` and reads the timing
column, so a malformed row (missing name, non-numeric timing, duplicate name)
must fail CI instead of silently corrupting the trend.  Rules:

* the payload is ``{"meta": {...}, "rows": [...]}``;
* every row is an object with a non-empty string ``name``, unique across rows;
* a row's timing field — ``us_per_call`` or ``per_sample_ms`` — when present
  must be numeric (or ``""`` with an explanatory ``note``, the "dependency
  unavailable" convention);
* benchmark families with a timing contract (``spmm_roofline_*``,
  ``decode_attn_*``, ``decode_sharded_*``, ``fsi_*``) must carry a timing
  field;
* ``fsi_sharded_fused_*`` rows (the megakernel + batched-channel sweep) must
  carry a numeric ``wall_s``, and a row with a ``budget_s`` (the paper-scale
  case) must carry numeric ``budget_s`` and boolean ``within_budget``;
* ``wall_ms`` (host wall-clock alongside the billed timing), when present,
  must be numeric — it is never gated by bench_delta (machine-dependent),
  but a corrupt value would still poison the trajectory artifact;
* ``fsi_*_overlap_*`` rows (the double-buffered pipeline sweep) must carry
  numeric ``per_sample_ms`` AND ``phased_per_sample_ms`` plus a boolean
  ``counters_identical`` — the differential-oracle bit asserting charge
  counts match the phased path exactly;
* ``lm_pipeline_*`` rows (the pipeline-parallel LM serving sweep) must carry
  numeric ``per_token_ms``, ``phased_per_token_ms`` and
  ``usd_per_1k_tokens`` plus the same boolean ``counters_identical`` bit;
* ``serving_cb_*`` rows (continuous batching vs padded-static, PR 8) must
  carry numeric ``per_token_ms`` and ``tokens_per_s`` (both modeled from
  decode slot-step counts — deterministic and gated), and the
  ``serving_cb_continuous_*`` row must carry a boolean ``beats_static`` —
  the acceptance bit asserting continuous sustained throughput strictly
  above the padded-static baseline at equal slot count;
* ``fsi_*_eager_*`` rows (eager-polling sweep, PR 9) must carry numeric
  ``per_sample_ms``, ``lazy_per_sample_ms`` and ``phased_per_sample_ms``
  plus the boolean ``counters_identical`` oracle bit;
* ``fsi_warm_*`` rows (warm-pool provisioning) must carry numeric
  ``warm_pool_usd`` — the explicit pre-request GB-seconds line — plus
  ``counters_identical``;
* ``lm_pipeline_auto_*`` rows (per-boundary channel autotune) must carry a
  non-empty string ``chosen_channel_plan`` on top of the standard
  ``lm_pipeline_*`` contract;
* ``fsi_chaos_*`` rows (seeded crash-fault recovery, PR 10) must carry the
  boolean ``output_equal`` acceptance bit (recovered output bitwise equal to
  the fault-free run) plus numeric ``recovery_usd`` and ``n_reinvokes``;
* ``fsi_recovery_overhead_*`` rows must carry numeric ``overhead_pct`` and
  ``recovery_usd`` plus the ``counters_identical`` bit — arming a zero-fault
  plan must not move a single main-fabric charge count.

``SCHEMA_VERSION`` stamps the artifact (written into ``meta`` by
``benchmarks.run --json``): bump it whenever a rule above changes shape, so
``bench_delta`` can refuse a baseline produced under an older schema instead
of silently diffing incompatible rows.

Usage::

    PYTHONPATH=src python -m benchmarks.check_schema [BENCH_fsi.json]
"""

from __future__ import annotations

import json
import sys
from typing import List

# v2: lm_pipeline_* rows + per_token_ms timing column (PR 7)
# v3: serving_cb_* rows — continuous-batching throughput gate (PR 8)
# v4: fsi_*_eager_* / fsi_warm_* / lm_pipeline_auto_* rows — eager polling,
#     warm-pool billing (warm_pool_usd) and channel autotune
#     (chosen_channel_plan) gates (PR 9)
# v5: fsi_chaos_* / fsi_recovery_overhead_* rows — crash-fault recovery
#     (output_equal, recovery_usd) and zero-fault arming-overhead gates
#     (PR 10)
SCHEMA_VERSION = 5

TIMING_FIELDS = ("us_per_call", "per_sample_ms", "per_token_ms")
TIMED_PREFIXES = ("spmm_roofline_", "decode_attn_", "decode_sharded_",
                  "fsi_", "lm_pipeline_", "serving_cb_")


def validate(payload) -> List[str]:
    """Returns a list of human-readable problems (empty == valid)."""
    problems: List[str] = []
    if not isinstance(payload, dict):
        return [f"payload must be an object, got {type(payload).__name__}"]
    if not isinstance(payload.get("meta"), dict):
        problems.append("missing/invalid 'meta' object")
    rows = payload.get("rows")
    if not isinstance(rows, list) or not rows:
        problems.append("missing/empty 'rows' list")
        return problems
    seen = set()
    for i, row in enumerate(rows):
        where = f"rows[{i}]"
        if not isinstance(row, dict):
            problems.append(f"{where}: not an object")
            continue
        name = row.get("name")
        if not isinstance(name, str) or not name:
            problems.append(f"{where}: missing/empty 'name'")
            continue
        if name in seen:
            problems.append(f"{where}: duplicate name {name!r}")
        seen.add(name)
        timing = [f for f in TIMING_FIELDS if f in row]
        for f in timing:
            val = row[f]
            if val == "":
                if not row.get("note"):
                    problems.append(
                        f"{where} ({name}): empty {f} without a 'note'")
            elif not isinstance(val, (int, float)) or isinstance(val, bool):
                problems.append(
                    f"{where} ({name}): non-numeric {f}={val!r}")
        if not timing and name.startswith(TIMED_PREFIXES):
            problems.append(f"{where} ({name}): timed family without "
                            f"any of {TIMING_FIELDS}")
        if name.startswith("fsi_sharded_fused_") and not row.get("note"):
            wall = row.get("wall_s")
            if not isinstance(wall, (int, float)) or isinstance(wall, bool):
                problems.append(
                    f"{where} ({name}): fused sweep row without numeric "
                    f"'wall_s'")
        if "wall_ms" in row:
            wms = row["wall_ms"]
            if not isinstance(wms, (int, float)) or isinstance(wms, bool):
                problems.append(
                    f"{where} ({name}): non-numeric wall_ms={wms!r}")
        if name.startswith("fsi_") and "_overlap_" in name:
            ph = row.get("phased_per_sample_ms")
            if not isinstance(ph, (int, float)) or isinstance(ph, bool):
                problems.append(
                    f"{where} ({name}): overlap row without numeric "
                    f"'phased_per_sample_ms'")
            if not isinstance(row.get("counters_identical"), bool):
                problems.append(
                    f"{where} ({name}): overlap row without boolean "
                    f"'counters_identical'")
        if name.startswith("fsi_") and "_eager_" in name:
            for f in ("per_sample_ms", "lazy_per_sample_ms",
                      "phased_per_sample_ms"):
                v = row.get(f)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(
                        f"{where} ({name}): eager row without numeric {f!r}")
            if not isinstance(row.get("counters_identical"), bool):
                problems.append(
                    f"{where} ({name}): eager row without boolean "
                    f"'counters_identical'")
        if name.startswith("fsi_warm_"):
            v = row.get("warm_pool_usd")
            if not isinstance(v, (int, float)) or isinstance(v, bool):
                problems.append(
                    f"{where} ({name}): warm-pool row without numeric "
                    f"'warm_pool_usd'")
            if not isinstance(row.get("counters_identical"), bool):
                problems.append(
                    f"{where} ({name}): warm-pool row without boolean "
                    f"'counters_identical'")
        if name.startswith("fsi_chaos_"):
            if not isinstance(row.get("output_equal"), bool):
                problems.append(
                    f"{where} ({name}): chaos row without boolean "
                    f"'output_equal'")
            for f in ("recovery_usd", "n_reinvokes"):
                v = row.get(f)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(
                        f"{where} ({name}): chaos row without numeric {f!r}")
        if name.startswith("fsi_recovery_overhead_"):
            for f in ("overhead_pct", "recovery_usd"):
                v = row.get(f)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(
                        f"{where} ({name}): recovery-overhead row without "
                        f"numeric {f!r}")
            if not isinstance(row.get("counters_identical"), bool):
                problems.append(
                    f"{where} ({name}): recovery-overhead row without "
                    f"boolean 'counters_identical'")
        if name.startswith("lm_pipeline_auto_") and not row.get("note"):
            v = row.get("chosen_channel_plan")
            if not isinstance(v, str) or not v:
                problems.append(
                    f"{where} ({name}): autotune row without non-empty "
                    f"string 'chosen_channel_plan'")
        if name.startswith("lm_pipeline_") and not row.get("note"):
            for f in ("per_token_ms", "phased_per_token_ms",
                      "usd_per_1k_tokens"):
                v = row.get(f)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(
                        f"{where} ({name}): LM pipeline row without numeric "
                        f"{f!r}")
            if not isinstance(row.get("counters_identical"), bool):
                problems.append(
                    f"{where} ({name}): LM pipeline row without boolean "
                    f"'counters_identical'")
        if name.startswith("serving_cb_") and not row.get("note"):
            for f in ("per_token_ms", "tokens_per_s"):
                v = row.get(f)
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    problems.append(
                        f"{where} ({name}): serving_cb row without numeric "
                        f"{f!r}")
            if name.startswith("serving_cb_continuous_") \
                    and not isinstance(row.get("beats_static"), bool):
                problems.append(
                    f"{where} ({name}): continuous row without boolean "
                    f"'beats_static'")
        if "budget_s" in row:
            budget = row["budget_s"]
            if not isinstance(budget, (int, float)) or isinstance(budget, bool):
                problems.append(
                    f"{where} ({name}): non-numeric budget_s={budget!r}")
            if not isinstance(row.get("within_budget"), bool):
                problems.append(
                    f"{where} ({name}): budget_s without boolean "
                    f"'within_budget'")
    return problems


def main(argv=None) -> int:
    path = (argv or sys.argv[1:] or ["BENCH_fsi.json"])[0]
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable ({e})", file=sys.stderr)
        return 2
    problems = validate(payload)
    for p in problems:
        print(f"{path}: {p}", file=sys.stderr)
    if not problems:
        print(f"{path}: {len(payload['rows'])} rows ok")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
